"""repro.nn unit tests: composite-op accuracy, per-block oracle
contracts, target/optimizer portability, workload assembly, and the
lazy-import satellite on :mod:`repro.kernels`.

The conformance suite (``tests/test_conformance.py``) pushes random
block shapes through the full executor equivalence class; this file
pins the *numeric* contracts — the exp/recip error bounds docs/MODELS.md
documents, bit-exactness of the integer blocks, and the rtol bound of
the softmax block — plus the subsystem surface (``model_blocks``,
scheduler submission, bench section wiring).
"""
import subprocess
import sys

import numpy as np
import pytest

from repro import opt, targets
from repro.core import MVEConfig
from repro.core.isa import DType
from repro.frontend import BCAST, SEQ, KernelBuilder
from repro.nn import (ATTN_RTOL, BLOCK_KERNELS, MULTIDIM_BLOCKS,
                      model_blocks, ops)

CFG = MVEConfig()


# ---------------------------------------------------------------------------
# Composite ops: the three ISA gaps, measured against numpy.
# ---------------------------------------------------------------------------

def _run_unary(build, xs):
    """Trace ``y = build(b, x_vec)`` over a 1-D input and execute."""
    xs = np.asarray(xs, np.float32)
    b = KernelBuilder("unary")
    xo = b.input("x", (len(xs),), DType.F, init=xs)
    yo = b.output("y", (len(xs),), DType.F)
    b.width(32)
    with b.dims(len(xs)):
        yo.store(build(b, xo.load(SEQ)), SEQ)
    k = b.build()
    mem, _ = k.compile().run(k.pack())
    return k.unpack(np.asarray(mem))["y"]


def test_exp_approx_accuracy():
    """Relative error < 1e-5 over the whole post-max-subtract domain
    (docs/MODELS.md promises ~3e-6; assert with margin but tighter than
    the attention block's rtol)."""
    xs = np.linspace(-60.0, 0.0, 2048).astype(np.float32)
    got = _run_unary(lambda b, v: ops.exp_approx(b, v), xs)
    want = np.exp(xs.astype(np.float64))
    rel = np.abs(got - want) / want
    assert float(rel.max()) < 1e-5
    # exp(0) == 1 exactly: the online-softmax running sum relies on the
    # current chunk's max contributing exactly 1.0
    assert _run_unary(lambda b, v: ops.exp_approx(b, v), [0.0])[0] == 1.0


def test_exp_approx_clamps_underflow():
    got = _run_unary(lambda b, v: ops.exp_approx(b, v), [-1e4, -500.0])
    want = np.exp(-60.0)
    assert np.all(got > 0.0) and np.allclose(got, want, rtol=1e-5)


def test_recip_approx_accuracy():
    """1/s to ~fp32 precision over [1, max_val] — softmax denominators."""
    xs = np.concatenate([np.linspace(1.0, 64.0, 1024),
                         [1.0, 2.0, 63.999, 64.0]]).astype(np.float32)
    got = _run_unary(lambda b, v: ops.recip_approx(b, v, max_val=64.0), xs)
    rel = np.abs(got * xs.astype(np.float64) - 1.0)
    assert float(rel.max()) < 1e-6


@pytest.mark.parametrize("op,npop", [("add", None), ("max", np.max),
                                     ("min", np.min)])
def test_tree_reduce_dim0(op, npop):
    """Cross-dimension reduction matches numpy (add: in the pairwise
    tree order ``tree_sum_ref`` mirrors — bit-exact, not approximate)."""
    from repro.kernels.ref import tree_sum_ref

    rows, n = 8, 32
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows, n)).astype(np.float32)
    b = KernelBuilder("reduce")
    xo = b.inout("x", (rows, n), DType.F, init=x)
    ro = b.scratch("r", (rows, n), DType.F)
    yo = b.output("y", (rows,), DType.F)
    b.width(32)
    ops.tree_reduce_dim0(b, xo, ro, n, rows, op=op)
    b.dims(rows, ld_strides={0: n})
    yo.store(ro.at(0, 0).load(ops.CR), SEQ)
    k = b.build()
    mem, _ = k.compile().run(k.pack())
    got = k.unpack(np.asarray(mem))["y"]
    if op == "add":
        np.testing.assert_array_equal(got, np.asarray(tree_sum_ref(x)))
    else:
        np.testing.assert_array_equal(got, npop(x, axis=1))


def test_tree_reduce_rejects_non_pow2():
    b = KernelBuilder("bad")
    xo = b.scratch("x", (4, 6), DType.F)
    b.width(32)
    with pytest.raises(ValueError):
        ops.tree_reduce_dim0(b, xo, xo, 6, 4)


# ---------------------------------------------------------------------------
# Block kernels: oracle contracts + register budget.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(BLOCK_KERNELS))
def test_block_oracle(name):
    """Default-shape build passes its jnp-oracle check and fits the
    8-register file at width 32."""
    run = BLOCK_KERNELS[name]()
    assert run.kernel.n_regs <= 8
    mem, state = run.kernel.compile().run(run.memory)
    run.check(np.asarray(mem), state)


def test_attention_error_within_documented_bound():
    run = BLOCK_KERNELS["attn_tile"]()
    mem, _ = run.kernel.compile().run(run.memory)
    assert run.error_of(np.asarray(mem)) < ATTN_RTOL


@pytest.mark.parametrize("name", sorted(BLOCK_KERNELS))
def test_block_every_target_and_opt_level(name):
    """Each block compiles and runs bit-identically on every registered
    target (including the ``*-timed`` twins) and at max opt level."""
    run = BLOCK_KERNELS[name]()
    base, _ = run.kernel.compile().run(run.memory)
    base = np.asarray(base)
    for tname in targets.list_targets():
        mem, _ = run.kernel.compile(target=tname).run(run.memory)
        np.testing.assert_array_equal(np.asarray(mem), base,
                                      err_msg=f"{name} on {tname}")
    mem, _ = run.kernel.compile(opt_level=opt.MAX_OPT_LEVEL).run(run.memory)
    np.testing.assert_array_equal(np.asarray(mem), base)


def test_blocks_through_scheduler():
    """Zoo kernels submit directly to the serving scheduler and come
    back oracle-correct (the serving_lm bench path)."""
    from repro.runtime.scheduler import MVEScheduler

    runs = [BLOCK_KERNELS[n](seed=7) for n in ("kv_gather", "moe_gather",
                                               "ssm_scan")]
    sched = MVEScheduler(CFG, promote_after=1)
    tickets = [sched.submit(r.kernel) for r in runs]
    sched.drain()
    for r, t in zip(runs, tickets):
        r.check(np.asarray(t.result().memory), t.result())


# ---------------------------------------------------------------------------
# Workload assembly + bench section.
# ---------------------------------------------------------------------------

def test_model_blocks_assembly():
    specs = model_blocks(quick=True)
    names = [s.name for s in specs]
    assert len(names) == len(set(names)) and len(specs) >= 6
    assert set(MULTIDIM_BLOCKS) <= {s.run.name for s in specs}
    for s in specs:
        assert s.tiles_per_layer >= 1.0
        mem, state = s.run.kernel.compile().run(s.run.memory)
        s.run.check(np.asarray(mem), state)
    # the multidim flag drives the bench's Fig-10-style assertion
    assert [s.name for s in specs if s.multidim] == list(MULTIDIM_BLOCKS)


def test_models_bench_quick_rows():
    from benchmarks.models_bench import models_bench

    rows = {name: derived for name, _, derived
            in models_bench(only_targets=("mve-bs", "rvv-1d"), quick=True)}
    summary = rows["models/summary"]
    assert "mve_ahead_on_multidim=True" in summary
    assert "models/attn_tile/mve-bs" in rows
    assert "models/block_mix_autotune" in rows
    # per-block oracle rows carry the exactness contract
    assert "exactness=bit" in rows["models/qkv_gemm/oracle"]
    assert "exactness=rtol" in rows["models/attn_tile/oracle"]


def test_autotune_programs_deterministic():
    from repro.silicon.autotune import Candidate, autotune_programs

    runs = [BLOCK_KERNELS[n]() for n in ("kv_gather", "ssm_scan")]
    mix = [(r.name, r.kernel, float(i + 1)) for i, r in enumerate(runs)]
    cands = [Candidate(scheme=s) for s in ("bs", "bp")]
    a = autotune_programs("mix", mix, candidates=cands)
    b = autotune_programs("mix", mix, candidates=cands)
    assert [p.label for p in a.points] == [p.label for p in b.points]
    assert a.best("energy_pj").energy_pj == b.best("energy_pj").energy_pj
    assert len(a.points) == 2 and len(a.front) >= 1


# ---------------------------------------------------------------------------
# Satellite: repro.kernels imports lazily (PEP 562).
# ---------------------------------------------------------------------------

def test_kernels_package_lazy_import():
    """Importing the package (or just ``ref``) must not drag in the
    Pallas TPU kernel modules."""
    code = (
        "import sys; import repro.kernels as kp; from repro.kernels "
        "import ref; assert 'repro.kernels.ref' in sys.modules; "
        "assert 'repro.kernels.ops' not in sys.modules; "
        "assert 'repro.kernels.mdgather' not in sys.modules; "
        "assert hasattr(ref, 'tree_sum_ref'); "
        "assert 'ops' in dir(kp)"
    )
    subprocess.run([sys.executable, "-c", code], check=True)
