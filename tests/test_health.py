"""Unit tests for the cluster-health runtime primitives
(:mod:`repro.runtime.health`): heartbeat death/revival, robust
straggler detection, elastic-remesh planning edge cases.  All clocked
deterministically — no sleeps."""
import numpy as np
import pytest

from repro.runtime.health import (HeartbeatMonitor, StragglerDetector,
                                  plan_elastic_remesh)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# -- HeartbeatMonitor --------------------------------------------------------

def test_heartbeat_all_healthy_within_timeout():
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10.0, clock=clk)
    clk.advance(9.0)
    assert mon.dead_hosts() == []
    assert mon.healthy()


def test_heartbeat_silence_marks_dead_and_beat_revives():
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10.0, clock=clk)
    clk.advance(5.0)
    mon.beat("h0")
    clk.advance(6.0)            # h1 silent for 11s, h0 for 6s
    assert mon.dead_hosts() == ["h1"]
    assert not mon.healthy()
    mon.beat("h1")              # restarted host reports again
    assert mon.dead_hosts() == []
    assert mon.healthy()


def test_heartbeat_unknown_host_beat_registers_it():
    clk = FakeClock()
    mon = HeartbeatMonitor([], timeout_s=10.0, clock=clk)
    mon.beat("late-joiner")
    assert mon.healthy()
    clk.advance(11.0)
    assert mon.dead_hosts() == ["late-joiner"]


# -- StragglerDetector -------------------------------------------------------

def _feed(det, times, steps=8):
    for _ in range(steps):
        for host, t in times.items():
            det.record(host, t)


def test_straggler_flagged_only_after_persistence():
    det = StragglerDetector(window=8, mad_threshold=4.0, persistence=3)
    _feed(det, {"h0": 1.0, "h1": 1.01, "h2": 0.99, "h3": 5.0})
    assert det.stragglers() == []       # 1st window: flagged once
    assert det.stragglers() == []       # 2nd
    assert det.stragglers() == ["h3"]   # persistence=3 reached


def test_straggler_flag_resets_on_recovery():
    det = StragglerDetector(window=4, mad_threshold=4.0, persistence=2)
    _feed(det, {"h0": 1.0, "h1": 1.01, "h2": 0.99, "h3": 5.0}, steps=4)
    assert det.stragglers() == []
    # h3 recovers before the persistence threshold: counter resets
    _feed(det, {"h0": 1.0, "h1": 1.01, "h2": 0.99, "h3": 1.0}, steps=4)
    assert det.stragglers() == []
    assert det.stragglers() == []


def test_straggler_needs_three_hosts_and_enough_samples():
    det = StragglerDetector(window=8, mad_threshold=4.0, persistence=1)
    _feed(det, {"h0": 1.0, "h1": 50.0})         # only two hosts
    assert det.stragglers() == []
    det2 = StragglerDetector(window=8, persistence=1)
    _feed(det2, {"h0": 1.0, "h1": 1.0, "h2": 50.0}, steps=2)
    assert det2.stragglers() == []              # < window//2 samples each


def test_straggler_robust_to_uniform_times():
    det = StragglerDetector(window=4, persistence=1)
    _feed(det, {f"h{i}": 1.0 for i in range(4)}, steps=4)
    assert det.stragglers() == []               # zero MAD, no outlier


# -- plan_elastic_remesh -----------------------------------------------------

def test_remesh_exact_fit_uses_every_chip():
    plan = plan_elastic_remesh(512, model_parallel=16, chips_per_pod=256)
    assert (plan.pods, plan.data, plan.model) == (2, 16, 16)
    assert plan.chips == 512
    assert plan.dropped_chips == 0


def test_remesh_zero_spare_single_mp_group():
    # Exactly one model-parallel group: data parallelism collapses to 1.
    plan = plan_elastic_remesh(16, model_parallel=16, chips_per_pod=256)
    assert (plan.pods, plan.data, plan.model) == (1, 1, 16)
    assert plan.dropped_chips == 0


def test_remesh_survivor_loss_shrinks_dp_keeps_tp():
    # 300 survivors of a 2x256 deployment: TP extent must be preserved,
    # DP shrinks to the largest power of two that fits.
    plan = plan_elastic_remesh(300, model_parallel=16, chips_per_pod=256)
    assert plan.model == 16
    assert plan.data & (plan.data - 1) == 0     # power of two
    assert plan.chips <= 300
    assert plan.dropped_chips == 300 - plan.chips


def test_remesh_not_enough_chips_raises():
    with pytest.raises(ValueError, match="model-parallel"):
        plan_elastic_remesh(15, model_parallel=16)
