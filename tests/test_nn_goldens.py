"""Frozen per-block cost rows for the repro.nn kernel zoo.

Freezes, for every default-shape zoo block, the emitted program size,
the register allocation, and the priced cycles/energy/instruction mix
on ``mve-bs`` and ``rvv-1d`` — the two ends of the Fig. 10 comparison.
A change to the frontend lowering, the optimizer default, a block
kernel, or either cost model shows up here as an exact diff instead of
an unexplained drift in the ``models`` bench section.

Regenerating after an *intentional* change:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_nn_goldens.py

Float fields round-trip exactly through JSON (shortest-repr), so
equality is exact, not approximate.
"""
import json
import os
import pathlib

import numpy as np
import pytest

from repro import targets
from repro.nn import BLOCK_KERNELS

GOLDEN = pathlib.Path(__file__).parent / "data" / "nn_goldens.json"
REGEN = bool(os.environ.get("REPRO_REGEN_GOLDEN"))
_TARGETS = ("mve-bs", "rvv-1d")


def _block_entry(name: str) -> dict:
    run = BLOCK_KERNELS[name]()
    entry = {
        "instrs": len(run.kernel.program),
        "n_regs": run.kernel.n_regs,
        "max_live": run.kernel.max_live,
        "exactness": run.exactness,
    }
    for tname in _TARGETS:
        art = targets.compile(run.kernel, target=tname)
        mem, state = art.run(run.memory)
        run.check(np.asarray(mem), state)
        tl = art.timeline(state)
        mix = art.instruction_mix()
        entry[tname] = {
            "cycles": tl.total_cycles,
            "energy_pj": art.energy(state).total_pj,
            "vector_instructions": mix.vector,
            "scalar_instructions": mix.scalar,
        }
    return entry


def _current() -> dict:
    return {"blocks": {n: _block_entry(n) for n in sorted(BLOCK_KERNELS)}}


@pytest.fixture(scope="module")
def golden():
    if REGEN:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_current(), indent=1, sort_keys=True))
    assert GOLDEN.exists(), \
        "golden file missing - regenerate with REPRO_REGEN_GOLDEN=1"
    return json.loads(GOLDEN.read_text())


@pytest.mark.parametrize("name", sorted(BLOCK_KERNELS))
def test_block_rows_frozen(golden, name):
    """Exact program size + per-target cycle/energy rows per block."""
    assert _block_entry(name) == golden["blocks"][name], \
        f"{name}: cost rows drifted"


def test_golden_covers_all_blocks(golden):
    assert sorted(golden["blocks"]) == sorted(BLOCK_KERNELS)
    for name, entry in golden["blocks"].items():
        assert entry["n_regs"] <= 8            # the width-32 register file
        # MVE must price fewer vector instructions than sliced RVV on
        # every block (the instruction-count story of Fig. 10)
        assert entry["mve-bs"]["vector_instructions"] < \
            entry["rvv-1d"]["vector_instructions"], name
