"""MoE dispatch: GShard grouped top-k vs per-token dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import materialize_tree
from repro.models.lm import _moe_defs
from repro.models.moe import moe_ffn

RNG = np.random.default_rng(2)


def _setup(k, e=8, capacity_factor=16.0):
    cfg = get_config("arctic-480b", reduced=True)
    cfg = dataclasses.replace(cfg, num_experts=e, experts_per_token=k,
                              capacity_factor=capacity_factor,
                              moe_dense_residual=False,
                              moe_group_size=32)
    defs = _moe_defs(cfg, 1)
    params = materialize_tree(defs, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda a: a[0].astype(jnp.float32), params)
    return cfg, params


def _oracle(params, x, cfg):
    """Per-token dense computation with the same top-k renormalized gates."""
    b, s, d = x.shape
    xt = np.asarray(x).reshape(-1, d)
    router = np.asarray(params["router"], np.float64)
    probs = jax.nn.softmax(jnp.asarray(xt @ router), axis=-1)
    probs = np.asarray(probs)
    out = np.zeros_like(xt)
    k = cfg.experts_per_token
    wi = np.asarray(params["wi"], np.float64)
    wg = np.asarray(params["wg"], np.float64)
    wo = np.asarray(params["wo"], np.float64)
    for t in range(xt.shape[0]):
        idx = np.argsort(-probs[t])[:k]
        gates = probs[t, idx]
        gates = gates / gates.sum()
        for e_i, gate in zip(idx, gates):
            h = xt[t] @ wi[e_i]
            h = h / (1 + np.exp(-h))            # silu
            h = h * (xt[t] @ wg[e_i])
            out[t] += gate * (h @ wo[e_i])
    return out.reshape(b, s, d)


@pytest.mark.parametrize("k", [1, 2])
def test_moe_matches_dense_oracle_lossless(k):
    cfg, params = _setup(k)
    x = jnp.asarray(RNG.standard_normal((2, 16, cfg.d_model))
                    .astype(np.float32)) * 0.5
    got, aux = moe_ffn(params, x, cfg)
    want = _oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    """With a tiny capacity factor some tokens are dropped (output 0)."""
    cfg, params = _setup(k=1, capacity_factor=0.10)
    x = jnp.asarray(RNG.standard_normal((1, 32, cfg.d_model))
                    .astype(np.float32))
    got, _ = moe_ffn(params, x, cfg)
    lossless = _oracle(params, x, cfg)
    norms_got = np.linalg.norm(np.asarray(got).reshape(32, -1), axis=1)
    dropped = (norms_got < 1e-6).sum()
    assert dropped > 0
    # kept tokens still match the oracle
    kept = norms_got > 1e-6
    np.testing.assert_allclose(np.asarray(got).reshape(32, -1)[kept],
                               lossless.reshape(32, -1)[kept],
                               rtol=2e-3, atol=2e-3)


def test_aux_loss_uniform_router_is_one():
    """Balanced routing gives aux = E * E*(1/E)*(1/E) = 1."""
    cfg, params = _setup(k=1)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])   # uniform probs
    x = jnp.asarray(RNG.standard_normal((1, 64, cfg.d_model))
                    .astype(np.float32))
    _, aux = moe_ffn(params, x, cfg)
    # frac concentrates on argmax=expert 0 with zero logits (ties) but
    # mean_prob is uniform -> aux = E * sum_e frac_e * (1/E) = 1
    assert abs(float(aux) - 1.0) < 1e-5


def test_shared_expert_added():
    cfg, params = _setup(k=1)
    cfg = dataclasses.replace(cfg, moe_shared_expert=True)
    defs = _moe_defs(cfg, 1)
    params2 = materialize_tree(defs, jax.random.PRNGKey(0))
    params2 = jax.tree.map(lambda a: a[0].astype(jnp.float32), params2)
    x = jnp.asarray(RNG.standard_normal((1, 8, cfg.d_model))
                    .astype(np.float32))
    with_shared, _ = moe_ffn(params2, x, cfg)
    params_no = {k: v for k, v in params2.items() if k != "shared"}
    cfg_no = dataclasses.replace(cfg, moe_shared_expert=False)
    without, _ = moe_ffn(params_no, x, cfg_no)
    assert not np.allclose(np.asarray(with_shared), np.asarray(without))
