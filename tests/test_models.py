"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, s=S):
    batch = {
        "tokens": jax.random.randint(KEY, (B, s), 1, cfg.vocab_size),
        "targets": jax.random.randint(KEY, (B, s), 1, cfg.vocab_size),
        "loss_mask": jnp.ones((B, s), jnp.float32),
        "positions": jnp.tile(jnp.arange(s), (B, 1)),
        "segment_ids": jnp.ones((B, s), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.num_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = LM(cfg)
    params = model.init_params(KEY)
    loss, metrics = jax.jit(model.loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == B * S
    # gradients finite too
    grads = jax.grad(lambda p: model.loss(p, _batch(cfg))[0])(params)
    gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in jax.tree.leaves(grads))))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_consistency(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.family == "moe":
        # lossless dispatch: capacity dropping legitimately differs
        # between prefill-sized and decode-sized routing groups
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = LM(cfg)
    params = model.init_params(KEY)
    cache_s = S + 8
    toks = jax.random.randint(KEY, (B, cache_s), 1, cfg.vocab_size)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        extras["frames"] = jax.random.normal(
            KEY, (B, cfg.num_frames, cfg.d_model), jnp.bfloat16)

    def prefill(n):
        batch = {"tokens": toks[:, :n],
                 "positions": jnp.tile(jnp.arange(n), (B, 1)), **extras}
        return model.prefill(params, batch)

    logits, cache = prefill(S)
    # pad attention caches to cache_s
    def pad(c):
        out = {}
        for k, v in c.items():
            if k in ("k", "v", "shared_k", "shared_v") and v.shape[2] == S:
                pad_w = [(0, 0)] * v.ndim
                pad_w[2] = (0, cache_s - S)
                out[k] = jnp.pad(v, pad_w)
            else:
                out[k] = v
        return out
    cache = pad(cache)

    for t in range(S, S + 2):
        dl, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        want, _ = prefill(t + 1)
        np.testing.assert_allclose(
            np.asarray(dl, np.float32), np.asarray(want, np.float32),
            rtol=4e-2, atol=4e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_matches_materialized(arch):
    """ModelConfig.param_count() (used for MODEL_FLOPS) must track the
    real parameter tree within 2%."""
    cfg = get_config(arch, reduced=True)
    model = LM(cfg)
    abstract = model.abstract_params()
    actual = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(abstract))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)


def test_full_configs_match_assignment():
    """The full configs carry the exact published dimensions."""
    checks = {
        "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=29568, vocab_size=152064),
        "qwen2-0.5b": dict(num_layers=24, d_model=896, num_heads=14,
                           num_kv_heads=2, d_ff=4864, vocab_size=151936),
        "nemotron-4-15b": dict(num_layers=32, d_model=6144, num_heads=48,
                               num_kv_heads=8, d_ff=24576,
                               vocab_size=256000,
                               activation="squared_relu"),
        "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120,
                                      num_heads=40, num_kv_heads=8,
                                      d_ff=8192, vocab_size=202048,
                                      num_experts=16, experts_per_token=1),
        "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                            num_kv_heads=8, d_ff=4864, vocab_size=32000,
                            num_experts=128, experts_per_token=2),
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, d_ff=0,
                            vocab_size=50280, ssm_state=128),
        "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64),
        "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096,
                                     num_heads=32, num_kv_heads=8,
                                     d_ff=14336, vocab_size=128256),
        "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                             num_kv_heads=8, d_ff=2048, vocab_size=51865,
                             encoder_layers=6),
    }
    for arch, expect in checks.items():
        cfg = get_config(arch)
        for k, v in expect.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_moe_active_params_smaller():
    cfg = get_config("arctic-480b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
    # arctic really is ~480B total
    assert 4.0e11 < cfg.param_count() < 5.6e11
    q = get_config("qwen2-72b")
    assert 6.8e10 < q.param_count() < 8.2e10
