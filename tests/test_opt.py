"""The optimizer pass pipeline (``repro.opt``): unit semantics of every
pass, the structural guard, differential bit-exactness of every pipeline
prefix against the stepwise oracle, cross-target exactness, and the
``tune()`` schedule sweep.

The contract under test (docs/OPTIMIZER.md): any program, any pipeline
prefix, any executor — memory, the full register file (masked lanes
included) and the Tag latch equal the oracle's on the *unoptimized*
program bit for bit; the optimized trace never invents memory or config
work; instruction count and register pressure never increase.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import opt, targets
from repro.core import isa
from repro.core.engine import compile_program
from repro.core.interp import MVEInterpreter
from repro.core.isa import DType, Op
from repro.core.machine import MVEConfig
from repro.core.patterns import PATTERNS
from repro.frontend.regalloc import max_pressure

CFG = MVEConfig()
ORACLE = MVEInterpreter(CFG, compiled=False)
F, DW = DType.F, DType.DW


# ---------------------------------------------------------------------------
# dead-config: unit semantics
# ---------------------------------------------------------------------------

def test_dead_config_drops_power_on_reestablishment():
    """width=32 and dimc=1 are the power-on values — writing them at
    program start is an architectural no-op."""
    prog = [isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1), isa.vsst(F, 0, 64, 1)]
    out = list(opt.dead_config(prog))
    assert out == [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1),
                   isa.vsst(F, 0, 64, 1)]


def test_dead_config_drops_reestablished_scope():
    """Re-writing the dimension config already in effect (the frontend's
    old dimension-scope re-entry pattern) is removed."""
    prog = [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1),
            isa.vsetdimc(1), isa.vsetdiml(0, 8),       # re-establishment
            isa.vsst(F, 0, 64, 1)]
    out = list(opt.dead_config(prog))
    assert out == [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1),
                   isa.vsst(F, 0, 64, 1)]


def test_dead_config_drops_overwritten_unobserved_write():
    prog = [isa.vsetdiml(0, 4), isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1)]
    assert list(opt.dead_config(prog)) == \
        [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1)]


def test_dead_config_keeps_observed_state():
    """Writes something later observes — including the final (tail)
    control state and mask bits a load's lane mask depends on — stay."""
    prog = [isa.vsetdiml(0, 8), isa.vunsetmask(3),
            isa.vsld(F, 0, 0, 1),                      # observes the mask
            isa.vsetmask(3), isa.vsst(F, 0, 64, 1)]
    assert list(opt.dead_config(prog)) == prog


def test_dead_config_fixpoint_cascades():
    """unset+set of one mask bit with no observer between collapses to
    nothing, which in turn kills the first diml write (the mask ops were
    its only observers) — the two rules iterate to a fixpoint."""
    prog = [isa.vsetdiml(0, 16), isa.vunsetmask(3), isa.vsetmask(3),
            isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1)]
    assert list(opt.dead_config(prog)) == \
        [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1)]


# ---------------------------------------------------------------------------
# cse: unit semantics
# ---------------------------------------------------------------------------

def test_cse_drops_exact_reload():
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1),
            isa.vsld(F, 0, 0, 1),                      # exact re-execution
            isa.vsst(F, 0, 64, 1)]
    assert list(opt.cse(prog)) == \
        [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1), isa.vsst(F, 0, 64, 1)]


def test_cse_rewrites_duplicate_load_to_move():
    """Same access, different destination: the second load becomes a
    vcpy — identical write-back lanes, one memory access fewer."""
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1), isa.vsld(F, 1, 0, 1),
            isa.vadd(F, 2, 0, 1), isa.vsst(F, 2, 64, 1)]
    out = list(opt.cse(prog))
    assert out[2] == isa.vcpy(F, 1, 0)
    assert sum(1 for i in out if i.op is Op.SLD) == 1
    assert len(out) == len(prog)                       # substitution, not drop


def test_cse_store_invalidates_available_loads():
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1),
            isa.vsst(F, 0, 0, 1),                      # clobbers the row
            isa.vsld(F, 1, 0, 1)]
    assert list(opt.cse(prog)) == prog


def test_cse_config_change_blocks_reuse():
    """The full control-state digest is part of the expression key: a
    reconfigured load resolves different addresses/lanes and must stay."""
    prog = [isa.vsetdiml(0, 8), isa.vsld(F, 0, 0, 1),
            isa.vsetdiml(0, 4), isa.vsld(F, 1, 0, 1),
            isa.vsst(F, 1, 64, 1)]
    assert list(opt.cse(prog)) == prog


def test_cse_folds_duplicate_splats_but_not_predicated():
    prog = [isa.vsetdiml(0, 8), isa.vsetdup(DW, 0, 5), isa.vsetdup(DW, 1, 5)]
    assert list(opt.cse(prog))[-1] == isa.vcpy(DW, 1, 0)
    pred = isa.Instr(Op.SET_DUP, dtype=DW, vd=1, imm=5, predicated=True)
    out = list(opt.cse([isa.vsetdiml(0, 8), isa.vsetdup(DW, 0, 5), pred]))
    assert out[-1] == pred                 # Tag-dependent write-back: kept


def test_cse_register_clobber_invalidates_expression():
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1),
            isa.vsetdup(F, 0, 7),                      # clobbers v0
            isa.vsld(F, 1, 0, 1)]                      # not available anymore
    assert list(opt.cse(prog)) == prog


# ---------------------------------------------------------------------------
# schedule: unit semantics
# ---------------------------------------------------------------------------

def test_schedule_hoists_independent_loads():
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1),
            isa.vadd(F, 2, 0, 0),
            isa.vsld(F, 1, 64, 1),                     # independent load
            isa.vadd(F, 3, 1, 2),
            isa.vsst(F, 3, 128, 1)]
    out = list(opt.schedule(prog, priority="loads-first"))
    assert sorted(map(repr, out)) == sorted(map(repr, prog))  # a permutation
    assert out.index(isa.vsld(F, 1, 64, 1)) < out.index(isa.vadd(F, 2, 0, 0))


def test_schedule_respects_memory_dependences():
    """A load from a stored-to interval must not move above the store."""
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(F, 0, 0, 1),
            isa.vsst(F, 0, 64, 1),
            isa.vsld(F, 1, 64, 1),                     # reads the stored row
            isa.vsst(F, 1, 128, 1)]
    out = list(opt.schedule(prog, priority="loads-first"))
    assert out.index(isa.vsst(F, 0, 64, 1)) < out.index(isa.vsld(F, 1, 64, 1))


def test_schedule_respects_tag_dependences():
    prog = [isa.vsetdiml(0, 8),
            isa.vsld(DW, 0, 0, 1),
            isa.vcmp(Op.GT, DW, 0, 0),
            isa.vadd(DW, 1, 0, 0, predicated=True),
            isa.vsld(DW, 2, 64, 1),
            isa.vsst(DW, 1, 128, 1)]
    out = list(opt.schedule(prog, priority="loads-first"))
    assert out.index(isa.vcmp(Op.GT, DW, 0, 0)) < \
        out.index(isa.vadd(DW, 1, 0, 0, predicated=True))
    # the independent load still hoisted above the compare
    assert out.index(isa.vsld(DW, 2, 64, 1)) < \
        out.index(isa.vcmp(Op.GT, DW, 0, 0))


def test_schedule_source_priority_is_identity():
    prog = isa.Program(PATTERNS["daxpy"]().program)
    assert list(opt.schedule(prog, priority="source")) == list(prog)


def test_schedule_rejects_unknown_priority():
    with pytest.raises(ValueError, match="unknown schedule priority"):
        opt.schedule([], priority="bogus")


# ---------------------------------------------------------------------------
# Pipeline: levels, audit trail, the structural guard
# ---------------------------------------------------------------------------

def test_opt_level_resolution():
    prog = isa.Program(PATTERNS["daxpy"]().program)
    assert list(opt.optimize(prog)) == list(prog)              # None = identity
    assert list(opt.optimize(prog, level=0)) == list(prog)
    assert list(opt.optimize(prog, level=99)) == \
        list(opt.optimize(prog, level=opt.MAX_OPT_LEVEL))      # clamped
    with pytest.raises(isa.ProgramError, match="unknown optimizer pass"):
        opt.optimize(prog, passes=("nope",))
    prefixes = opt.pipeline_prefixes()
    assert prefixes[0] == () and prefixes[-1] == opt.DEFAULT_PIPELINE
    assert len(prefixes) == opt.MAX_OPT_LEVEL + 1
    assert opt.OPT_LEVELS[opt.MAX_OPT_LEVEL] == opt.DEFAULT_PIPELINE


def test_optimize_result_audit_trail():
    res = opt.optimize_result(PATTERNS["spmm"]().program, level=True)
    assert tuple(r.name for r in res.reports) == opt.DEFAULT_PIPELINE
    assert res.removed == len(res.source) - len(res.program) > 0
    assert not any(r.reverted for r in res.reports)
    assert res.reports[0].removed > 0              # dead-config fires on spmm
    for r in res.reports:
        assert r.instructions_out <= r.instructions_in
        assert r.pressure_out <= r.pressure_in


def test_pipeline_guard_reverts_contract_breaking_pass(monkeypatch):
    """A pass whose output is longer or fails validation degrades to a
    no-op (reported as ``reverted``) instead of a miscompile."""
    run = PATTERNS["daxpy"]()

    def longer(program):
        return list(program) + [isa.vsetwidth(64)]

    def invalid(program):
        return [isa.Instr(Op.ADD, dtype=F, vd=0, vs1=0)]   # missing vs2

    monkeypatch.setitem(opt.PASSES, "longer", longer)
    monkeypatch.setitem(opt.PASSES, "invalid", invalid)
    try:
        for name in ("longer", "invalid"):
            opt.cache_clear()
            res = opt.optimize_result(run.program, passes=(name,))
            assert res.reports[0].reverted, name
            assert list(res.program) == list(res.source), name
    finally:
        opt.cache_clear()          # drop entries keyed on the fake passes


def test_pipeline_idempotent_on_pattern_library():
    for name in sorted(PATTERNS):
        once = opt.optimize(PATTERNS[name]().program, level=True)
        assert list(opt.optimize(once, level=True)) == list(once), name


def test_optimizer_reduces_sweep_instruction_count():
    """Acceptance: across the full Section-IV pattern sweep the pipeline
    strictly reduces total instruction count and never regresses any
    single pattern (counts per pattern are frozen in
    tests/data/opt_goldens.json)."""
    total_in = total_out = 0
    for name in sorted(PATTERNS):
        res = opt.optimize_result(PATTERNS[name]().program, level=True)
        assert len(res.program) <= len(res.source), name
        total_in += len(res.source)
        total_out += len(res.program)
    assert total_out < total_in


# ---------------------------------------------------------------------------
# Differential verification: prefixes x executors x targets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name",
                         ["daxpy", "reduction", "spmm", "upsample",
                          "transpose"])
def test_pipeline_prefixes_bit_exact_on_patterns(name):
    """Every pipeline prefix reproduces the stepwise oracle of the
    unoptimized program bit for bit (memory, registers, Tag) and keeps
    sub-multiset trace semantics, on the VM executor."""
    run = PATTERNS[name]()
    opt.verify_prefixes(run.program, run.memory, cfg=CFG, modes=("vm",))


def test_full_pipeline_bit_exact_on_fused_executor():
    run = PATTERNS["daxpy"]()
    opt.verify_optimized(run.program, run.memory, level=opt.MAX_OPT_LEVEL,
                         cfg=CFG, modes=("vm", "fused"))


def test_prefixes_across_all_registered_targets():
    """Bit-exact vs the oracle on all six targets at every prefix — the
    acceptance bar of this PR."""
    run = PATTERNS["upsample"]()
    assert len(targets.list_targets()) >= 6
    for prefix in opt.pipeline_prefixes():
        opt.verify_across_targets(run.program, run.memory, passes=prefix)


def test_opt_level_threads_through_compile_surfaces():
    """engine.compile_program / targets.compile / Kernel.compile all run
    the same pipeline and agree on the optimized text."""
    run = PATTERNS["reduction"]()
    base = compile_program(run.program, CFG)
    lvl = compile_program(run.program, CFG, opt_level=opt.MAX_OPT_LEVEL)
    assert len(lvl.program) < len(base.program)
    mem_b, _ = base.run(run.memory)
    mem_o, _ = lvl.run(run.memory)
    np.testing.assert_array_equal(np.asarray(mem_b), np.asarray(mem_o))

    art = targets.compile(run.program, target="mve-bs", opt_level=True)
    assert list(art.program) == list(lvl.program)

    k = run.kernel
    cp = k.compile(opt_level=opt.MAX_OPT_LEVEL)
    assert len(cp.program) <= len(k.program)


# ---------------------------------------------------------------------------
# tune(): the per-target schedule sweep
# ---------------------------------------------------------------------------

def test_tune_picks_cheapest_schedule_and_stays_exact():
    run = PATTERNS["daxpy"]()
    res = opt.tune(run.program, target="mve-bs")
    assert res.target == "mve-bs"
    assert set(res.table) == set(opt.SCHEDULE_PRIORITIES)
    assert res.best in res.table and res.cycles == min(res.table.values())
    # the tuned artifact still executes bit-exactly vs the oracle
    mem_i, st_i = ORACLE.run_stepwise(run.program, run.memory)
    _, st_t = res.artifact.run(run.memory)
    opt.assert_states_equal(st_i, mem_i, st_t)
    # a target with a different cost structure sweeps the same table
    res2 = opt.tune(run.program, target="rvv-1d",
                    priorities=("source", "loads-first"))
    assert res2.target == "rvv-1d" and set(res2.table) == \
        {"source", "loads-first"}


# ---------------------------------------------------------------------------
# Hypothesis properties (run in CI where hypothesis is installed)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10 ** 9), st.integers(0, 3))
def test_property_pipeline_monotone_and_valid(seed, n_passes):
    """Any prefix over any random program: never longer, never more
    register pressure, still validates."""
    from test_conformance import _random_program_ex
    prog, _ = _random_program_ex(seed, variants=1)
    base = isa.Program(prog)
    out = opt.optimize(base, passes=opt.DEFAULT_PIPELINE[:n_passes])
    assert len(out) <= len(base)
    assert max_pressure(list(out)) <= max_pressure(list(base))
    isa.validate(out)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_property_each_pass_idempotent(seed):
    from test_conformance import _random_program_ex
    prog, _ = _random_program_ex(seed, variants=1)
    base = isa.Program(prog)
    for name, fn in opt.PASSES.items():
        once = fn(base)
        assert list(fn(once)) == list(once), name


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 9), st.integers(0, 3))
def test_property_strict_validation_preserved(seed, n_passes):
    """Strictly-valid frontend kernels stay strictly valid under every
    pipeline prefix (config trajectory preservation)."""
    from test_conformance import _random_frontend_kernel
    k = _random_frontend_kernel(seed)
    size = len(k.pack())
    isa.validate(k.program, memory_size=size, strict=True)
    out = opt.optimize(k.program, passes=opt.DEFAULT_PIPELINE[:n_passes])
    isa.validate(out, memory_size=size, strict=True)
