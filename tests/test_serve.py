"""Continuous-batching engine: dimension-level masked serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.models import LM

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b", reduced=True)
    cfg = dataclasses.replace(cfg, num_layers=1)
    model = LM(cfg)
    params = model.init_params(KEY)
    return cfg, model, params


def _reference_decode(cfg, params, prompt, n_new):
    """Single-request oracle: a fresh engine with ONE slot — the invariant
    under test is that *batching with other requests never changes a
    request's output* (slot/cache isolation via dimension-level masks).
    (Greedy argmax is not stable between prefill- and decode-path bf16
    numerics, so a prefill-based oracle would be flaky by construction.)"""
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    done = eng.run_until_drained()
    return done[0].output


def test_all_requests_complete_and_match_reference(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=ln).astype(np.int32)
               for ln in (3, 5, 4, 6, 3)]
    engine = ContinuousBatchingEngine(cfg, params, batch_slots=2,
                                      max_seq=32)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = engine.run_until_drained()
    assert sorted(done) == list(range(5))
    for i, p in enumerate(prompts):
        want = _reference_decode(cfg, params, p, 4)
        assert done[i].output == want, (i, done[i].output, want)


def test_dimension_level_masking_occupancy(small_model):
    cfg, model, params = small_model
    engine = ContinuousBatchingEngine(cfg, params, batch_slots=4,
                                      max_seq=16)
    assert engine.occupancy == 0.0
    engine.submit(Request(rid=0, prompt=np.asarray([5, 6], np.int32),
                          max_new_tokens=2))
    engine.step()
    assert engine.occupancy == pytest.approx(0.25)
    # the grid mask is the MVE-style per-request (top-dim) mask
    assert engine.grid.mask.sum() == 1
    engine.run_until_drained()
    assert engine.occupancy == 0.0


def test_queueing_beyond_slots(small_model):
    cfg, model, params = small_model
    engine = ContinuousBatchingEngine(cfg, params, batch_slots=2,
                                      max_seq=16)
    for i in range(4):
        engine.submit(Request(rid=i, prompt=np.asarray([2 + i], np.int32),
                              max_new_tokens=2))
    engine.step()
    assert len(engine.grid.active_slots()) == 2   # only 2 resident
    assert len(engine._queue) == 2
    done = engine.run_until_drained()
    assert len(done) == 4
