"""Program-as-data VM vs the stepwise oracle and the fused engine.

The equivalence contract (docs/ENGINE.md): for every program the VM must
produce bit-identical memory, registers, Tag latch, and an identical
cost-model trace — through one signature-keyed XLA executable shared by
every program of that signature.  Includes a seeded random-program
equivalence suite (always runs) and a hypothesis property test (runs when
hypothesis is installed; otherwise skips via the compat shim).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (MVEConfig, MVEInterpreter, cache_info,
                        compile_program, isa)
from repro.core import vm as vm_mod
from repro.core.isa import DType, Op
from repro.core.machine import OOB_BASE, store_layout
from repro.core.patterns import PATTERNS, run_pattern_batch

CFG = MVEConfig()
ORACLE = MVEInterpreter(CFG, compiled=False)


def _assert_state_equal(st_i, st_e):
    assert set(st_i.regs) == set(st_e.regs)
    for r in st_i.regs:
        np.testing.assert_array_equal(np.asarray(st_i.regs[r]),
                                      np.asarray(st_e.regs[r]))
    np.testing.assert_array_equal(np.asarray(st_i.tag),
                                  np.asarray(st_e.tag))
    assert len(st_i.trace) == len(st_e.trace)
    for i, (a, b) in enumerate(zip(st_i.trace, st_e.trace)):
        assert a.same_as(b), (i, a, b)


def _assert_all_executors_match(program, memory):
    """Stepwise oracle == VM == fused, bit for bit (memory/regs/tag/trace)."""
    mem_i, st_i = ORACLE.run_stepwise(program, memory)
    out = None
    for mode in ("vm", "fused"):
        cp = compile_program(program, CFG, mode=mode)
        assert cp.mode == mode
        mem_e, st_e = cp.run(memory)
        np.testing.assert_array_equal(np.asarray(mem_i), np.asarray(mem_e))
        _assert_state_equal(st_i, st_e)
        out = (mem_e, st_e)
    return out


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_vm_matches_interpreter_and_fused(name):
    """Bit-identical memory/regs/tag/trace on every Section-IV pattern."""
    run = PATTERNS[name]()
    mem_e, st_e = _assert_all_executors_match(run.program, run.memory)
    run.check(np.asarray(mem_e), st_e)


def test_one_executable_for_the_whole_sweep():
    """Every pattern maps to the same signature: the full sweep costs at
    most 2 distinct XLA compilations (acceptance bound; measured 1)."""
    before = cache_info()
    sigs = set()
    for name in sorted(PATTERNS):
        run = PATTERNS[name]()
        cp = compile_program(run.program, CFG, mode="vm")
        cp.run(run.memory)
        sigs.add(cp._vm._signature(run.memory.shape[0]))
    after = cache_info()
    assert len(sigs) == 1
    assert after.vm_xla_compiles - before.vm_xla_compiles <= 2


def test_spmm_variants_share_one_compilation():
    """Data-dependent program streams (one spmm program per sparsity
    pattern) replay through the cached executable — the tentpole claim."""
    base = PATTERNS["spmm"]()
    compile_program(base.program, CFG, mode="vm").run(base.memory)
    before = cache_info().vm_xla_compiles
    # densities chosen so every variant's memory image stays inside the
    # same memory-size bucket (a bigger image is a legitimately new
    # signature)
    for seed, density in ((3, 0.1), (4, 0.3), (5, 0.4)):
        run = PATTERNS["spmm"](seed=seed, density=density)
        assert run.program != base.program          # genuinely new programs
        cp = compile_program(run.program, CFG, mode="vm")
        mem, state = cp.run(run.memory)
        run.check(np.asarray(mem), state)
    assert cache_info().vm_xla_compiles == before   # zero new XLA work


def test_vm_predication_and_tag():
    mem = np.zeros(16)
    mem[:8] = np.arange(8)
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(DType.DW, 1, 0, 1),
            isa.vsetdup(DType.DW, 0, 3),
            isa.vcmp(Op.GT, DType.DW, 1, 0),
            isa.vsetdup(DType.DW, 2, 1),
            isa.vadd(DType.DW, 1, 1, 2, predicated=True)]
    _assert_all_executors_match(prog, mem)


def test_vm_predicated_load_ignores_tag():
    """The eager executors honor the Tag latch only on compute write-backs;
    a load marked ``predicated`` still writes under the lane mask alone.
    Regression test: the VM lowering must not route Tag into load keeps."""
    mem = np.zeros(32)
    mem[:8] = np.arange(8)
    mem[8:16] = np.arange(100, 108)
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(DType.DW, 0, 0, 1),
            isa.vsetdup(DType.DW, 1, 3),
            isa.vcmp(Op.GT, DType.DW, 0, 1),        # tag = lane > 3
            isa.Instr(Op.SLD, dtype=DType.DW, vd=0, base=8, modes=(1,),
                      predicated=True)]
    _, st_e = _assert_all_executors_match(prog, mem)
    np.testing.assert_array_equal(np.asarray(st_e.regs[0])[:8],
                                  np.arange(100, 108))


def test_vm_float_to_narrow_int_saturates():
    """Out-of-range float->narrow-int casts saturate in the eager
    executors (direct XLA converts); the VM's clamp-then-convert must
    match bit for bit.  Regression test for the via-int32 wrap bug."""
    mem = np.zeros(32)
    mem[:4] = [-1.5, 70000.0, 300.0, 42.0]
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 4),
            isa.vsld(DType.F, 0, 0, 1),
            isa.vcvt(DType.B, 1, 0),     # -1.5 -> 0, 300 -> 255
            isa.vcvt(DType.W, 2, 0),     # 70000 -> 32767
            isa.vsld(DType.B, 3, 0, 1),  # loads saturate too
            isa.vsld(DType.W, 4, 0, 1)]
    _, st_e = _assert_all_executors_match(prog, mem)
    np.testing.assert_array_equal(np.asarray(st_e.regs[1])[:4],
                                  [0, 255, 255, 42])
    np.testing.assert_array_equal(np.asarray(st_e.regs[2])[:4],
                                  [-1, 32767, 300, 42])


def test_vm_masked_store_blend():
    """Dimension-masked contiguous stores run through the blend path."""
    mem = np.zeros(64)
    mem[:32] = np.arange(32)
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 8), isa.vsetdiml(1, 4),
            isa.vsld(DType.F, 0, 0, 1, 2),
            isa.vunsetmask(1), isa.vunsetmask(3),
            isa.vsst(DType.F, 0, 32, 1, 2)]
    mem_e, _ = _assert_all_executors_match(prog, mem)
    got = np.asarray(mem_e)
    np.testing.assert_array_equal(got[40:48], 0)
    np.testing.assert_array_equal(got[48:56], np.arange(16, 24))


def test_vm_noncontiguous_store_scatter():
    """A strided (stride-2) store exercises the sorted-unique scatter."""
    mem = np.zeros(128)
    mem[:16] = np.arange(16) + 1
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 16),
            isa.vsetststr(0, 2),
            isa.vsld(DType.F, 0, 0, 1),
            isa.vsst(DType.F, 0, 64, 3)]
    mem_e, _ = _assert_all_executors_match(prog, mem)
    got = np.asarray(mem_e)
    np.testing.assert_array_equal(got[64:96:2], np.arange(16) + 1)
    np.testing.assert_array_equal(got[65:96:2], 0)


def test_vm_colliding_store_last_lane_wins():
    """Stride-0 store dimension: every lane of the replicated dim collides
    on one address; the last lane must win in every executor."""
    mem = np.zeros(64)
    mem[:12] = np.arange(12)
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 4), isa.vsetdiml(1, 3),
            isa.vsld(DType.F, 0, 0, 1, 2),
            isa.vsst(DType.F, 0, 32, 1, 0)]   # S1=0: rows collide
    mem_e, _ = _assert_all_executors_match(prog, mem)
    np.testing.assert_array_equal(np.asarray(mem_e)[32:36],
                                  np.arange(8, 12))


def test_vm_nonfloat_memory_routes_to_fused():
    """The VM datapath is float32-canonical; an int32 memory image must
    keep exact integer semantics by routing through the fused function."""
    mem = np.zeros(64, dtype=np.int32)
    mem[:8] = (1 << 24) + 1          # not representable in float32
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(DType.DW, 0, 0, 1),
            isa.vsst(DType.DW, 0, 16, 1)]
    mem_i, st_i = ORACLE.run_stepwise(prog, mem)
    cp = compile_program(prog, CFG, mode="vm")
    assert cp.mode == "vm"           # float images still use the VM
    mem_e, st_e = cp.run(mem)
    assert np.asarray(mem_e).dtype == np.int32
    np.testing.assert_array_equal(np.asarray(mem_i), np.asarray(mem_e))
    np.testing.assert_array_equal(np.asarray(mem_e)[16:24], (1 << 24) + 1)
    _assert_state_equal(st_i, st_e)


def test_vm_fallback_too_many_registers():
    """Programs beyond the fixed register file fall back to fused mode."""
    mem = np.zeros(32)
    mem[:8] = np.arange(8)
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8)]
    for r in range(vm_mod.N_REGS + 2):
        prog.append(isa.vsetdup(DType.DW, r, r))
    before = cache_info().vm_fallbacks
    cp = compile_program(prog, CFG, mode="vm")
    assert cp.mode == "fused"
    assert cache_info().vm_fallbacks == before + 1
    mem_i, st_i = ORACLE.run_stepwise(prog, mem)
    mem_e, st_e = cp.run(mem)
    np.testing.assert_array_equal(np.asarray(mem_i), np.asarray(mem_e))
    _assert_state_equal(st_i, st_e)


def test_warmup_removes_compile_cliff():
    """warmup() AOT-compiles; the next run adds no XLA compilation."""
    run = PATTERNS["daxpy"]()
    for mode in ("vm", "fused"):
        cp = compile_program(run.program, CFG, mode=mode)
        cp.warmup(run.memory.shape[0])
        jit = (vm_mod._executor(cp._vm._signature(run.memory.shape[0]))
               .single if mode == "vm" else cp._jit)
        assert jit._aot, "warmup must stash an AOT executable"
        compiles = jit.compiles
        mem, state = cp.run(run.memory)
        run.check(np.asarray(mem), state)
        assert jit.compiles == compiles


def test_vm_batch_matches_per_image_runs():
    seeds = [0, 1, 2, 3]
    runs, mems = run_pattern_batch("daxpy", seeds, CFG, mode="vm")
    mems = np.asarray(mems)
    assert mems.shape[0] == len(seeds)
    for r, got in zip(runs, mems):
        mem_i, _ = ORACLE.run_stepwise(r.program, r.memory)
        np.testing.assert_array_equal(np.asarray(mem_i), got)
        r.check(got, None)


def test_store_layout_classification():
    lanes = 16
    lane = np.arange(lanes, dtype=np.int64)
    mask = np.ones(lanes, dtype=bool)
    assert store_layout(lane + 7, mask) == ("contig", 7)
    assert store_layout(lane, np.zeros(lanes, dtype=bool)) == ("none",)
    kind, idx, perm = store_layout(lane * 2, mask)
    assert kind == "scatter"
    assert (np.diff(idx) > 0).all()             # sorted and unique
    live = idx < OOB_BASE
    np.testing.assert_array_equal(idx[live], lane * 2)


def test_store_layout_last_lane_wins():
    """Colliding addresses keep only the highest active lane in bounds."""
    addr = np.array([5, 5, 9, 5, 9, 3], dtype=np.int64)
    mask = np.array([True, True, True, True, False, True])
    kind, idx, perm = store_layout(addr, mask)
    assert kind == "scatter"
    live = idx < OOB_BASE
    winners = {int(a): int(p) for a, p in zip(idx[live], perm[live])}
    assert winners == {3: 5, 5: 3, 9: 2}        # lane 3 beats lanes 0/1


# ---------------------------------------------------------------------------
# Random-program equivalence: VM == fused == interpreter.
# ---------------------------------------------------------------------------
#
# The generator stays inside the semantics every executor defines
# identically (documented in docs/ENGINE.md "VM lowering"): narrow integer
# binops draw from integer-stored registers (any width — wrapping
# matches); float-stored registers are read back via F/HF/DW ops and via
# vcvt to any dtype (float->narrow-int saturates identically everywhere).

_MEM = 4096
_IN, _OUT = 0, 3072       # input values live in [0, 1024); stores >= _OUT
_INT_DT = [DType.B, DType.W, DType.DW, DType.QW]


def _random_program(seed):
    rng = np.random.default_rng(seed)
    mem = np.zeros(_MEM)
    mem[:1024] = rng.integers(0, 100, size=1024)
    prog = [isa.vsetwidth(32)]
    stored = {}                      # reg -> "int" | "float"
    lens = []

    def set_dims():
        nonlocal lens
        nd = int(rng.integers(1, 3))
        lens = [int(rng.integers(2, 17)) for _ in range(nd)]
        prog.append(isa.vsetdimc(nd))
        for d, ln in enumerate(lens):
            prog.append(isa.vsetdiml(d, ln))

    def total():
        return int(np.prod(lens))

    def int_reg(width_ok_b=True):
        cands = [r for r, k in stored.items() if k == "int"]
        return int(rng.choice(cands)) if cands else None

    def any_reg():
        return int(rng.choice(list(stored))) if stored else None

    set_dims()
    for _ in range(int(rng.integers(10, 30))):
        c = int(rng.integers(0, 12))
        rd = int(rng.integers(0, 7))
        if c == 0:
            set_dims()
        elif c == 1:                                # dimension mask toggle
            top = lens[-1]
            idx = int(rng.integers(0, min(top, 256)))
            prog.append(isa.vunsetmask(idx) if rng.random() < 0.5
                        else isa.vsetmask(idx))
        elif c == 2:                                # load
            dt = _INT_DT[int(rng.integers(0, 4))] if rng.random() < 0.6 \
                else (DType.F if rng.random() < 0.7 else DType.HF)
            hi = 1024 if dt in (DType.B, DType.W) else _MEM
            base = int(rng.integers(0, max(hi - total(), 1)))
            prog.append(isa.vsld(dt, rd, base, *([1] + [2] * (len(lens) - 1))))
            stored[rd] = "float" if dt.is_float else "int"
        elif c == 3:                                # store
            src = any_reg()
            if src is None:
                continue
            dt = DType.F if stored[src] == "float" else DType.DW
            if rng.random() < 0.3:                  # strided -> scatter path
                prog.append(isa.vsetststr(0, 2))
                base = int(rng.integers(_OUT, _MEM - 2 * total()))
                prog.append(isa.vsst(dt, src, base,
                                     *([3] + [2] * (len(lens) - 1))))
            else:
                base = int(rng.integers(_OUT, _MEM - total()))
                prog.append(isa.vsst(dt, src, base,
                                     *([1] + [2] * (len(lens) - 1))))
        elif c == 4:                                # setdup
            if rng.random() < 0.5:
                prog.append(isa.vsetdup(DType.DW, rd,
                                        int(rng.integers(-50, 50))))
                stored[rd] = "int"
            else:
                prog.append(isa.vsetdup(
                    DType.F, rd, float(np.round(rng.normal(), 3))))
                stored[rd] = "float"
        elif c == 5:                                # narrow int binop
            a, b = int_reg(), int_reg()
            if a is None or b is None:
                continue
            dt = _INT_DT[int(rng.integers(0, 4))]
            op = [isa.vadd, isa.vsub, isa.vmul, isa.vmin, isa.vmax,
                  isa.vxor, isa.vand, isa.vor][int(rng.integers(0, 8))]
            prog.append(op(dt, rd, a, b))
            stored[rd] = "int"
        elif c == 6:                                # 32-bit op, any sources
            a, b = any_reg(), any_reg()
            if a is None or b is None:
                continue
            dt = DType.DW if rng.random() < 0.5 else DType.F
            op = [isa.vadd, isa.vsub, isa.vmul, isa.vmin,
                  isa.vmax][int(rng.integers(0, 5))]
            prog.append(op(dt, rd, a, b,
                           predicated=bool(rng.random() < 0.25)))
            stored[rd] = "float" if dt.is_float else "int"
        elif c == 7:                                # compare (writes Tag)
            a, b = any_reg(), any_reg()
            if a is None or b is None:
                continue
            dt = DType.F if (stored[a] == "float" or stored[b] == "float") \
                else DType.DW
            cmp = [Op.GT, Op.GTE, Op.LT, Op.LTE, Op.EQ,
                   Op.NEQ][int(rng.integers(0, 6))]
            prog.append(isa.vcmp(cmp, dt, a, b))
        elif c == 8:                                # shift immediate
            a = int_reg()
            if a is None:
                continue
            dt = _INT_DT[int(rng.integers(0, 4))]
            prog.append(isa.vshi(dt, rd, a, int(rng.integers(-3, 4))))
            stored[rd] = "int"
        elif c == 9:                                # rotate
            a = int_reg()
            if a is None:
                continue
            dt = _INT_DT[int(rng.integers(0, 3))]   # B/W/DW
            prog.append(isa.Instr(Op.ROTI, dtype=dt, vd=rd, vs1=a,
                                  imm=int(rng.integers(1, dt.bits))))
            stored[rd] = "int"
        elif c == 10:                               # shift by register
            a = int_reg()
            if a is None:
                continue
            prog.append(isa.vsetdup(DType.DW, 7, int(rng.integers(0, 8))))
            stored[7] = "int"
            prog.append(isa.vshr_reg(DType.DW, rd, a, 7))
            stored[rd] = "int"
        else:                                       # cvt / cpy
            a = any_reg()
            if a is None:
                continue
            # any source kind -> any dtype: float->narrow-int saturates
            # identically in every executor (clamped converts)
            dt = [DType.F, DType.HF, DType.DW, DType.W,
                  DType.B][int(rng.integers(0, 5))]
            prog.append(isa.vcvt(dt, rd, a))
            stored[rd] = "float" if dt.is_float else "int"
    # make every program end with an observable store
    src = any_reg()
    if src is not None:
        dt = DType.F if stored[src] == "float" else DType.DW
        prog.append(isa.vsst(dt, src, _OUT, *([1] + [2] * (len(lens) - 1))))
    return prog, mem


@pytest.mark.parametrize("seed", range(12))
def test_random_program_equivalence(seed):
    """Seeded random programs: stepwise == VM == fused, bit for bit."""
    prog, mem = _random_program(seed)
    _assert_all_executors_match(prog, mem)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**9))
def test_random_program_equivalence_property(seed):
    """Hypothesis-driven version of the seeded suite (CI installs
    hypothesis; locally the shim skips when it is missing)."""
    prog, mem = _random_program(seed)
    _assert_all_executors_match(prog, mem)
