"""The original hand-coded Section-IV pattern programs (PR 1-3 era).

Frozen copies of the legacy ``core/patterns.py`` program builders —
flat ``isa.Instr`` lists with manually-assigned register numbers,
hand-sequenced config ops and raw byte offsets.  They are the
*equivalence references* for the kernel frontend: ``tests/test_frontend``
asserts that every frontend-built pattern is bit-identical (memory, regs
modulo the register renaming, Tag, TraceEvents) to these on all three
executors.  Do not modernize this file; its value is that it does not
change.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.core import isa
from repro.core.isa import DType
from repro.core.machine import MVEConfig
from repro.core.patterns import NeonWork, PatternRun

LANES = MVEConfig().lanes  # 8192


def _mem(size: int) -> np.ndarray:
    return np.zeros(size, dtype=np.float64)


# ---------------------------------------------------------------------------
# 1. Linpack: daxpy (1D)                        y[i] += alpha * x[i]
# ---------------------------------------------------------------------------

def daxpy(n: int = LANES, seed: int = 0) -> PatternRun:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    alpha = np.float32(1.5)
    mem = _mem(2 * n)
    mem[:n] = x
    mem[n:2 * n] = y
    expected = y + alpha * x

    p: List[isa.Instr] = [
        isa.vsetwidth(32),
        isa.vsetdimc(1), isa.vsetdiml(0, n),
        isa.scalar(4),
        isa.vsld(DType.F, 0, 0, 1),            # x
        isa.vsld(DType.F, 1, n, 1),            # y
        isa.vsetdup(DType.F, 2, 1.5),
        isa.vmul(DType.F, 3, 0, 2),
        isa.vadd(DType.F, 1, 1, 3),
        isa.vsst(DType.F, 1, n, 1),
    ]

    def check(mem_after, state):
        np.testing.assert_allclose(mem_after[n:2 * n], expected, rtol=1e-5)

    return PatternRun("daxpy", "Linpack", "1D", p, mem, check,
                      NeonWork(vector_ops=2, elements=n, bits=32,
                               mem_bytes=3 * 4 * n),
                      flops=2 * n, copy_bytes=8 * n)


# ---------------------------------------------------------------------------
# 2. XNNPACK: row-wise GEMM with multi-dimensional replication (Section IV)
# ---------------------------------------------------------------------------

def gemm(n_rows: int = 128, k: int = 16, m: int = 64, seed: int = 1,
         lanes: int = LANES, dtype: DType = DType.F) -> PatternRun:
    """C[N,M] = A[N,K] @ B[K,M] with input/weight replication (2D).

    ``dtype=DType.W`` gives the quantized-CNN (int16) variant used for
    the Figure 9 GPU-crossover sweep."""
    rng = np.random.default_rng(seed)
    if dtype is DType.W:
        a = rng.integers(-8, 8, (n_rows, k)).astype(np.float32)
        b = rng.integers(-8, 8, (k, m)).astype(np.float32)
    else:
        a = rng.standard_normal((n_rows, k)).astype(np.float32)
        b = rng.standard_normal((k, m)).astype(np.float32)
    rows_per_iter = min(lanes // m, n_rows, 256)
    a_base, b_base, c_base = 0, n_rows * k, n_rows * k + k * m
    mem = _mem(c_base + n_rows * m)
    mem[a_base:b_base] = a.ravel()
    mem[b_base:c_base] = b.ravel()
    expected = (a @ b).astype(np.float32)

    p: List[isa.Instr] = [
        isa.vsetwidth(dtype.bits),
        isa.vsetdimc(2),
        isa.vsetdiml(0, m), isa.vsetdiml(1, rows_per_iter),
        isa.vsetldstr(1, k),       # input column stride
        isa.vsetststr(1, m),       # output row stride
    ]
    for n0 in range(0, n_rows, rows_per_iter):
        p.append(isa.scalar(6))                       # loop + addressing
        p.append(isa.vsetdup(dtype, 2, 0))            # acc = 0
        for kk in range(k):
            p.append(isa.scalar(4))
            # input column A[n0:n0+R, kk] replicated horizontally (S0=0)
            p.append(isa.vsld(dtype, 0, a_base + n0 * k + kk, 0, 3))
            # weight row B[kk, :] replicated vertically (S1=0)
            p.append(isa.vsld(dtype, 1, b_base + kk * m, 1, 0))
            p.append(isa.vmul(dtype, 3, 0, 1))
            p.append(isa.vadd(dtype, 2, 2, 3))
        # store R output rows sequentially (S0=1, S1=M via mode 2)
        p.append(isa.vsst(dtype, 2, c_base + n0 * m, 1, 2))

    def check(mem_after, state):
        got = mem_after[c_base:c_base + n_rows * m].reshape(n_rows, m)
        np.testing.assert_allclose(got, expected, rtol=1e-4, atol=1e-4)

    flops = 2.0 * n_rows * k * m
    return PatternRun("gemm", "XNNPACK", "2D", p, mem, check,
                      NeonWork(vector_ops=2 * k, elements=n_rows * m, bits=32,
                               mem_bytes=4.0 * (n_rows * k + k * m +
                                                n_rows * m)),
                      flops=flops,
                      copy_bytes=4.0 * (n_rows * k + k * m + n_rows * m))


# ---------------------------------------------------------------------------
# 3. XNNPACK: SpMM — CSR sparse inputs, random weight-row loads (Section IV)
# ---------------------------------------------------------------------------

def spmm(rows: int = 64, cols: int = 64, m: int = 64, density: float = 0.25,
         seed: int = 2, lanes: int = LANES) -> PatternRun:
    """out[r,:] = sum_nz A[r,c] * W[c,:] using random-base loads."""
    rng = np.random.default_rng(seed)
    a = (rng.random((rows, cols)) < density) * \
        rng.standard_normal((rows, cols))
    a = a.astype(np.float32)
    w = rng.standard_normal((cols, m)).astype(np.float32)
    expected = (a @ w).astype(np.float32)

    nnz_r, nnz_c = np.nonzero(a)
    nnz_v = a[nnz_r, nnz_c]
    w_base = 0
    v_base = w_base + cols * m
    ptr_base = v_base + len(nnz_v)
    out_base = ptr_base + len(nnz_v)
    mem = _mem(out_base + len(nnz_v) * m)   # one partial product row per nnz
    mem[w_base:v_base] = w.ravel()
    mem[v_base:ptr_base] = nnz_v
    # "Core computes the weight row addresses corresponding to non-zero
    # input cells" — the pointer array the random load walks.
    mem[ptr_base:out_base] = w_base + nnz_c * m

    group = min(lanes // m, 256)
    p: List[isa.Instr] = [isa.vsetwidth(32)]
    lane_rows: List[int] = []
    i = 0
    while i < len(nnz_v):
        g = min(group, len(nnz_v) - i)
        p += [isa.scalar(8),
              isa.vsetdimc(2), isa.vsetdiml(0, m), isa.vsetdiml(1, g)]
        # nnz values replicated horizontally from a strided load (S0=0,S1=1)
        p.append(isa.vsld(DType.F, 0, v_base + i, 0, 1))
        # weight rows from random base pointers, sequential inner dim
        p.append(isa.vrld(DType.F, 1, ptr_base + i, 1))
        p.append(isa.vmul(DType.F, 2, 0, 1))
        # store partial products; combined on the scalar core per-row
        p.append(isa.vsst(DType.F, 2, out_base + i * m, 1, 2))
        p.append(isa.scalar(2 * g))
        i += g

    def check(mem_after, state):
        partial = mem_after[out_base:out_base + len(nnz_v) * m]
        got = np.zeros((rows, m), dtype=np.float32)
        for j, r in enumerate(nnz_r):
            got[r] += partial[j * m:(j + 1) * m].astype(np.float32)
        np.testing.assert_allclose(got, expected, rtol=1e-4, atol=1e-4)

    flops = 2.0 * len(nnz_v) * m
    return PatternRun("spmm", "XNNPACK", "2D", p, mem, check,
                      NeonWork(vector_ops=2 * density * cols,
                               elements=rows * m, bits=32,
                               mem_bytes=4.0 * (len(nnz_v) * (m + 2) +
                                                rows * m)),
                      flops=flops,
                      copy_bytes=4.0 * (cols * m + 2 * len(nnz_v)))


# ---------------------------------------------------------------------------
# 4. CMSIS-DSP: FIR filter (1D, multiple shifted loads)
# ---------------------------------------------------------------------------

def fir(n: int = LANES, taps: int = 16, seed: int = 3) -> PatternRun:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n + taps).astype(np.float32)
    h = rng.standard_normal(taps).astype(np.float32)
    mem = _mem(2 * (n + taps))
    mem[:n + taps] = x
    out_base = n + taps
    expected = np.stack([x[t:t + n] for t in range(taps)], 0).T @ h

    p: List[isa.Instr] = [
        isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, n),
        isa.vsetdup(DType.F, 2, 0.0),
    ]
    for t in range(taps):
        p += [isa.scalar(3),
              isa.vsld(DType.F, 0, t, 1),
              isa.vsetdup(DType.F, 1, float(h[t])),
              isa.vmul(DType.F, 3, 0, 1),
              isa.vadd(DType.F, 2, 2, 3)]
    p.append(isa.vsst(DType.F, 2, out_base, 1))

    def check(mem_after, state):
        np.testing.assert_allclose(mem_after[out_base:out_base + n],
                                   expected, rtol=1e-4, atol=1e-4)

    return PatternRun("fir", "CMSIS-DSP", "1D", p, mem, check,
                      NeonWork(vector_ops=2 * taps, elements=n, bits=32,
                               mem_bytes=4.0 * (taps * n / 4 + 2 * n)),
                      flops=2.0 * taps * n, copy_bytes=8.0 * n)


# ---------------------------------------------------------------------------
# 5. Kvazaar: intra-picture prediction (3D strided load, Figure 3)
# ---------------------------------------------------------------------------

def intra_pred(blocks: int = 256, seed: int = 4) -> PatternRun:
    """3D load with S=(1,0,3): each 3-pel reference row is replicated down
    a 3x3 predicted block (Figure 3), then averaged with a second ref."""
    bs = 3
    refs = np.random.default_rng(seed).integers(
        0, 255, size=(blocks, bs)).astype(np.int32)
    refs2 = np.random.default_rng(seed + 1).integers(
        0, 255, size=(blocks, bs)).astype(np.int32)
    r1_base, r2_base = 0, blocks * bs
    out_base = 2 * blocks * bs
    mem = _mem(out_base + blocks * bs * bs)
    mem[r1_base:r2_base] = refs.ravel()
    mem[r2_base:out_base] = refs2.ravel()
    # predicted[b, y, x] = (ref1[b, x] + ref2[b, y]) >> 1  (planar-ish)
    expected = (refs[:, None, :] + refs2[:, :, None]) >> 1

    p: List[isa.Instr] = [
        isa.vsetwidth(32),
        isa.vsetdimc(3),
        isa.vsetdiml(0, bs), isa.vsetdiml(1, bs), isa.vsetdiml(2, blocks),
        isa.vsetldstr(2, bs),
        isa.scalar(6),
        # ref row replicated down the column dim: S = (1, 0, 3)
        isa.vsld(DType.W, 0, r1_base, 1, 0, 3),
        # ref col replicated across the row dim: S = (0, 1, 3)
        isa.vsld(DType.W, 1, r2_base, 0, 1, 3),
        isa.vadd(DType.W, 2, 0, 1),
        isa.vshi(DType.W, 2, 2, -1),
        isa.vsst(DType.W, 2, out_base, 1, 2, 2),
    ]

    def check(mem_after, state):
        got = mem_after[out_base:out_base + blocks * bs * bs].reshape(
            blocks, bs, bs).astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    n = blocks * bs * bs
    return PatternRun("intra_pred", "Kvazaar", "3D", p, mem, check,
                      NeonWork(vector_ops=3, elements=n, bits=16,
                               mem_bytes=4.0 * (2 * blocks * bs + n)),
                      flops=2.0 * n, copy_bytes=4.0 * n)


# ---------------------------------------------------------------------------
# 6. libjpeg: h2v2 upsample (random base + replication, Figure 4)
# ---------------------------------------------------------------------------

def upsample(rows: int = 32, m: int = 128, seed: int = 5) -> PatternRun:
    """Each pixel replicated 2x horizontally; vertical replication via
    duplicated row pointers (the paper's 4th random dimension)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 255, size=(rows, m)).astype(np.int32)
    # rows live at "random" (shuffled) locations, like libjpeg row pointers
    row_order = rng.permutation(rows)
    in_base = 0
    mem_rows = np.zeros(rows * m)
    row_addr = np.zeros(rows, dtype=np.int64)
    for slot, r in enumerate(row_order):
        mem_rows[slot * m:(slot + 1) * m] = img[r]
        row_addr[r] = in_base + slot * m
    in_ptr_base = rows * m
    out_ptr_base = in_ptr_base + 2 * rows
    out_base = out_ptr_base + 2 * rows
    mem = _mem(out_base + 2 * rows * 2 * m)
    mem[:rows * m] = mem_rows
    # input pointer per *output* row (each input row appears twice)
    in_ptrs = np.repeat(row_addr, 2)
    out_ptrs = out_base + np.arange(2 * rows) * (2 * m)
    mem[in_ptr_base:in_ptr_base + 2 * rows] = in_ptrs
    mem[out_ptr_base:out_ptr_base + 2 * rows] = out_ptrs
    expected = np.repeat(np.repeat(img, 2, axis=0), 2, axis=1)

    group = max(1, min(LANES // (2 * m), 2 * rows, 256))
    p: List[isa.Instr] = [isa.vsetwidth(32)]
    for n0 in range(0, 2 * rows, group):
        g = min(group, 2 * rows - n0)
        p += [isa.scalar(6),
              isa.vsetdimc(3),
              isa.vsetdiml(0, 2), isa.vsetdiml(1, m), isa.vsetdiml(2, g),
              # load: replicate 2x (S0=0), pixels sequential (S1=1),
              # random row base from the pointer array
              isa.vrld(DType.B, 0, in_ptr_base + n0, 0, 1),
              # store: sequential (S0=1), row-major (S1=2 -> derived 2),
              # random output row base
              isa.vrst(DType.B, 0, out_ptr_base + n0, 1, 2)]

    def check(mem_after, state):
        got = mem_after[out_base:out_base + 2 * rows * 2 * m].reshape(
            2 * rows, 2 * m).astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    n = rows * m
    return PatternRun("upsample", "libjpeg", "4D", p, mem, check,
                      NeonWork(vector_ops=3, elements=4 * n, bits=8,
                               mem_bytes=5.0 * n),
                      flops=4.0 * n, copy_bytes=5.0 * n)


# ---------------------------------------------------------------------------
# 7. libpng: "up" defilter — rows at random pointers (2D random)
# ---------------------------------------------------------------------------

def png_up(rows: int = 64, width: int = 128, seed: int = 6) -> PatternRun:
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    prior = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    raw_base, prior_base = 0, rows * width
    rp_base = 2 * rows * width
    pp_base = rp_base + rows
    out_base = pp_base + rows
    mem = _mem(out_base + rows * width)
    mem[raw_base:prior_base] = raw.ravel()
    mem[prior_base:rp_base] = prior.ravel()
    mem[rp_base:rp_base + rows] = raw_base + np.arange(rows) * width
    mem[pp_base:pp_base + rows] = prior_base + np.arange(rows) * width
    expected = (raw + prior) & 0xFF

    group = max(1, min(LANES // width, rows, 256))
    p: List[isa.Instr] = [isa.vsetwidth(32)]
    for r0 in range(0, rows, group):
        g = min(group, rows - r0)
        p += [isa.scalar(5),
              isa.vsetdimc(2), isa.vsetdiml(0, width), isa.vsetdiml(1, g),
              isa.vrld(DType.B, 0, rp_base + r0, 1),
              isa.vrld(DType.B, 1, pp_base + r0, 1),
              isa.vadd(DType.B, 2, 0, 1),        # uint8 wrap == & 0xFF
              isa.vsst(DType.B, 2, out_base + r0 * width, 1, 2)]

    def check(mem_after, state):
        got = mem_after[out_base:out_base + rows * width].reshape(
            rows, width).astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    n = rows * width
    return PatternRun("png_up", "libpng", "2D", p, mem, check,
                      NeonWork(vector_ops=3, elements=n, bits=8,
                               mem_bytes=3.0 * n),
                      flops=float(n), copy_bytes=3.0 * n)


# ---------------------------------------------------------------------------
# 8. libwebp: RGB -> gray (strided channel loads)
# ---------------------------------------------------------------------------

def rgb2gray(pixels: int = LANES, seed: int = 7) -> PatternRun:
    rng = np.random.default_rng(seed)
    rgb = rng.integers(0, 255, size=(pixels, 3)).astype(np.int32)
    in_base, out_base = 0, 3 * pixels
    mem = _mem(out_base + pixels)
    mem[:3 * pixels] = rgb.ravel()
    expected = (5 * rgb[:, 0] + 9 * rgb[:, 1] + 2 * rgb[:, 2]) >> 4

    p: List[isa.Instr] = [
        isa.vsetwidth(16), isa.vsetdimc(1), isa.vsetdiml(0, pixels),
        isa.vsetldstr(0, 3),
        isa.scalar(4),
        isa.vsld(DType.W, 0, in_base + 0, 3),     # R, stride 3
        isa.vsld(DType.W, 1, in_base + 1, 3),     # G
        isa.vsld(DType.W, 2, in_base + 2, 3),     # B
        isa.vsetdup(DType.W, 3, 5), isa.vmul(DType.W, 0, 0, 3),
        isa.vsetdup(DType.W, 3, 9), isa.vmul(DType.W, 1, 1, 3),
        isa.vsetdup(DType.W, 3, 2), isa.vmul(DType.W, 2, 2, 3),
        isa.vadd(DType.W, 0, 0, 1),
        isa.vadd(DType.W, 0, 0, 2),
        isa.vshi(DType.W, 0, 0, -4),
        isa.vsst(DType.W, 0, out_base, 1),
    ]

    def check(mem_after, state):
        got = mem_after[out_base:out_base + pixels].astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    return PatternRun("rgb2gray", "libwebp", "1D", p, mem, check,
                      NeonWork(vector_ops=10, elements=pixels, bits=16,
                               mem_bytes=4.0 * pixels),
                      flops=6.0 * pixels, copy_bytes=4.0 * pixels)


# ---------------------------------------------------------------------------
# 9. Skia: alpha blend (8-bit pixels, 2D rows)
# ---------------------------------------------------------------------------

def alpha_blend(rows: int = 64, width: int = 128, seed: int = 8
                ) -> PatternRun:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    dst = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    alpha = 6                        # 4-bit alpha: 6/16 src + 10/16 dst
    s_base, d_base = 0, rows * width
    mem = _mem(2 * rows * width)
    mem[s_base:d_base] = src.ravel()
    mem[d_base:] = dst.ravel()
    expected = (src * alpha + dst * (16 - alpha)) >> 4

    n = rows * width
    p: List[isa.Instr] = [
        isa.vsetwidth(32),
        isa.vsetdimc(2), isa.vsetdiml(0, width), isa.vsetdiml(1, rows),
        isa.scalar(4),
        isa.vsld(DType.W, 0, s_base, 1, 2),
        isa.vsld(DType.W, 1, d_base, 1, 2),
        isa.vsetdup(DType.W, 2, alpha),
        isa.vmul(DType.W, 0, 0, 2),
        isa.vsetdup(DType.W, 2, 16 - alpha),
        isa.vmul(DType.W, 1, 1, 2),
        isa.vadd(DType.W, 0, 0, 1),
        isa.vshi(DType.W, 0, 0, -4),
        isa.vsst(DType.W, 0, d_base, 1, 2),
    ]

    def check(mem_after, state):
        got = mem_after[d_base:d_base + n].reshape(rows, width)
        np.testing.assert_array_equal(got.astype(np.int64), expected)

    return PatternRun("alpha_blend", "Skia", "2D", p, mem, check,
                      NeonWork(vector_ops=8, elements=n, bits=8,
                               mem_bytes=3.0 * n),
                      flops=4.0 * n, copy_bytes=3.0 * n)


# ---------------------------------------------------------------------------
# 10. webaudio: multi-channel chunk mixing (3D)
# ---------------------------------------------------------------------------

def audio_mix(chunks: int = 16, channels: int = 4, samples: int = 128,
              seed: int = 9) -> PatternRun:
    """Processes multiple 128-sample chunks at once — the paper's flagship
    example of limited 1D DLP (Section I: webaudio exposes only 128)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((chunks, channels, samples)).astype(np.float32)
    b = rng.standard_normal((chunks, channels, samples)).astype(np.float32)
    gain = np.float32(0.7)
    n = chunks * channels * samples
    mem = _mem(3 * n)
    mem[:n] = a.ravel()
    mem[n:2 * n] = b.ravel()
    expected = (a + b) * gain

    p: List[isa.Instr] = [
        isa.vsetwidth(32),
        isa.vsetdimc(3),
        isa.vsetdiml(0, samples), isa.vsetdiml(1, channels),
        isa.vsetdiml(2, chunks),
        isa.scalar(5),
        isa.vsld(DType.F, 0, 0, 1, 2, 2),
        isa.vsld(DType.F, 1, n, 1, 2, 2),
        isa.vadd(DType.F, 0, 0, 1),
        isa.vsetdup(DType.F, 2, 0.7),
        isa.vmul(DType.F, 0, 0, 2),
        isa.vsst(DType.F, 0, 2 * n, 1, 2, 2),
    ]

    def check(mem_after, state):
        got = mem_after[2 * n:3 * n].reshape(chunks, channels, samples)
        np.testing.assert_allclose(got, expected, rtol=1e-5)

    return PatternRun("audio_mix", "webaudio", "3D", p, mem, check,
                      NeonWork(vector_ops=2, elements=n, bits=32,
                               mem_bytes=12.0 * n),
                      flops=2.0 * n, copy_bytes=12.0 * n)


# ---------------------------------------------------------------------------
# 11. zlib: adler32-style reduction (dimension-level masked tree, Section IV)
# ---------------------------------------------------------------------------

def reduction(n: int = LANES, seed: int = 10, floor: int = 256
              ) -> PatternRun:
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 255, size=n).astype(np.int64)
    in_base = 0
    tmp_base = n
    out_base = n + n // 2
    mem = _mem(out_base + floor)
    mem[:n] = x
    expected_sum = int(x.sum())

    p: List[isa.Instr] = [
        isa.vsetwidth(32),
        isa.vsetdimc(1), isa.vsetdiml(0, n),
        isa.scalar(3),
        isa.vsld(DType.DW, 0, in_base, 1),
    ]
    m = n
    while m > floor:
        half = m // 2
        p += [
            isa.scalar(4),
            # Split M lanes into 2 halves along a fresh highest dim and
            # mask off the first one (Section IV reduction snippet).
            isa.vsetdimc(2), isa.vsetdiml(0, half), isa.vsetdiml(1, 2),
            isa.vunsetmask(0),
            isa.vsst(DType.DW, 0, tmp_base - half, 1, 2),
            isa.vsetmask(0),
            isa.vsetdimc(1), isa.vsetdiml(0, half),
            isa.vsld(DType.DW, 1, tmp_base, 1),
            isa.vadd(DType.DW, 0, 0, 1),
        ]
        m = half
    p += [isa.vsetdimc(1), isa.vsetdiml(0, floor),
          isa.vsst(DType.DW, 0, out_base, 1),
          isa.scalar(floor)]          # final scalar-core reduction

    def check(mem_after, state):
        got = int(mem_after[out_base:out_base + floor].sum())
        assert got == expected_sum, (got, expected_sum)

    return PatternRun("reduction", "zlib", "1D", p, mem, check,
                      NeonWork(vector_ops=2, elements=n, bits=32,
                               mem_bytes=4.0 * n),
                      flops=float(n), copy_bytes=4.0 * n)


# ---------------------------------------------------------------------------
# 12. boringssl: XOR stream cipher with key replication (2D)
# ---------------------------------------------------------------------------

def xor_cipher(blocks: int = 256, key_len: int = 32, seed: int = 11
               ) -> PatternRun:
    rng = np.random.default_rng(seed)
    pt = rng.integers(0, 255, size=(blocks, key_len)).astype(np.int64)
    key = rng.integers(0, 255, size=key_len).astype(np.int64)
    n = blocks * key_len
    p_base, k_base, c_base = 0, n, n + key_len
    mem = _mem(c_base + n)
    mem[p_base:n] = pt.ravel()
    mem[k_base:k_base + key_len] = key
    expected = pt ^ key[None, :]

    p: List[isa.Instr] = [
        isa.vsetwidth(8),
        isa.vsetdimc(2), isa.vsetdiml(0, key_len), isa.vsetdiml(1, blocks),
        isa.scalar(4),
        isa.vsld(DType.B, 0, p_base, 1, 2),
        isa.vsld(DType.B, 1, k_base, 1, 0),       # key replicated (S1=0)
        isa.vxor(DType.B, 2, 0, 1),
        isa.vsst(DType.B, 2, c_base, 1, 2),
    ]

    def check(mem_after, state):
        got = mem_after[c_base:c_base + n].reshape(blocks, key_len)
        np.testing.assert_array_equal(
            got.astype(np.int64) & 0xFF, expected)

    return PatternRun("xor_cipher", "boringssl", "2D", p, mem, check,
                      NeonWork(vector_ops=1, elements=n, bits=8,
                               mem_bytes=2.0 * n),
                      flops=float(n), copy_bytes=2.0 * n)


# ---------------------------------------------------------------------------
# 13. Arm optimized routines: memcpy (1D bytes)
# ---------------------------------------------------------------------------

def memcpy(n: int = LANES, seed: int = 12) -> PatternRun:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 255, size=n).astype(np.int64)
    mem = _mem(2 * n)
    mem[:n] = src

    p: List[isa.Instr] = [
        isa.vsetwidth(8), isa.vsetdimc(1), isa.vsetdiml(0, n),
        isa.scalar(2),
        isa.vsld(DType.B, 0, 0, 1),
        isa.vsst(DType.B, 0, n, 1),
    ]

    def check(mem_after, state):
        np.testing.assert_array_equal(
            mem_after[n:2 * n].astype(np.int64) & 0xFF, src)

    return PatternRun("memcpy", "ArmRoutines", "1D", p, mem, check,
                      NeonWork(vector_ops=0.5, elements=n, bits=8,
                               mem_bytes=2.0 * n),
                      flops=0.0, copy_bytes=2.0 * n)


# ---------------------------------------------------------------------------
# 14. Matrix transpose (Section IV; XNNPACK 512x49 MobileNet-V1 case)
# ---------------------------------------------------------------------------

def transpose(m: int = 512, n: int = 49, seed: int = 13) -> PatternRun:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n)).astype(np.float32)
    in_base, out_base = 0, m * n
    mem = _mem(2 * m * n)
    mem[:m * n] = a.ravel()
    expected = a.T.copy()

    cols_per_iter = max(1, min(LANES // m, 256))
    p: List[isa.Instr] = [
        isa.vsetwidth(32),
        isa.vsetdimc(2), isa.vsetdiml(0, m), isa.vsetdiml(1, cols_per_iter),
        isa.vsetldstr(0, n), isa.vsetststr(1, m),
    ]
    for i in range(0, n, cols_per_iter):
        c = min(cols_per_iter, n - i)
        if c != cols_per_iter:
            p.append(isa.vsetdiml(1, c))
        p += [isa.scalar(4),
              # load c columns: element (y,x) <- input[x, i+y]
              isa.vsld(DType.F, 0, in_base + i, 3, 1),
              # store c rows of output: element (y,x) -> output[i+y, x]
              isa.vsst(DType.F, 0, out_base + i * m, 1, 3)]

    def check(mem_after, state):
        got = mem_after[out_base:out_base + n * m].reshape(n, m)
        np.testing.assert_allclose(got, expected, rtol=1e-6)

    return PatternRun("transpose", "XNNPACK", "2D", p, mem, check,
                      NeonWork(vector_ops=1.5, elements=m * n, bits=32,
                               mem_bytes=8.0 * m * n),
                      flops=0.0, copy_bytes=8.0 * m * n)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

LEGACY_PATTERNS: Dict[str, Callable[..., PatternRun]] = {
    "daxpy": daxpy,
    "gemm": gemm,
    "spmm": spmm,
    "fir": fir,
    "intra_pred": intra_pred,
    "upsample": upsample,
    "png_up": png_up,
    "rgb2gray": rgb2gray,
    "alpha_blend": alpha_blend,
    "audio_mix": audio_mix,
    "reduction": reduction,
    "xor_cipher": xor_cipher,
    "memcpy": memcpy,
    "transpose": transpose,
}
