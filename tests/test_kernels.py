"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.bitplane_gemm import bitplane_matmul, int8_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mdgather import mdgather
from repro.kernels.ops import mdv_gather, quantized_matmul

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# mdgather
# ---------------------------------------------------------------------------

@st.composite
def gather_case(draw):
    ndim = draw(st.integers(1, 4))
    dims = tuple(draw(st.integers(1, 6)) for _ in range(ndim))
    strides = tuple(draw(st.sampled_from([0, 1, 2, 3, 7]))
                    for _ in range(ndim))
    base = draw(st.integers(0, 8))
    return dims, strides, base


@settings(max_examples=20, deadline=None)
@given(gather_case())
def test_mdgather_matches_ref(case):
    dims, strides, base = case
    span = base + sum((l - 1) * s for l, s in zip(dims, strides)) + 1
    src = jnp.asarray(RNG.standard_normal(span + 8).astype(np.float32))
    got = mdgather(src, dims, strides, base)
    want = ref.mdgather_ref(src, dims, strides, base)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_mdgather_dtypes(dtype):
    src = jnp.arange(4096).astype(dtype)
    dims, strides = (4, 8, 16), (1, 0, 5)
    got = mdgather(src, dims, strides, 3)
    want = ref.mdgather_ref(src, dims, strides, 3)
    np.testing.assert_array_equal(np.asarray(got, np.float64),
                                  np.asarray(want, np.float64))


def test_mdgather_large_lane_count():
    """Exercises multiple (8,128) grid tiles."""
    src = jnp.asarray(RNG.standard_normal(1 << 15).astype(np.float32))
    dims, strides = (128, 64), (1, 128)          # 8192 lanes
    got = mdv_gather(src, dims, strides, 0, force_pallas=True)
    want = ref.mdgather_ref(src, dims, strides, 0)
    np.testing.assert_allclose(got, want)


# ---------------------------------------------------------------------------
# bitplane / int8 GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 128, 128),
                                   (100, 60, 200), (130, 96, 257)])
def test_int8_matmul_exact(m, k, n):
    x = jnp.asarray(RNG.integers(-128, 128, (m, k)).astype(np.int8))
    w = jnp.asarray(RNG.integers(-128, 128, (k, n)).astype(np.int8))
    want = ref.int8_matmul_ref(x, w)
    np.testing.assert_array_equal(int8_matmul(x, w), want)
    np.testing.assert_array_equal(bitplane_matmul(x, w), want)
    np.testing.assert_array_equal(ref.bitplane_matmul_ref(x, w), want)


def test_bitplane_nbits4():
    """4-bit weights use 4 planes; values in [-8, 7]."""
    x = jnp.asarray(RNG.integers(-128, 128, (32, 32)).astype(np.int8))
    w4 = RNG.integers(-8, 8, (32, 32)).astype(np.int8)
    got = bitplane_matmul(x, jnp.asarray(w4), nbits=4)
    want = ref.int8_matmul_ref(x, jnp.asarray(w4))
    np.testing.assert_array_equal(got, want)


def test_quantized_matmul_close_to_float():
    x = jnp.asarray(RNG.standard_normal((64, 96)).astype(np.float32))
    w = RNG.standard_normal((96, 32)).astype(np.float32)
    wq, ws = ref.quantize_rowwise_ref(jnp.asarray(w.T))
    got = quantized_matmul(x, wq.T, ws[:, 0], force_pallas=True)
    want = x @ w
    rel = np.abs(np.asarray(got) - np.asarray(want)) / \
        (np.abs(np.asarray(want)) + 1.0)
    assert rel.mean() < 0.02


def test_quantize_roundtrip_bound():
    x = jnp.asarray(RNG.standard_normal((16, 256)).astype(np.float32))
    q, s = ref.quantize_rowwise_ref(x)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,causal,d", [
    (128, 128, True, 64), (1, 128, True, 64), (77, 200, True, 64),
    (64, 64, False, 128), (128, 128, True, 128), (33, 95, False, 64),
])
def test_flash_attention_sweep(sq, sk, causal, d):
    q = jnp.asarray(RNG.standard_normal((2, 3, sq, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, 3, sk, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, 3, sk, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 96, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 96, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 96, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.06, atol=0.06)


def test_flash_matches_chunked_model_path():
    """The model's jnp chunked attention and the Pallas kernel agree."""
    from repro.models.attention import chunked_attention
    q = jnp.asarray(RNG.standard_normal((2, 64, 8, 64)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, 64, 2, 64)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, 64, 2, 64)).astype(np.float32))
    jnp_path = chunked_attention(q, k, v, causal=True, chunk=16)
    pallas_path = chunked_attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(jnp_path, pallas_path, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mdscatter
# ---------------------------------------------------------------------------

from repro.kernels.mdscatter import mdscatter


@settings(max_examples=15, deadline=None)
@given(gather_case())
def test_mdscatter_matches_ref(case):
    dims, strides, base = case
    span = base + sum((l - 1) * s for l, s in zip(dims, strides)) + 1
    total = int(np.prod(dims))
    dst = jnp.asarray(RNG.standard_normal(span + 8).astype(np.float32))
    vals = jnp.asarray(RNG.standard_normal(total).astype(np.float32))
    got = mdscatter(dst, vals, dims, strides, base)
    want = ref.mdscatter_ref(dst, vals, dims, strides, base)
    np.testing.assert_allclose(got, want)


def test_mdscatter_collision_last_lane_wins():
    """Stride-0 output dims collide; the highest lane's value lands."""
    dst = jnp.zeros(8, jnp.float32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    got = mdscatter(dst, vals, dims=(3, 2), strides=(1, 0), base=2)
    want = ref.mdscatter_ref(dst, vals, (3, 2), (1, 0), 2)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(np.asarray(got[2:5]), [4.0, 5.0, 6.0])


def test_mdscatter_roundtrip_with_gather():
    """scatter(gather(x)) over the same bijective layout = identity;
    storing with the transposed strides performs the transpose (the
    Section IV pattern)."""
    src = jnp.asarray(RNG.standard_normal(64).astype(np.float32))
    dims = (8, 8)
    vals = mdgather(src, dims, (8, 1), 0)     # read columns
    same = mdscatter(jnp.zeros_like(src), vals, dims, (8, 1), 0)
    np.testing.assert_allclose(same, src)
    trans = mdscatter(jnp.zeros_like(src), vals, dims, (1, 8), 0)
    np.testing.assert_allclose(
        np.asarray(trans).reshape(8, 8),
        np.asarray(src).reshape(8, 8).T)
