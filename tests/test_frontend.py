"""Kernel frontend: legacy equivalence, regalloc, packing, validation.

The contract of :mod:`repro.frontend` is that abstraction costs nothing
semantically: every Section-IV pattern expressed through the tracing
builder must be *bit-identical* — memory, registers (modulo the
allocator's register renaming), Tag latch, and TraceEvents — to the
original hand-coded instruction list (``tests/legacy_patterns.py``) on
all three executors, and the frontend-built sweep must reuse the same
signature-keyed VM executables (zero additional XLA compiles).
"""
import dataclasses

import numpy as np
import pytest

import legacy_patterns as lp
import repro.frontend as mve
from repro import opt
from _hypothesis_compat import given, settings, st
from repro.core import isa
from repro.core.engine import cache_info, compile_program
from repro.core.interp import MVEInterpreter
from repro.core.isa import DType, Op
from repro.core.machine import MVEConfig
from repro.core.patterns import PATTERNS
from repro.core.vm import N_REGS
from repro.frontend import (BCAST, CR, DERIVED, SEQ, KernelBuilder,
                            MemoryPlan, RegisterPressureError, regalloc)
from repro.frontend.operands import OperandError

CFG = MVEConfig()
ORACLE = MVEInterpreter(CFG, compiled=False)


# ---------------------------------------------------------------------------
# Program isomorphism: equal modulo a consistent register renaming
# ---------------------------------------------------------------------------

def register_renaming(old_prog, new_prog):
    """The bijection legacy reg -> frontend reg, asserting the programs
    are identical in every other field at every instruction."""
    assert len(old_prog) == len(new_prog)
    fwd, bwd = {}, {}
    for i, (a, b) in enumerate(zip(old_prog, new_prog)):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for f in ("vd", "vs1", "vs2"):
            ra, rb = da.pop(f), db.pop(f)
            assert (ra is None) == (rb is None), (i, f, a, b)
            if ra is None:
                continue
            assert fwd.setdefault(ra, rb) == rb, \
                f"[{i}] inconsistent renaming {ra}->{rb} vs {fwd[ra]}"
            assert bwd.setdefault(rb, ra) == ra, \
                f"[{i}] renaming not injective at {rb}"
            fwd[ra] = rb
        assert da == db, f"[{i}] non-register field mismatch:\n{a}\n{b}"
    return fwd


def _assert_states_equal(st_old, st_new, renaming, compare_trace=True):
    np.testing.assert_array_equal(np.asarray(st_old.memory),
                                  np.asarray(st_new.memory))
    np.testing.assert_array_equal(np.asarray(st_old.tag),
                                  np.asarray(st_new.tag))
    assert {renaming[r] for r in st_old.regs} == set(st_new.regs)
    for r in st_old.regs:
        np.testing.assert_array_equal(
            np.asarray(st_old.regs[r]), np.asarray(st_new.regs[renaming[r]]))
    if not compare_trace:
        return
    assert len(st_old.trace) == len(st_new.trace)
    for ea, eb in zip(st_old.trace, st_new.trace):
        da, db = dataclasses.asdict(ea), dataclasses.asdict(eb)
        np.testing.assert_array_equal(da.pop("cb_mask"), db.pop("cb_mask"))
        assert da == db, (ea, eb)


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_frontend_pattern_matches_legacy(name):
    """Bit-identical to the hand-coded program on interp, fused and VM.

    The builder folds config writes that re-establish control state the
    machine is already in (dimension-scope re-entry used to re-emit the
    whole scope), so program *text* is compared after ``opt.dead_config``
    normalization of both sides — under which legacy and frontend must be
    identical modulo a consistent register renaming.  Execution state
    (memory, registers, Tag) is still compared on the raw programs.
    """
    old = lp.LEGACY_PATTERNS[name]()
    new = PATTERNS[name]()
    norm_old = list(opt.dead_config(isa.Program(old.program)))
    norm_new = list(opt.dead_config(isa.Program(new.program)))
    renaming = register_renaming(norm_old, norm_new)
    np.testing.assert_array_equal(old.memory, new.memory)

    if tuple(old.program) == tuple(new.program):
        # The frontend reproduced the hand-written instruction stream
        # exactly — every executor trivially agrees; one compiled run
        # to confirm the check still passes end to end.
        mem_after, state = compile_program(new.program, CFG).run(new.memory)
        new.check(np.asarray(mem_after), state)
        return

    # Different text (renamed registers and/or folded config writes):
    # execute both programs on all three executors and compare
    # exhaustively.  Traces are only comparable event-for-event when the
    # instruction streams have equal length.
    same_len = len(old.program) == len(new.program)
    _, st_old = ORACLE.run_stepwise(old.program, old.memory)
    _, st_new = ORACLE.run_stepwise(new.program, new.memory)
    _assert_states_equal(st_old, st_new, renaming, compare_trace=same_len)
    for mode in ("fused", "vm"):
        _, so = compile_program(old.program, CFG, mode=mode).run(old.memory)
        _, sn = compile_program(new.program, CFG, mode=mode).run(new.memory)
        _assert_states_equal(so, sn, renaming, compare_trace=same_len)
        new.check(np.asarray(sn.memory), sn)


def test_frontend_patterns_stay_on_vm_path():
    """Every pattern's allocation fits the VM's dense register file, so
    the whole library rides the signature-shared executor."""
    for name in sorted(PATTERNS):
        k = PATTERNS[name]().kernel
        assert k.n_regs <= N_REGS, (name, k.n_regs)
        cp = compile_program(k, CFG, mode="vm")
        assert cp.mode == "vm", name


def test_frontend_sweep_reuses_vm_signature_cache():
    """Acceptance: the frontend-built 14-pattern sweep adds zero XLA
    compiles over the hand-coded sweep — same signatures, same
    executables."""
    for name in sorted(lp.LEGACY_PATTERNS):
        run = lp.LEGACY_PATTERNS[name]()
        compile_program(run.program, CFG, mode="vm").run(run.memory)
    before = cache_info().vm_xla_compiles
    for name in sorted(PATTERNS):
        run = PATTERNS[name]()
        mem_after, state = compile_program(
            run.program, CFG, mode="vm").run(run.memory)
        run.check(np.asarray(mem_after), state)
    assert cache_info().vm_xla_compiles == before


def test_builder_folds_reestablished_config():
    """Regression (PR 6): re-entering an identical dimension scope used
    to re-emit the whole vsetdimc/vsetdiml/vset*str block; the builder
    now tracks machine control state and skips writes that re-establish
    the value a cell already holds.  First writes are always emitted
    (the program documents its own geometry), and changed values still
    are."""
    n = 64

    def build(repeats):
        b = KernelBuilder("dedup")
        b.input("x", (n,), DType.F)
        b.output("y", (n,), DType.F)
        b.width(32)
        acc = None
        for _ in range(repeats):
            with b.dims(n):
                v = b.operand("x").load(SEQ)
                acc = v if acc is None else acc + v
        with b.dims(n):
            b.operand("y").store(acc, SEQ)
        return b.build()

    k1, k3 = build(1), build(3)
    confs = [[i for i in k.program if i.op in isa.CONFIG_OPS]
             for k in (k1, k3)]
    # re-established scopes add zero config traffic...
    assert confs[0] == confs[1]
    # ...and the folded program still computes 3*x
    xs = np.arange(n, dtype=np.float32)
    out, _ = k3.run({"x": xs})
    np.testing.assert_allclose(out["y"], 3 * xs, rtol=1e-6)

    # a *changed* dimension scope is still emitted
    b = KernelBuilder("changed")
    b.input("x", (n,), DType.F)
    b.output("y", (n,), DType.F)
    b.width(32)
    with b.dims(n):
        v = b.operand("x").load(SEQ)
    with b.dims(n // 2, 2):
        b.operand("y").store(v, SEQ)
    k = b.build()
    assert any(i.op is Op.SET_DIMC and i.imm == 2 for i in k.program)


# ---------------------------------------------------------------------------
# Named-operand overloads through the stack
# ---------------------------------------------------------------------------

def _daxpy_kernel(n=256):
    b = KernelBuilder("daxpy_small")
    x = b.input("x", (n,), DType.F)
    y = b.inout("y", (n,), DType.F)
    b.width(32)
    with b.dims(n):
        vy = y.load(SEQ)
        vy += 2.0 * x.load(SEQ)
        y.store(vy, SEQ)
    return b.build()


def test_kernel_run_reads_results_by_name():
    n = 256
    k = _daxpy_kernel(n)
    x = np.arange(n, dtype=np.float32)
    y = np.ones(n, dtype=np.float32)
    out, state = k.run({"x": x, "y": y})
    expected = y + np.float32(2.0) * x
    np.testing.assert_allclose(out["y"], expected, rtol=1e-6)
    np.testing.assert_array_equal(state.operands["y"], out["y"])
    # compiled-program dict overload
    cp = compile_program(k)
    _, st2 = cp.run({"x": x, "y": y})
    np.testing.assert_array_equal(st2.operands["y"], out["y"])
    # batch overload
    outs = k.run_batch({"x": np.stack([x, 2 * x]),
                        "y": np.stack([y, y])})
    np.testing.assert_allclose(outs["y"][0], expected, rtol=1e-6)
    np.testing.assert_allclose(outs["y"][1], y + np.float32(4.0) * x,
                               rtol=1e-6)


def test_scheduler_and_server_kernel_submissions():
    from repro.launch.serve import MVEProgramServer
    from repro.runtime.scheduler import MVEScheduler

    n = 256
    k = _daxpy_kernel(n)
    x = np.arange(n, dtype=np.float32)
    y = np.full(n, 3.0, dtype=np.float32)
    expected = y + np.float32(2.0) * x

    with MVEScheduler() as sched:
        t = sched.submit(k, {"x": x, "y": y})
        t_default = sched.submit(k)          # declared inits (zeros)
        sched.drain()
        np.testing.assert_allclose(t.result().operands["y"], expected,
                                   rtol=1e-6)
        np.testing.assert_array_equal(t_default.result().operands["y"],
                                      np.zeros(n, dtype=np.float64))
    # an already-packed flat image passes through the kernel overload
    with MVEScheduler() as sched:
        t_flat = sched.submit(k, k.pack({"x": x, "y": y}))
        sched.drain()
        np.testing.assert_allclose(t_flat.result().operands["y"],
                                   expected, rtol=1e-6)
    with pytest.raises(TypeError):
        MVEScheduler().submit(list(k.program))   # raw program, no memory

    srv = MVEProgramServer()
    req = srv.submit(k, {"x": x, "y": y})
    srv.run_until_drained()
    np.testing.assert_allclose(req.result.operands["y"], expected,
                               rtol=1e-6)


def test_comparisons_and_predication_match_oracle():
    """v.gt() writes the Tag latch; predicated ops execute under it —
    bit-exact against the stepwise oracle."""
    n = 64
    b = KernelBuilder("relu_shift")
    x = b.input("x", (n,), DType.DW)
    y = b.output("y", (n,), DType.DW)
    b.width(32)
    with b.dims(n):
        vx = x.load(SEQ)
        vx.gt(3)                              # tag = x > 3
        bumped = b.add(vx, 100, predicated=True)
        y.store(bumped, SEQ)
    k = b.build()
    xs = np.arange(n, dtype=np.int64)
    mem = k.pack({"x": xs})
    for mode in ("vm", "fused"):
        mem_i, st_i = ORACLE.run_stepwise(k.program, mem)
        mem_c, st_c = compile_program(k, CFG, mode=mode).run(dict(x=xs))
        np.testing.assert_array_equal(np.asarray(mem_i),
                                      np.asarray(mem_c))
        np.testing.assert_array_equal(np.asarray(st_i.tag),
                                      np.asarray(st_c.tag))
    got = k.unpack(np.asarray(mem_c))["y"]
    expected = np.where(xs > 3, xs + 100, 0)   # masked lanes: power-on 0
    np.testing.assert_array_equal(got[:n], expected)


def test_shared_program_text_with_distinct_kernels_is_not_aliased():
    """Two kernels emitting identical programs but different init data
    must not silently serve each other's operands through the compile
    cache."""
    def build(init, n=32):
        b = KernelBuilder("aliased")
        x = b.input("x", (n,), DType.F, init=init)
        y = b.output("y", (n,), DType.F)
        b.width(32)
        with b.dims(n):
            y.store(x.load(SEQ), SEQ)
        return b.build()

    k1 = build(np.full(32, 1.0))
    k2 = build(np.full(32, 2.0))
    assert tuple(k1.program) == tuple(k2.program)
    assert not k1.equivalent(k2)
    cp1 = compile_program(k1, CFG)
    cp2 = compile_program(k2, CFG)
    assert cp1 is cp2                        # shared compilation...
    with pytest.raises(TypeError, match="multiple distinct kernels"):
        cp2.run({})                          # ...but no silent aliasing
    # the unambiguous path still works and uses each kernel's own data
    out1, _ = k1.run()
    out2, _ = k2.run()
    np.testing.assert_array_equal(out1["y"], np.full(32, 1.0))
    np.testing.assert_array_equal(out2["y"], np.full(32, 2.0))
    # equivalent kernels (same layout + inits) share the binding freely
    # (fresh program text: n differs, so this compilation is unpoisoned)
    k3, k4 = build(np.full(48, 5.0), 48), build(np.full(48, 5.0), 48)
    assert k3.equivalent(k4)
    cp = compile_program(k3, CFG)
    compile_program(k4, CFG)
    _, state = cp.run({})
    np.testing.assert_array_equal(state.operands["y"], np.full(48, 5.0))


# ---------------------------------------------------------------------------
# Memory planner: packing round-trips by name
# ---------------------------------------------------------------------------

def test_operand_packing_round_trip():
    b = KernelBuilder("plan")
    b.input("a", (4, 8), DType.F)
    b.input("b", (32,), DType.W)
    b.scratch("tmp", (16,), DType.F)
    b.output("c", (2, 4, 4), DType.F)
    b.width(32)
    with b.dims(32):
        va = b.operand("a").load(SEQ)
        b.operand("c").store(va, SEQ)
    k = b.build()
    rng = np.random.default_rng(0)
    vals = {"a": rng.standard_normal((4, 8)),
            "b": rng.integers(0, 99, 32),
            "c": rng.standard_normal((2, 4, 4))}
    mem = k.pack(vals)
    assert mem.shape == (4 * 8 + 32 + 16 + 32,)
    out = k.unpack(mem)
    assert "tmp" not in out                      # scratch is private
    for name in vals:
        np.testing.assert_allclose(out[name], vals[name])
    with pytest.raises(OperandError):
        k.pack({"nope": np.zeros(3)})
    with pytest.raises(OperandError):
        k.pack({"a": np.zeros(7)})


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 6)),
                min_size=1, max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_packing_round_trip_property(shapes, seed):
    rng = np.random.default_rng(seed)
    b = KernelBuilder("prop")
    vals = {}
    for i, shape in enumerate(shapes):
        name = f"op{i}"
        b.input(name, tuple(shape), DType.F)
        vals[name] = rng.standard_normal(tuple(shape))
    plan = MemoryPlan(b._operands)
    out = plan.unpack(plan.pack(vals))
    assert plan.size == sum(int(np.prod(s)) for s in shapes)
    for name, v in vals.items():
        np.testing.assert_array_equal(out[name], v)


# ---------------------------------------------------------------------------
# Register allocator: optimal for straight-line code
# ---------------------------------------------------------------------------

def _interval_program(spans):
    """A straight-line program realising the given (start, length) value
    lifetimes: each value is defined by a vsetdup at its start slot and
    read by compares until its end slot."""
    end = max(s + ln for s, ln in spans) + 1
    by_slot = {}
    for v, (s, ln) in enumerate(spans):
        by_slot.setdefault(s, []).append(("def", v))
        for t in range(s + 1, s + ln + 1):
            by_slot.setdefault(t, []).append(("use", v))
    prog = [isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, 8)]
    for t in range(end):
        for kind, v in by_slot.get(t, []):
            if kind == "def":
                prog.append(isa.Instr(Op.SET_DUP, dtype=DType.DW,
                                      vd=100 + v, imm=v))
            else:
                prog.append(isa.vcmp(Op.GT, DType.DW, 100 + v, 100 + v))
    return prog


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), st.integers(1, 10)),
                min_size=1, max_size=24))
def test_regalloc_never_exceeds_nregs_when_assignment_exists(spans):
    """Acceptance property: allocation succeeds iff peak simultaneous
    liveness fits the register file, and the output never names a
    register >= N_REGS."""
    prog = _interval_program(spans)
    pressure = regalloc.max_pressure(prog)
    if pressure <= N_REGS:
        alloc = regalloc.allocate(prog, N_REGS)
        assert alloc.max_live <= N_REGS
        for instr in alloc.program:
            for r in (instr.vd, instr.vs1, instr.vs2):
                assert r is None or 0 <= r < N_REGS
        # structure is preserved: only register fields were rewritten
        for a, b in zip(prog, alloc.program):
            assert a.op is b.op and a.imm == b.imm
    else:
        with pytest.raises(RegisterPressureError):
            regalloc.allocate(prog, N_REGS)


def test_regalloc_pressure_error_is_readable():
    spans = [(0, 5)] * (N_REGS + 1)
    with pytest.raises(RegisterPressureError) as ei:
        regalloc.allocate(_interval_program(spans), N_REGS)
    msg = str(ei.value)
    assert "register pressure" in msg and "live virtual registers" in msg


def test_regalloc_reuses_registers_across_lifetimes():
    b = KernelBuilder("reuse")
    x = b.input("x", (64,), DType.F)
    y = b.output("y", (64,), DType.F)
    b.width(32)
    with b.dims(64):
        acc = b.const(DType.F, 0.0)
        for t in range(20):                 # 20 loads, 20 products
            acc += x.at(0).load(SEQ) * 0.5
        y.store(acc, SEQ)
    k = b.build()
    assert k.n_vregs == 1 + 3 * 20          # far more virtual...
    assert k.n_regs == 4                    # ...than physical registers
    assert k.n_regs <= N_REGS


def test_read_before_write_is_a_build_error():
    b = KernelBuilder("oops")
    b.input("x", (8,), DType.F)
    h = mve.VectorHandle(b, 42, DType.F)    # never defined
    b.width(32)
    b.dims(8)
    b.operand("x").store(h, SEQ)
    with pytest.raises(isa.ProgramError, match="read before"):
        b.build()


# ---------------------------------------------------------------------------
# Program.validate / Program.dump
# ---------------------------------------------------------------------------

def test_validate_rejects_bad_dim_index():
    prog = [isa.vsetwidth(32), isa.Instr(Op.SET_DIML, dim=7, length=4)]
    with pytest.raises(isa.ProgramError, match="dimension index"):
        isa.validate(prog)


def test_validate_rejects_register_beyond_width_budget_strict():
    prog = [isa.vsetwidth(64),              # 256/64 = 4 physical registers
            isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsetdup(DType.DW, 5, 1)]
    with pytest.raises(isa.ProgramError, match="out of range"):
        isa.validate(prog, strict=True)
    isa.validate(prog)                       # lenient: executors accept


def test_validate_rejects_wide_dtype_on_narrow_width_strict():
    prog = [isa.vsetwidth(8), isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsetdup(DType.F, 0, 1.0)]
    with pytest.raises(isa.ProgramError, match="wider than"):
        isa.validate(prog, strict=True)


def test_validate_rejects_mask_beyond_top_dimension_strict():
    prog = [isa.vsetwidth(32), isa.vsetdimc(2),
            isa.vsetdiml(0, 16), isa.vsetdiml(1, 4),
            isa.vunsetmask(9)]               # top dim has 4 elements
    with pytest.raises(isa.ProgramError, match="highest dimension"):
        isa.validate(prog, strict=True)
    isa.validate(prog)


def test_validate_rejects_float_shift():
    prog = [isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsetdup(DType.F, 0, 1.0), isa.vshi(DType.F, 0, 0, 2)]
    with pytest.raises(isa.ProgramError, match="float"):
        isa.validate(prog)


def test_validate_rejects_out_of_image_access_strict():
    prog = [isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, 64),
            isa.vsld(DType.F, 0, 100, 1)]
    with pytest.raises(isa.ProgramError, match="memory image"):
        isa.validate(prog, memory_size=128, strict=True)
    isa.validate(prog, memory_size=4096, strict=True)


def test_compile_rejects_malformed_program_with_location():
    prog = [isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.Instr(Op.ADD, dtype=DType.F, vd=0, vs1=0)]   # missing vs2
    with pytest.raises(isa.ProgramError, match=r"at \[  3\]"):
        compile_program(prog, CFG)


def test_dump_is_readable():
    run = PATTERNS["daxpy"]()
    text = isa.Program(run.program).dump()
    for token in ("vsetwidth", "vsetdiml", "vsld.f", "vmul.f", "vsst.f",
                  "[  0]"):
        assert token in text, token
    assert len(text.splitlines()) == len(run.program)


def test_kernel_builder_rejects_misuse():
    b = KernelBuilder("bad")
    b.input("x", (8,), DType.F)
    with pytest.raises(OperandError, match="twice"):
        b.input("x", (8,), DType.F)
    with pytest.raises(mve.BuildError):
        b.dims()                             # zero dimensions
    b.width(32)
    b.dims(8)
    vx = b.operand("x").load(SEQ)
    with pytest.raises(mve.BuildError, match="non-integral"):
        _ = b.mul(vx.astype(DType.DW), 1.5)
    k = b.build()
    with pytest.raises(mve.BuildError, match="already built"):
        b.scalar(1)


def test_frontend_mode_mnemonics_match_isa_encoding():
    assert (BCAST, SEQ, DERIVED, CR) == (0, 1, 2, 3)
    assert regalloc.DEFAULT_MAX_REGS == N_REGS
