"""Beyond-paper performance knobs keep model semantics: fp8 KV cache,
bf16 grad accumulation, int8 optimizer state (see EXPERIMENTS.md §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM

KEY = jax.random.PRNGKey(7)


def test_fp8_kv_cache_decode_close_to_bf16():
    cfg = get_config("qwen2-72b", reduced=True)
    model = LM(cfg)
    params = model.init_params(KEY)
    toks = jax.random.randint(KEY, (2, 12), 1, cfg.vocab_size)

    def run(kv_dtype):
        c = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
        m = LM(c)
        batch = {"tokens": toks[:, :8],
                 "positions": jnp.tile(jnp.arange(8), (2, 1))}
        logits, cache = m.prefill(params, batch)
        # pad cache seq 8 -> 16 and cast to the cache dtype
        from repro.models.common import DTYPES
        cache = {k: (jnp.pad(v, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)])
                     .astype(DTYPES[kv_dtype]) if k in ("k", "v") else v)
                 for k, v in cache.items()}
        outs = []
        for t in range(8, 11):
            dl, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
            outs.append(np.asarray(dl, np.float32))
        return np.stack(outs)

    bf16 = run("bfloat16")
    f8 = run("float8")
    # fp8 cache introduces bounded quantization noise on the logits
    err = np.abs(bf16 - f8).max()
    scale = np.abs(bf16).max()
    assert err < 0.15 * scale + 0.5, (err, scale)


def test_grad_accum_bf16_close_to_fp32():
    from repro.configs.base import ShapeCell
    from repro.launch.train import TrainLoopConfig, train_loop
    from repro.optim import AdamWConfig
    cfg = get_config("qwen2-0.5b", reduced=True)
    cell = ShapeCell("t", 32, 4, "train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=3)
    losses = {}
    for dt in ("float32", "bfloat16"):
        c = dataclasses.replace(cfg, grad_accum=2, grad_accum_dtype=dt)
        m = train_loop(c, cell, TrainLoopConfig(steps=3, log_every=100),
                       opt_cfg=opt, seed=0)
        losses[dt] = m["loss"]
    assert abs(losses["float32"] - losses["bfloat16"]) < 5e-2, losses


def test_int8_optimizer_trains_lm():
    from repro.configs.base import ShapeCell
    from repro.launch.train import TrainLoopConfig, train_loop
    from repro.optim import AdamWConfig
    cfg = get_config("qwen2-0.5b", reduced=True)
    cfg = dataclasses.replace(cfg, num_layers=1)
    cell = ShapeCell("t", 32, 4, "train")
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=15,
                      state_format="int8")
    m = train_loop(cfg, cell, TrainLoopConfig(steps=15, log_every=100),
                   opt_cfg=opt, seed=0)
    assert m["loss"] < 6.2          # below ln(512) init => learning
