"""Cycle-model checks against Table II and Section II-B."""
import numpy as np
import pytest

from repro.core import MVEConfig, cost
from repro.core.isa import DType, Op


def test_table2_bit_serial_latencies():
    cfg = MVEConfig(scheme="bs")
    n = 32
    dt = DType.DW
    assert cost.compute_cycles(Op.ADD, dt, cfg) == n
    assert cost.compute_cycles(Op.SUB, dt, cfg) == 2 * n
    assert cost.compute_cycles(Op.MUL, dt, cfg) == n * n + 5 * n
    assert cost.compute_cycles(Op.MIN, dt, cfg) == 2 * n
    assert cost.compute_cycles(Op.XOR, dt, cfg) == n
    assert cost.compute_cycles(Op.SHI, dt, cfg) == n
    assert cost.compute_cycles(Op.SHR, dt, cfg) == n * np.log2(n)
    assert cost.compute_cycles(Op.CPY, dt, cfg) == n
    assert cost.compute_cycles(Op.GT, dt, cfg) == n


def test_precision_quadratic_for_mul():
    """Section VII-E: bit-serial multiply is O(n^2) in precision."""
    cfg = MVEConfig()
    c8 = cost.compute_cycles(Op.MUL, DType.B, cfg)
    c32 = cost.compute_cycles(Op.MUL, DType.DW, cfg)
    assert 10 < c32 / c8 < 18          # (32^2+160)/(64+40) ~ 11.4


def test_bp_bh_latency_ordering():
    """BP < BH < BS latency; BP has 1/n lanes, BH 1/p (Section II-B)."""
    bs, bp = MVEConfig(scheme="bs"), MVEConfig(scheme="bp")
    bh = MVEConfig(scheme="bh", bh_segment_bits=4)
    dt = DType.DW
    assert cost.compute_cycles(Op.MUL, dt, bp) < \
        cost.compute_cycles(Op.MUL, dt, bh) < \
        cost.compute_cycles(Op.MUL, dt, bs)
    assert bp.effective_lanes(32) == bs.lanes // 32
    assert bh.effective_lanes(32) == bs.lanes // 4


def test_ac_arithmetic_4_to_8x_slower_than_bs():
    """Section VII-C: AC arithmetic latency is 4-8x BS."""
    bs, ac = MVEConfig(scheme="bs"), MVEConfig(scheme="ac")
    for op in (Op.ADD, Op.MUL):
        r = cost.compute_cycles(op, DType.DW, ac) / \
            cost.compute_cycles(op, DType.DW, bs)
        assert 3.5 <= r <= 8.5, (op, r)
    # ...but O(1)-ish logical ops are AC's strength
    assert cost.compute_cycles(Op.XOR, DType.DW, ac) < \
        cost.compute_cycles(Op.XOR, DType.DW, bs)


def test_float_ops_cost_more():
    cfg = MVEConfig()
    assert cost.compute_cycles(Op.ADD, DType.F, cfg) > \
        cost.compute_cycles(Op.ADD, DType.DW, cfg)


def test_timeline_memory_barrier():
    """Vector memory accesses serialize across CBs (Section V-B)."""
    from repro.core.interp import TraceEvent
    cfg = MVEConfig()
    ncb = cfg.num_cbs
    full = np.ones(ncb, bool)
    half = np.zeros(ncb, bool)
    half[: ncb // 2] = True
    trace = [
        TraceEvent(Op.ADD, DType.DW, cfg.lanes, half),
        TraceEvent(Op.SLD, DType.DW, cfg.lanes, full, segments=1,
                   contiguous_run=cfg.lanes),
        TraceEvent(Op.ADD, DType.DW, cfg.lanes, full),
    ]
    tl = cost.simulate(trace, cfg)
    # the load blocks everything: total >= compute-before + load + after
    assert tl.total_cycles >= tl.data_cycles + 2 * \
        cost.compute_cycles(Op.ADD, DType.DW, cfg) - 1e-6


def test_breakdown_fractions_sum():
    from repro.core.patterns import PATTERNS
    from repro.core import MVEInterpreter
    run = PATTERNS["daxpy"]()
    _, state = MVEInterpreter().run(run.program, run.memory)
    tl = cost.simulate(state.trace, MVEConfig())
    bd = cost.breakdown(tl)
    assert 0.99 < sum(bd.values()) < 1.01
    assert all(v >= 0 for v in bd.values())


def test_neon_model_lower_precision_scales_linearly():
    neon = cost.NeonModel()
    c8 = neon.kernel_cycles(2, 1024, 8, 0)
    c32 = neon.kernel_cycles(2, 1024, 32, 0)
    assert abs(c32 / c8 - 4.0) < 0.01


def test_gpu_model_launch_overhead_dominates_small_kernels():
    gpu = cost.GPUModel()
    small = gpu.kernel_us(flops=1e4, copy_bytes=1e3)
    assert small < gpu.launch_overhead_us * 1.2
    big = gpu.kernel_us(flops=1e10, copy_bytes=1e6)
    assert big > 10 * small
