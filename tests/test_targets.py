"""The pluggable Target API (:mod:`repro.targets`, docs/TARGETS.md).

Covers the acceptance contract of the targets redesign:

* ``repro.targets.compile(kernel_or_program, target=t)`` works for every
  registered target on every Section-IV pattern with **bit-exact**
  results across targets (and against the stepwise interpreter oracle);
* the uniform :class:`CompiledArtifact` surface — run / run_batch /
  trace / timeline / energy / instruction_mix;
* the registry (unknown names raise a :class:`ProgramError` naming what
  is registered; ``register_target`` refuses silent overwrites);
* per-target compile-cache keys (``cache_info().per_target``) — RVV/Neon
  compilations never alias MVE LRU entries;
* target-aware scheduling: per-target bucketing, promotion, and the
  readable errors for unknown / geometry-mismatched targets.
"""
import numpy as np
import pytest

from repro import targets
from repro.core import MVEConfig, MVEInterpreter, cache_info
from repro.core.isa import ProgramError
from repro.core.patterns import PATTERNS
from repro.runtime.scheduler import MVEScheduler

CFG = MVEConfig()
ORACLE = MVEInterpreter(CFG, compiled=False)
ALL_BUILTIN = ("mve-bs", "mve-bp", "mve-bh", "mve-ac", "rvv-1d", "neon")


def _assert_state_equal(st_a, st_b):
    assert set(st_a.regs) == set(st_b.regs)
    for r in st_a.regs:
        np.testing.assert_array_equal(np.asarray(st_a.regs[r]),
                                      np.asarray(st_b.regs[r]))
    np.testing.assert_array_equal(np.asarray(st_a.tag),
                                  np.asarray(st_b.tag))


# ---------------------------------------------------------------------------
# The cross-target bit-exactness invariant (the RVV path is the same
# access, sliced — first-class and tested, not a docstring claim).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_all_patterns_bit_exact_on_every_target(name):
    run = PATTERNS[name]()
    mem_i, st_i = ORACLE.run_stepwise(run.program, run.memory)
    mem_i = np.asarray(mem_i)
    for tname in ALL_BUILTIN:
        art = targets.compile(run.program, target=tname)
        mem_t, st_t = art.run(run.memory)
        np.testing.assert_array_equal(
            np.asarray(mem_t), mem_i,
            err_msg=f"{tname} diverged from the oracle on {name}")
        _assert_state_equal(st_i, st_t)
        run.check(np.asarray(mem_t), st_t)


def test_registry_contents_and_default():
    names = targets.list_targets()
    for required in ALL_BUILTIN:
        assert required in names
    assert targets.DEFAULT_TARGET == "mve-bs"
    assert targets.get_target("mve-bs") is targets.MVE_BS
    # instances pass through
    assert targets.get_target(targets.RVV_1D) is targets.RVV_1D


def test_unknown_target_names_registered_ones():
    with pytest.raises(ProgramError) as ei:
        targets.get_target("sve-2d")
    msg = str(ei.value)
    for name in ALL_BUILTIN:
        assert name in msg


def test_register_target_rejects_silent_overwrite():
    custom = targets.InCacheTarget("bs-test-dup", scheme="bs")
    try:
        targets.register_target(custom)
        with pytest.raises(ProgramError):
            targets.register_target(
                targets.InCacheTarget("bs-test-dup", scheme="bp"))
        replacement = targets.InCacheTarget("bs-test-dup", scheme="bp")
        assert targets.register_target(replacement, overwrite=True) \
            is replacement
        with pytest.raises(TypeError):
            targets.register_target("not-a-target")
    finally:
        targets.base._REGISTRY.pop("bs-test-dup", None)


def test_third_party_scheme_registration_end_to_end():
    """The extension story: register a custom scheme, compile, run,
    price — then it also serves through the scheduler by name."""
    wide_bh = targets.InCacheTarget(
        "bh8-test", scheme="bh", description="EVE with 8-bit segments",
        config_overrides=(("bh_segment_bits", 8),))
    try:
        targets.register_target(wide_bh)
        run = PATTERNS["daxpy"]()
        art = targets.compile(run.program, target="bh8-test")
        assert art.cfg.scheme == "bh" and art.cfg.bh_segment_bits == 8
        mem_t, st = art.run(run.memory)
        run.check(np.asarray(mem_t), st)
        assert art.timeline(st).total_cycles > 0
        sched = MVEScheduler(CFG)
        ticket = sched.submit(run.program, run.memory, target="bh8-test")
        sched.drain()
        run.check(np.asarray(ticket.result().memory), ticket.result())
    finally:
        targets.base._REGISTRY.pop("bh8-test", None)


# ---------------------------------------------------------------------------
# The uniform artifact surface.
# ---------------------------------------------------------------------------

def test_artifact_surface_timeline_energy_mix():
    run = PATTERNS["gemm"]()
    mve = targets.compile(run.program, target="mve-bs")
    rvv = targets.compile(run.program, target="rvv-1d")
    neon = targets.compile(run.program, target="neon")
    _, state = mve.run(run.memory)

    tl_m, tl_r, tl_n = (a.timeline(state) for a in (mve, rvv, neon))
    # gemm is multi-dimensional: the 1D lowering must cost more cycles
    assert tl_r.total_cycles > tl_m.total_cycles
    assert tl_n.total_cycles > 0
    for tl in (tl_m, tl_r, tl_n):
        assert tl.total_cycles > 0 and tl.compute_cycles > 0

    mix_m, mix_r = mve.instruction_mix(), rvv.instruction_mix()
    assert mix_r.vector > mix_m.vector        # Figure 11 ordering
    assert mix_r.scalar > mix_m.scalar
    assert mix_m.total > 0

    for art in (mve, rvv, neon):
        e = art.energy(state)
        assert e.total_pj > 0
        assert e.total_pj == pytest.approx(
            e.compute_pj + e.data_pj + e.issue_pj + e.scalar_pj)
        assert art.us(state) > 0

    # rvv performance trace is a different issue stream over the same work
    assert len(rvv.trace(state)) > len(mve.trace(state))


def test_artifact_static_vs_executed_pricing():
    """source=None prices the static trace; an execution state or a raw
    memory image price the exact run (identical for strided patterns)."""
    run = PATTERNS["daxpy"]()
    art = targets.compile(run.program, target="mve-bs")
    _, state = art.run(run.memory)
    static = art.timeline().total_cycles
    exact = art.timeline(state).total_cycles
    from_mem = art.timeline(run.memory).total_cycles
    assert static == exact == from_mem


def test_artifact_kernel_named_operands_and_batch():
    run = PATTERNS["daxpy"]()
    art = targets.compile(run.kernel, target="mve-bp")
    assert art.kernel is run.kernel
    mem_t, state = art.run()          # declared inits form the image
    assert sorted(state.operands) == ["x", "y"]
    run.check(np.asarray(mem_t), state)

    mems = np.stack([run.kernel.pack(), run.kernel.pack()])
    bmem, _, _ = art.run_batch(mems)
    np.testing.assert_array_equal(np.asarray(bmem)[0],
                                  np.asarray(bmem)[1])
    np.testing.assert_array_equal(np.asarray(bmem)[0], np.asarray(mem_t))


def test_artifact_raw_program_requires_memory():
    run = PATTERNS["daxpy"]()
    art = targets.compile(run.program, target="mve-bs")
    with pytest.raises(TypeError):
        art.run()


def test_config_overrides_flow_through():
    run = PATTERNS["daxpy"]()
    art = targets.compile(run.program, target="mve-bs", num_arrays=8)
    assert art.cfg.lanes == 8 * 256
    base = targets.compile(run.program, target="mve-bs")
    assert base.cfg.lanes == CFG.lanes
    # an explicit cfg is the base the target patches its scheme onto
    art2 = targets.compile(run.program, target="mve-bh",
                           cfg=MVEConfig(num_arrays=16))
    assert art2.cfg.scheme == "bh" and art2.cfg.num_arrays == 16


def test_per_target_cache_keys_never_alias():
    run = PATTERNS["reduction"]()
    before = cache_info()
    a = targets.compile(run.program, target="mve-bs")
    b = targets.compile(run.program, target="rvv-1d")
    c = targets.compile(run.program, target="rvv-1d")
    assert a.cp is not b.cp          # distinct LRU entries per target
    assert b.cp is c.cp              # ... but cached within one target
    after = cache_info()
    assert after.per_target["rvv-1d"]["hits"] >= 1
    assert after.per_target["rvv-1d"]["misses"] >= 1
    assert after.per_target["mve-bs"]["misses"] > \
        before.per_target.get("mve-bs", {}).get("misses", 0) - 1


def test_smoke_entry_point():
    cycles = targets.smoke("xor_cipher")
    assert set(cycles) >= set(ALL_BUILTIN)
    assert all(c > 0 for c in cycles.values())


# ---------------------------------------------------------------------------
# Target-aware scheduling / serving.
# ---------------------------------------------------------------------------

def test_scheduler_submit_target_bit_exact_and_bucketed():
    runs = [PATTERNS["alpha_blend"](seed=s) for s in range(3)]
    sched = MVEScheduler(CFG, promote_after=None)
    t_def = [sched.submit(r.program, r.memory) for r in runs]
    t_rvv = [sched.submit(r.program, r.memory, target="rvv-1d")
             for r in runs]
    sched.drain()
    for r, td, tr in zip(runs, t_def, t_rvv):
        np.testing.assert_array_equal(np.asarray(td.result().memory),
                                      np.asarray(tr.result().memory))
        r.check(np.asarray(tr.result().memory), tr.result())
    # per-target bucketing: same program, two targets -> two dispatches
    assert sched.stats.dispatches == 2
    assert sched.stats.batched_requests == 6


def test_scheduler_promotion_is_per_target():
    runs = [PATTERNS["daxpy"](seed=s) for s in range(4)]
    sched = MVEScheduler(CFG, promote_after=2)
    for r in runs[:2]:
        sched.submit(r.program, r.memory)
        sched.submit(r.program, r.memory, target="mve-bp")
    sched.drain()
    for r in runs[2:]:
        sched.submit(r.program, r.memory)
        sched.submit(r.program, r.memory, target="mve-bp")
    sched.drain()
    # both targets crossed promote_after independently
    assert sched.stats.promotions == 2


def test_scheduler_unknown_target_is_a_program_error():
    run = PATTERNS["daxpy"]()
    sched = MVEScheduler(CFG)
    with pytest.raises(ProgramError) as ei:
        sched.submit(run.program, run.memory, target="mve-zz")
    assert "registered targets" in str(ei.value)
    assert "rvv-1d" in str(ei.value)
    assert sched.stats.requests == 0       # rejected before enqueue


def test_scheduler_geometry_mismatch_is_a_program_error():
    small = targets.InCacheTarget(
        "tiny-bs-test", scheme="bs",
        config_overrides=(("num_arrays", 8),))
    try:
        targets.register_target(small)
        run = PATTERNS["daxpy"]()
        sched = MVEScheduler(CFG)
        with pytest.raises(ProgramError) as ei:
            sched.submit(run.program, run.memory, target="tiny-bs-test")
        msg = str(ei.value)
        assert "lanes=2048" in msg and "lanes=8192" in msg
        assert "registered targets" in msg.lower() \
            or "Registered targets" in msg
        # ... and a scheduler built for that geometry accepts it
        small_cfg = small.machine_config()
        sched2 = MVEScheduler(small_cfg)
        r = PATTERNS["daxpy"](n=small_cfg.lanes)
        t = sched2.submit(r.program, r.memory, target="tiny-bs-test")
        sched2.drain()
        r.check(np.asarray(t.result().memory), t.result())
    finally:
        targets.base._REGISTRY.pop("tiny-bs-test", None)


def test_program_server_submit_target():
    from repro.launch.serve import MVEProgramServer
    run = PATTERNS["rgb2gray"]()
    srv = MVEProgramServer()
    req = srv.submit(run.program, run.memory, target="neon")
    srv.run_until_drained()
    run.check(np.asarray(req.result.memory), req.result)
    with pytest.raises(ProgramError):
        srv.submit(run.program, run.memory, target="cuda")


# ---------------------------------------------------------------------------
# Frontend integration: one @mve.kernel, every target.
# ---------------------------------------------------------------------------

def test_kernel_compile_and_run_per_target():
    run = PATTERNS["audio_mix"]()
    k = run.kernel
    ref = None
    for tname in ALL_BUILTIN:
        art = k.compile(target=tname)
        assert isinstance(art, targets.CompiledArtifact)
        out, state = k.run(target=tname)
        got = {n: np.asarray(v) for n, v in out.items()}
        if ref is None:
            ref = got
        else:
            for n in ref:
                np.testing.assert_array_equal(got[n], ref[n])
    # default (no target) keeps returning the engine CompiledProgram
    from repro.core.engine import CompiledProgram
    assert isinstance(k.compile(), CompiledProgram)
