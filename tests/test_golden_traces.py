"""Golden-trace regression suite: the cost model may not drift silently.

Freezes, for every Section-IV pattern, the exact :class:`TraceEvent`
stream the engine emits and the :class:`Timeline` totals the controller/CB
model produces from it — plus the ``paper_claims`` Table II latencies and
Figure 7 rows — under ``tests/data/golden_traces.json``.  Any change to
addressing resolution, trace emission, or the timing model shows up as an
exact-value diff here instead of an unexplained shift in the benchmark
CSVs.

Regenerating after an *intentional* cost-model change:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_golden_traces.py

Float fields round-trip exactly through JSON (shortest-repr), so equality
is exact, not approximate.
"""
import json
import os
import pathlib

import numpy as np
import pytest

from repro.core import MVEConfig, compile_program, cost
from repro.core.patterns import PATTERNS, run_pattern

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_traces.json"
REGEN = bool(os.environ.get("REPRO_REGEN_GOLDEN"))
CFG = MVEConfig()

_TIMELINE_FIELDS = [
    "total_cycles", "compute_cycles", "data_cycles", "idle_cycles",
    "scalar_cycles", "issue_cycles", "vector_instructions",
    "scalar_instructions", "config_instructions", "busy_cb_cycles",
    "cb_slots", "busy_lane_cycles", "lane_slots",
]


def _event_row(ev) -> list:
    cb_bits = int(sum(1 << i for i, b in enumerate(ev.cb_mask) if b))
    return [ev.op.value, ev.dtype.suffix if ev.dtype else None,
            int(ev.elements), int(ev.segments), int(ev.scalar_count),
            int(ev.contiguous_run), int(ev.unique_elements),
            int(ev.lines), cb_bits]


def _pattern_entry(name: str) -> dict:
    run = PATTERNS[name]()
    _, state = run_pattern(run, CFG, compiled=True)
    tl = cost.simulate(state.trace, CFG)
    return {
        "trace": [_event_row(ev) for ev in state.trace],
        "timeline": {f: getattr(tl, f) for f in _TIMELINE_FIELDS},
    }


def _claims_entries() -> dict:
    from benchmarks import paper_claims
    return {
        "table2": {name: [us, derived]
                   for name, us, derived in paper_claims.table2_latencies()},
        "fig7": {name: [us, derived]
                 for name, us, derived in paper_claims.fig7_neon()},
    }


def _current() -> dict:
    out = {"patterns": {n: _pattern_entry(n) for n in sorted(PATTERNS)}}
    out.update(_claims_entries())
    return out


@pytest.fixture(scope="module")
def golden():
    if REGEN:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_current(), indent=1, sort_keys=True))
    assert GOLDEN.exists(), \
        "golden file missing - regenerate with REPRO_REGEN_GOLDEN=1"
    return json.loads(GOLDEN.read_text())


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_trace_and_timeline_frozen(golden, name):
    """Exact TraceEvent stream + Timeline totals for every pattern."""
    want = golden["patterns"][name]
    got = _pattern_entry(name)
    assert got["trace"] == want["trace"], f"{name}: trace drifted"
    assert got["timeline"] == want["timeline"], f"{name}: timeline drifted"


def test_table2_frozen(golden):
    """Table II bit-serial latencies reproduce exactly."""
    got = _claims_entries()["table2"]
    assert got == golden["table2"]


def test_fig7_frozen(golden):
    """Figure 7 per-library rows (speedup + energy + breakdown strings)
    reproduce exactly — including the geomean summary row."""
    got = _claims_entries()["fig7"]
    assert got == golden["fig7"]


def test_golden_covers_all_patterns(golden):
    assert sorted(golden["patterns"]) == sorted(PATTERNS)
    # cb_mask bitmasks must fit the configured CB count
    for name, entry in golden["patterns"].items():
        for row in entry["trace"]:
            assert 0 <= row[-1] < (1 << CFG.num_cbs)
