"""Chaos tests for the self-healing serving runtime (PR 7).

The acceptance criterion lives in ``test_chaos_replay_64_request_stream``:
a deterministic fault-injected replay of the 64-request mixed Swan
stream (``benchmarks.serving_bench.request_stream``) at a seeded 10 %
fault rate must (a) resolve **every** ticket — no orphans, no hangs,
(b) never serve a result produced by a failed dispatch, (c) serve every
successful request **bit-exactly** equal to the stepwise-interpreter
oracle, and (d) stay within 2x of fault-free steady-state throughput.

The rest are unit tests of the individual resilience mechanisms:
fault-plan determinism and replay, batch bisection + quarantine,
bounded retry, circuit breaking + tier degradation, deadlines,
admission control, cancellation, close semantics, worker supervision,
and the sampled bit-flip audit.
"""
import time

import numpy as np
import pytest

from benchmarks.serving_bench import _QUICK_MIX, request_stream
from repro.core import engine
from repro.core.interp import MVEInterpreter
from repro.core.machine import MVEConfig
from repro.core.patterns import PATTERNS
from repro.resilience import (CancelledError, CircuitBreaker,
                              DeadlineExceededError, FaultInjector,
                              FaultPlan, FaultSpec, InjectedFault,
                              QuarantinedError, QueueFullError,
                              SchedulerClosedError)
from repro.runtime.scheduler import MVEScheduler

CFG = MVEConfig()
_ORACLE = MVEInterpreter(CFG, compiled=False)


def _oracle_memory(req):
    mem_i, _ = _ORACLE.run_stepwise(list(req.program), req.memory)
    return np.asarray(mem_i)


def _daxpy_reqs(n, seed0=1):
    return [PATTERNS["daxpy"](seed=seed0 + i) for i in range(n)]


def _fired_sig(inj):
    """The replay log reduced to its deterministic fields."""
    return [(f["site"], f["kind"], f["rid"]) for f in inj.fired]


# -- FaultPlan determinism ---------------------------------------------------

def test_fault_plan_random_is_deterministic_in_seed():
    a = FaultPlan.random(seed=42, n_requests=64, rate=0.1, sticky_rids=(7,))
    b = FaultPlan.random(seed=42, n_requests=64, rate=0.1, sticky_rids=(7,))
    assert a.specs == b.specs
    c = FaultPlan.random(seed=43, n_requests=64, rate=0.1, sticky_rids=(7,))
    assert a.specs != c.specs


def test_fault_plan_json_round_trip():
    plan = FaultPlan.random(seed=5, n_requests=32, rate=0.2,
                            sticky_rids=(3,), worker_kills=1)
    back = FaultPlan.from_json(plan.to_json())
    assert back.specs == plan.specs
    assert back.seed == plan.seed


def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="dispatch", kind="gremlin")


def test_chaos_replay_log_is_reproducible():
    """Same plan + same stream + drain mode => identical firing log."""
    plan = FaultPlan.random(seed=9, n_requests=12, rate=0.4, sticky_rids=(4,))
    logs = []
    for _ in range(2):
        inj = FaultInjector(plan, sleep=lambda s: None)
        with MVEScheduler(CFG, promote_after=None, injector=inj) as s:
            reqs = [PATTERNS["daxpy"](seed=i + 1) for i in range(12)]
            for r in reqs:
                s.submit(r.program, r.memory)
            s.drain()
        logs.append(_fired_sig(inj))
    assert logs[0] == logs[1]
    assert logs[0]                      # the plan actually fired


# -- the acceptance criterion ------------------------------------------------

def _replay(stream, injector=None, **kw):
    sched = MVEScheduler(CFG, promote_after=2, injector=injector,
                         audit_rate=1.0, audit_method="cross", **kw)
    tickets = [sched.submit(r.program, r.memory) for _, r in stream]
    t0 = time.perf_counter()
    sched.drain()
    wall = time.perf_counter() - t0
    sched.close()
    return wall, tickets, sched


def test_chaos_replay_64_request_stream():
    stream = request_stream()           # the 64-request mixed Swan stream
    assert len(stream) == 64
    sticky = (11,)                      # one permanently poisoned request
    plan = FaultPlan.random(seed=2026, n_requests=len(stream), rate=0.10,
                            sticky_rids=sticky)
    assert len(plan) > 3                # the 10% draw actually found victims

    # Warm every executable so both measured replays are steady-state:
    # one clean pass (scheduler tiers + audit cross-executors) and one
    # chaos pass (the recovery paths introduce bisection-half batch
    # shapes the clean pass never compiles).
    _replay(stream)
    _replay(stream, injector=FaultInjector(plan))

    wall_clean, tickets_clean, _ = _replay(stream)
    assert all(t.done() for t in tickets_clean)
    assert all(t.error() is None for t in tickets_clean)

    inj = FaultInjector(plan)
    wall_chaos, tickets, sched = _replay(stream, injector=inj)

    # (a) every ticket resolved -- no orphans, no hangs.
    assert all(t.done() for t in tickets)

    # (b)+(c) every non-quarantined request served bit-exactly equal to
    # the stepwise oracle; the sticky request resolved with the typed
    # quarantine error (never a corrupt/failed-dispatch result).
    failed = {t.rid: t.error() for t in tickets if t.error() is not None}
    assert set(failed) == set(sticky), failed
    assert isinstance(failed[sticky[0]], QuarantinedError)
    for t, (_, req) in zip(tickets, stream):
        if t.rid in failed:
            continue
        assert np.array_equal(t.result().memory, _oracle_memory(req)), \
            f"rid {t.rid} not bit-exact vs the stepwise oracle"

    # The plan's faults really fired and recovery really ran.
    assert inj.injected >= len(plan) - 1    # sticky fires many times
    assert sched.stats.recovered > 0
    assert sched.stats.quarantines == 1

    # (d) steady-state throughput within 2x of fault-free.
    assert wall_chaos <= 2.0 * wall_clean + 0.05, \
        (wall_chaos, wall_clean, sched.stats)


def test_chaos_background_stream_with_worker_kill():
    """Background-mode chaos: injected worker death mid-stream + faults;
    the supervisor restarts the worker and every ticket still resolves."""
    stream = request_stream(mix=_QUICK_MIX)
    plan = FaultPlan.random(seed=3, n_requests=len(stream), rate=0.2,
                            worker_kills=1)
    inj = FaultInjector(plan)
    sched = MVEScheduler(CFG, promote_after=None, background=True,
                         injector=inj, audit_rate=1.0)
    tickets = [sched.submit(r.program, r.memory) for _, r in stream]
    results = [t.result(timeout=60) for t in tickets]
    assert len(results) == len(stream)
    for t, (_, req) in zip(tickets, stream):
        assert np.array_equal(t.result().memory, _oracle_memory(req))
    sched.close()


# -- bisection + quarantine --------------------------------------------------

def test_sticky_request_is_bisected_out_and_quarantined():
    reqs = _daxpy_reqs(4)
    plan = FaultPlan([FaultSpec(site="dispatch", kind="error", rid=2,
                                times=-1)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj) as s:
        ts = [s.submit(r.program, r.memory) for r in reqs]
        s.drain()
        assert s.stats.bisections > 0
        with pytest.raises(QuarantinedError) as ei:
            ts[2].result()
        assert ei.value.attempts > 1            # it really was retried
        for i in (0, 1, 3):                     # siblings unharmed, exact
            assert np.array_equal(ts[i].result().memory,
                                  _oracle_memory(reqs[i]))
        # Re-submission while quarantined is rejected with the typed error.
        t = s.submit(reqs[2].program, reqs[2].memory)
        s.drain()
        assert isinstance(t.error(), QuarantinedError)
        assert s.stats.quarantine_rejects == 1


def test_quarantine_cooldown_allows_probe():
    reqs = _daxpy_reqs(1)
    plan = FaultPlan([FaultSpec(site="dispatch", kind="error", rid=0,
                                times=-1)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj,
                      quarantine_cooldown_s=0.0) as s:
        t = s.submit(reqs[0].program, reqs[0].memory)
        s.drain()
        assert isinstance(t.error(), QuarantinedError)
        # Cooldown of 0: the next submission probes again (and, the fault
        # being rid-bound, a *fresh* rid now succeeds).
        t2 = s.submit(reqs[0].program, reqs[0].memory)
        s.drain()
        assert t2.error() is None
        assert np.array_equal(t2.result().memory, _oracle_memory(reqs[0]))


# -- retry / breaker / degradation ladder ------------------------------------

def test_transient_fault_recovers_via_retry_bit_exact():
    reqs = _daxpy_reqs(1)
    plan = FaultPlan([FaultSpec(site="dispatch", kind="error", rid=0)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj) as s:
        t = s.submit(reqs[0].program, reqs[0].memory)
        s.drain()
        assert np.array_equal(t.result().memory, _oracle_memory(reqs[0]))
        assert s.stats.retries >= 1
        assert s.stats.recovered == 1


def test_open_breaker_degrades_to_oracle_tier():
    """A tier that keeps failing opens its breaker; traffic degrades down
    the ladder and is served by the stepwise oracle — still bit-exact."""
    reqs = _daxpy_reqs(3)
    # Unshielded vm dispatches always fail; recovery paths are shielded,
    # but the breaker (threshold=1) opens on the very first failure.
    plan = FaultPlan([FaultSpec(site="dispatch", kind="error", tier="vm",
                                times=-1)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj,
                      breaker=CircuitBreaker(threshold=1, cooldown_s=60.0)
                      ) as s:
        ts = []
        for r in reqs:
            ts.append(s.submit(r.program, r.memory))
            s.drain()
        for t, r in zip(ts, reqs):
            assert np.array_equal(t.result().memory, _oracle_memory(r))
        assert s.stats.breaker_opens >= 1
        assert s.stats.oracle_serves >= 1       # ladder bottomed out
        assert s.stats.demotions >= 1
        assert any(t.result().tier == "oracle" for t in ts)
        health = s.health()
        assert health["breakers"]["open"]       # visible in the snapshot


def test_failed_promotion_does_not_fail_requests():
    reqs = [PATTERNS["daxpy"](seed=1) for _ in range(4)]
    plan = FaultPlan([FaultSpec(site="compile", kind="error", times=-1)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=2, injector=inj) as s:
        ts = [s.submit(r.program, r.memory) for r in reqs]
        s.drain()
        for t, r in zip(ts, reqs):
            assert np.array_equal(t.result().memory, _oracle_memory(r))
        assert s.stats.promotion_failures >= 1
        assert s.stats.promotions == 0          # fused tier never came up


def test_deep_engine_fault_hook_recovers():
    """Faults injected *inside* the engine (via the vm fault hook) surface
    like any dispatch failure and recover through the same ladder."""
    reqs = _daxpy_reqs(2)
    plan = FaultPlan([FaultSpec(site="engine.dispatch", kind="error")])
    inj = FaultInjector(plan)
    prev = engine.set_fault_hook(inj.engine_hook)
    try:
        with MVEScheduler(CFG, promote_after=None, injector=inj) as s:
            ts = [s.submit(r.program, r.memory) for r in reqs]
            s.drain()
            for t, r in zip(ts, reqs):
                assert np.array_equal(t.result().memory, _oracle_memory(r))
            assert s.stats.recovered >= 1
    finally:
        engine.set_fault_hook(prev)
    assert any(f["site"] == "engine.dispatch" for f in inj.fired)


def test_executor_error_taxonomy():
    assert issubclass(engine.CompileError, engine.ExecutorError)
    assert issubclass(engine.DispatchError, engine.ExecutorError)
    assert issubclass(engine.FinalizeError, engine.ExecutorError)
    assert issubclass(engine.ExecutorError, RuntimeError)


# -- bit-flips + audit -------------------------------------------------------

def test_bitflip_is_caught_and_corrected_by_audit():
    reqs = _daxpy_reqs(4)
    plan = FaultPlan([FaultSpec(site="finalize", kind="bitflip", rid=1,
                                word=5, bit=12)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj,
                      audit_rate=1.0, audit_method="cross") as s:
        ts = [s.submit(r.program, r.memory) for r in reqs]
        s.drain()
        assert s.stats.audit_corrected == 1
        for t, r in zip(ts, reqs):              # corrected result served
            assert np.array_equal(t.result().memory, _oracle_memory(r))


def test_bitflip_without_audit_is_silent():
    """The negative control: the SRAM cell-fault model is *silent* —
    without the audit the corrupted result is served as-is."""
    reqs = _daxpy_reqs(1)
    plan = FaultPlan([FaultSpec(site="finalize", kind="bitflip", rid=0,
                                word=5, bit=12)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj) as s:
        t = s.submit(reqs[0].program, reqs[0].memory)
        s.drain()
        assert not np.array_equal(t.result().memory,
                                  _oracle_memory(reqs[0]))


def test_straggler_latency_is_injected_and_logged():
    reqs = _daxpy_reqs(1)
    slept = []
    plan = FaultPlan([FaultSpec(site="dispatch", kind="straggler", rid=0,
                                latency_s=0.25)])
    inj = FaultInjector(plan, sleep=slept.append)
    with MVEScheduler(CFG, promote_after=None, injector=inj) as s:
        t = s.submit(reqs[0].program, reqs[0].memory)
        s.drain()
        assert t.error() is None
    assert slept == [0.25]
    assert _fired_sig(inj) == [("dispatch", "straggler", 0)]


# -- deadlines / admission / cancellation / close ----------------------------

def test_expired_deadline_resolves_typed_error():
    reqs = _daxpy_reqs(1)
    with MVEScheduler(CFG, promote_after=None) as s:
        t = s.submit(reqs[0].program, reqs[0].memory, deadline_s=0.0)
        time.sleep(0.002)
        s.drain()
        with pytest.raises(DeadlineExceededError):
            t.result()
        assert s.stats.deadline_misses == 1


def test_shed_admission_resolves_overflow_with_queue_full():
    reqs = _daxpy_reqs(5)
    with MVEScheduler(CFG, promote_after=None, max_queue=2,
                      admission="shed") as s:
        ts = [s.submit(r.program, r.memory) for r in reqs]
        shed = [t for t in ts if isinstance(t.error(), QueueFullError)]
        assert len(shed) == 3
        assert s.stats.sheds == 3
        s.drain()
        served = [t for t in ts if t.error() is None]
        assert len(served) == 2
        for t in served:
            assert t.result().batch_size >= 1


def test_block_admission_backpressures_until_space():
    reqs = _daxpy_reqs(6)
    with MVEScheduler(CFG, promote_after=None, background=True,
                      max_queue=2, admission="block") as s:
        ts = [s.submit(r.program, r.memory) for r in reqs]
        for t, r in zip(ts, reqs):
            assert np.array_equal(t.result(timeout=30).memory,
                                  _oracle_memory(r))
        assert s.stats.sheds == 0


def test_cancel_pending_ticket():
    reqs = _daxpy_reqs(2)
    with MVEScheduler(CFG, promote_after=None) as s:
        t0 = s.submit(reqs[0].program, reqs[0].memory)
        t1 = s.submit(reqs[1].program, reqs[1].memory)
        assert t0.cancel()
        s.drain()
        with pytest.raises(CancelledError):
            t0.result()
        assert t1.error() is None               # sibling unaffected
        assert not t1.cancel()                  # lost the race: already done
        assert t1.error() is None               # resolution stands


def test_close_resolves_pending_tickets_instead_of_hanging():
    reqs = _daxpy_reqs(2)
    s = MVEScheduler(CFG, promote_after=None)
    t0 = s.submit(reqs[0].program, reqs[0].memory)
    s.close(drain=False)
    with pytest.raises(SchedulerClosedError):
        t0.result(timeout=1)
    with pytest.raises(SchedulerClosedError):
        s.submit(reqs[1].program, reqs[1].memory)


def test_close_with_drain_serves_whats_pending():
    reqs = _daxpy_reqs(2)
    s = MVEScheduler(CFG, promote_after=None)
    ts = [s.submit(r.program, r.memory) for r in reqs]
    s.close()                                   # default drain=True
    for t, r in zip(ts, reqs):
        assert np.array_equal(t.result().memory, _oracle_memory(r))


def test_result_timeout_does_not_orphan_the_ticket():
    reqs = _daxpy_reqs(1)
    with MVEScheduler(CFG, promote_after=None) as s:
        t = s.submit(reqs[0].program, reqs[0].memory)
        with pytest.raises(TimeoutError):
            t.result(timeout=0.001)             # nothing drained yet
        s.drain()
        assert np.array_equal(t.result().memory, _oracle_memory(reqs[0]))


# -- worker supervision ------------------------------------------------------

def test_worker_death_requeues_and_supervisor_restarts():
    reqs = _daxpy_reqs(8)
    plan = FaultPlan([FaultSpec(site="worker", kind="kill")])
    inj = FaultInjector(plan)
    s = MVEScheduler(CFG, promote_after=None, background=True, injector=inj)
    ts = [s.submit(r.program, r.memory) for r in reqs]
    for t, r in zip(ts, reqs):
        assert np.array_equal(t.result(timeout=30).memory,
                              _oracle_memory(r))
    assert s.stats.worker_restarts == 1
    assert s.health()["worker"]["alive"]
    assert inj.counts() == {"kill": 1}
    s.close()


def test_program_server_surfaces_typed_errors_per_request():
    """The launch-layer facade: one quarantined request finishes with
    ``req.error`` set; it never aborts the drain of its neighbours."""
    from repro.launch.serve import MVEProgramServer

    reqs = _daxpy_reqs(3)
    plan = FaultPlan([FaultSpec(site="dispatch", kind="error", rid=1,
                                times=-1)])
    srv = MVEProgramServer(promote_after=None,
                           injector=FaultInjector(plan))
    handles = [srv.submit(r.program, r.memory) for r in reqs]
    done = srv.run_until_drained()
    assert len(done) == 3
    assert isinstance(handles[1].error, QuarantinedError)
    assert handles[1].result is None
    for i in (0, 2):
        assert handles[i].error is None
        assert np.array_equal(handles[i].result.memory,
                              _oracle_memory(reqs[i]))
    assert srv.health()["quarantine"]["total"] == 1
    srv.scheduler.close()


# -- health snapshot ---------------------------------------------------------

def test_health_snapshot_shape():
    reqs = _daxpy_reqs(2)
    plan = FaultPlan([FaultSpec(site="dispatch", kind="error", rid=0)])
    inj = FaultInjector(plan)
    with MVEScheduler(CFG, promote_after=None, injector=inj,
                      audit_rate=1.0) as s:
        for r in reqs:
            s.submit(r.program, r.memory)
        s.drain()
        h = s.health()
    for key in ("pending", "closed", "worker", "stragglers", "breakers",
                "quarantine", "counters", "audit", "injected"):
        assert key in h, key
    assert h["pending"] == 0
    assert h["counters"]["requests"] == 2
    # both batch-mates of the failed group dispatch count as recovered
    assert h["counters"]["recovered"] == 2
    assert h["injected"] == {"error": 1}
    assert h["audit"]["checked"] >= 1
