"""Mamba2/SSD: chunked algorithm vs naive recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.ssm import (mamba_block, ssd_chunked, ssd_decode_step,
                              ssd_naive)

RNG = np.random.default_rng(1)


def _case(b, s, h, p, g, n):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(0.1 + 0.4 * RNG.random((b, s, h)).astype(np.float32))
    a_log = jnp.asarray(RNG.standard_normal(h).astype(np.float32) * 0.3)
    bmat = jnp.asarray(RNG.standard_normal((b, s, g, n)).astype(np.float32))
    cmat = jnp.asarray(RNG.standard_normal((b, s, g, n)).astype(np.float32))
    return x, dt, a_log, bmat, cmat


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.sampled_from([8, 12, 16]),
       st.sampled_from([2, 4]), st.sampled_from([4, 8]),
       st.sampled_from([1, 2]), st.sampled_from([4, 8]),
       st.sampled_from([4, 8]))
def test_chunked_matches_naive(b, s, h, p, g, n, chunk):
    x, dt, a_log, bmat, cmat = _case(b, s, h, p, g, n)
    y1, st1 = ssd_chunked(x, dt, a_log, bmat, cmat, chunk)
    y2, st2 = ssd_naive(x, dt, a_log, bmat, cmat)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st1, st2, rtol=2e-4, atol=2e-4)


def test_initial_state_threading():
    x, dt, a_log, bmat, cmat = _case(2, 16, 4, 4, 1, 8)
    y_full, st_full = ssd_chunked(x, dt, a_log, bmat, cmat, 8)
    # split in two halves, thread the state
    y1, st1 = ssd_chunked(x[:, :8], dt[:, :8], a_log, bmat[:, :8],
                          cmat[:, :8], 8)
    y2, st2 = ssd_chunked(x[:, 8:], dt[:, 8:], a_log, bmat[:, 8:],
                          cmat[:, 8:], 8, initial_state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st2, st_full, rtol=2e-4, atol=2e-4)


def test_decode_step_matches_naive():
    x, dt, a_log, bmat, cmat = _case(2, 6, 4, 4, 2, 4)
    _, want_state = ssd_naive(x, dt, a_log, bmat, cmat)
    state = jnp.zeros((2, 2, 2, 4, 4), jnp.float32)
    ys = []
    for t in range(6):
        y, state = ssd_decode_step(x[:, t], dt[:, t], a_log,
                                   bmat[:, t], cmat[:, t], state)
        ys.append(y)
    want_y, _ = ssd_naive(x, dt, a_log, bmat, cmat)
    np.testing.assert_allclose(jnp.stack(ys, 1), want_y,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(state, want_state, rtol=2e-4, atol=2e-4)


def test_mamba_block_train_decode_consistency():
    """Prefill then one decode step == forward over seq+1 tokens."""
    from repro.configs import get_config
    from repro.models.common import materialize_tree
    from repro.models.lm import _ssm_defs

    cfg = get_config("mamba2-2.7b", reduced=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, ssm_chunk=8)
    defs = _ssm_defs(cfg, 1)
    params = materialize_tree(defs, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda a: a[0].astype(jnp.float32), params)

    x = jnp.asarray(RNG.standard_normal(
        (2, 17, cfg.d_model)).astype(np.float32))
    y_full, _ = mamba_block(params, x, cfg)

    y_pre, state = mamba_block(params, x[:, :16], cfg)
    y_dec, _ = mamba_block(params, x[:, 16:17], cfg, state=state)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 16],
                               rtol=2e-3, atol=2e-3)
