"""Fused compiled engine vs the step-interpreter oracle (docs/ENGINE.md).

The equivalence contract: for every registered Section-IV pattern the
engine must produce bit-identical memory, registers and Tag latch, and an
identical cost-model trace (every TraceEvent field, including the exact
cache-line counts of random-base accesses).  This file pins
``mode="fused"``; the program-as-data VM (the default mode) has its own
oracle suite in ``tests/test_vm.py``, which covers both executors.
"""
import numpy as np
import pytest

from repro.core import MVEConfig, MVEInterpreter, compile_program, isa
from repro.core.engine import clear_cache
from repro.core.isa import DType
from repro.core.patterns import (PATTERNS, run_pattern, run_pattern_batch)

CFG = MVEConfig()
ORACLE = MVEInterpreter(CFG, compiled=False)


def _assert_equivalent(program, memory):
    mem_i, st_i = ORACLE.run_stepwise(program, memory)
    cp = compile_program(program, CFG, mode="fused")
    mem_e, st_e = cp.run(memory)
    np.testing.assert_array_equal(np.asarray(mem_i), np.asarray(mem_e))
    assert set(st_i.regs) == set(st_e.regs)
    for r in st_i.regs:
        np.testing.assert_array_equal(np.asarray(st_i.regs[r]),
                                      np.asarray(st_e.regs[r]))
    np.testing.assert_array_equal(np.asarray(st_i.tag),
                                  np.asarray(st_e.tag))
    assert len(st_i.trace) == len(st_e.trace)
    for i, (a, b) in enumerate(zip(st_i.trace, st_e.trace)):
        assert a.same_as(b), (i, a, b)
    return mem_e, st_e


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_engine_matches_interpreter(name):
    """Bit-identical memory + identical trace on every pattern."""
    run = PATTERNS[name]()
    mem_e, st_e = _assert_equivalent(run.program, run.memory)
    run.check(np.asarray(mem_e), st_e)


def test_engine_predicated_and_tag():
    """Tag-latch semantics survive compilation (compare + predicated op)."""
    mem = np.zeros(16)
    mem[:8] = np.arange(8)
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(DType.DW, 1, 0, 1),
            isa.vsetdup(DType.DW, 0, 3),
            isa.vcmp(isa.Op.GT, DType.DW, 1, 0),
            isa.vsetdup(DType.DW, 2, 1),
            isa.vadd(DType.DW, 1, 1, 2, predicated=True)]
    _assert_equivalent(prog, mem)


def test_engine_masked_store_and_reduction_mask():
    """Dimension-level masking on stores compiles correctly."""
    mem = np.zeros(64)
    mem[:32] = np.arange(32)
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 8), isa.vsetdiml(1, 4),
            isa.vsld(DType.F, 0, 0, 1, 2),
            isa.vunsetmask(1), isa.vunsetmask(3),
            isa.vsst(DType.F, 0, 32, 1, 2)]
    mem_e, _ = _assert_equivalent(prog, mem)
    got = np.asarray(mem_e)
    np.testing.assert_array_equal(got[40:48], 0)
    np.testing.assert_array_equal(got[48:56], np.arange(16, 24))


def test_compile_cache_returns_same_object():
    run = PATTERNS["daxpy"]()
    a = compile_program(run.program, CFG)
    b = compile_program(list(run.program), CFG)
    assert a is b
    clear_cache()
    c = compile_program(run.program, CFG)
    assert c is not a


def test_run_pattern_compiled_equals_stepwise():
    run = PATTERNS["alpha_blend"]()
    mem_c, st_c = run_pattern(run, CFG, compiled=True)
    mem_s, st_s = run_pattern(run, CFG, compiled=False)
    np.testing.assert_array_equal(np.asarray(mem_c), np.asarray(mem_s))
    assert len(st_c.trace) == len(st_s.trace)


def test_vmap_batch_matches_per_image_runs():
    """One vmapped call over stacked memory images == N separate runs."""
    seeds = [0, 1, 2, 3]
    runs, mems = run_pattern_batch("daxpy", seeds, CFG)
    mems = np.asarray(mems)
    assert mems.shape[0] == len(seeds)
    for r, got in zip(runs, mems):
        mem_i, _ = ORACLE.run_stepwise(r.program, r.memory)
        np.testing.assert_array_equal(np.asarray(mem_i), got)
        r.check(got, None)


def test_vmap_batch_random_base_pointers_are_dynamic():
    """Random-base (Eq. 1) pointer arrays are data, not compile-time
    constants: a batch whose images carry different pointer tables must
    still be correct under one vmapped compilation."""
    seeds = [0, 7]
    runs, mems = run_pattern_batch("upsample", seeds, CFG)
    mems = np.asarray(mems)
    assert runs[0].program == runs[1].program   # same program, diff ptrs
    for r, got in zip(runs, mems):
        r.check(got, None)


def test_static_trace_exact_without_random_ops():
    """For purely strided programs the whole trace falls out of
    compilation — no execution needed."""
    run = PATTERNS["daxpy"]()
    cp = compile_program(run.program, CFG)
    _, st = cp.run(run.memory)
    assert len(cp.static_trace) == len(st.trace)
    for a, b in zip(cp.static_trace, st.trace):
        assert a.same_as(b)
