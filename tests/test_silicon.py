"""Tests for ``repro.silicon``: the parametric SRAM energy/area model,
the calibration contract, the sweep cache, and the Pareto autotuner.

The load-bearing invariant is **golden preservation**: deriving
``EnergyParams`` from the silicon model at the default Table IV geometry
must be *byte-identical* to the calibrated ``DEFAULT_ENERGY`` constants,
so re-pricing the fig7/table2 claims with derived params reproduces the
frozen golden rows exactly (``test_goldens_byte_identical_with_derived``).
"""
import dataclasses
import json
import os

import pytest

from repro.core import cost
from repro.core.isa import ProgramError
from repro.core.machine import MVEConfig
from repro.silicon import area, autotune, params, sram, sweep

DEFAULT = MVEConfig()


# ---------------------------------------------------------------------------
# MVEConfig validation (satellite: fail loud, not nonsense lane counts)
# ---------------------------------------------------------------------------

class TestMVEConfigValidation:
    def test_default_is_valid(self):
        MVEConfig()

    @pytest.mark.parametrize("field,value", [
        ("bitlines", 100), ("bitlines", 0), ("bitlines", -256),
        ("wordlines", 3), ("wordlines", 0),
        ("bh_segment_bits", 5),
    ])
    def test_power_of_two_fields(self, field, value):
        with pytest.raises(ProgramError, match="power of two"):
            MVEConfig(**{field: value})

    def test_arrays_must_group_into_cbs(self):
        with pytest.raises(ProgramError, match="arrays_per_cb"):
            MVEConfig(num_arrays=30, arrays_per_cb=4)

    def test_unknown_scheme(self):
        with pytest.raises(ProgramError, match="unknown compute scheme"):
            MVEConfig(scheme="quantum")

    def test_bad_array_count(self):
        with pytest.raises(ProgramError, match="positive int"):
            MVEConfig(num_arrays=0)

    def test_bad_frequency(self):
        with pytest.raises(ProgramError, match="freq_ghz"):
            MVEConfig(freq_ghz=0.0)

    def test_valid_variants_still_construct(self):
        for na in (8, 16, 32, 64):
            MVEConfig(num_arrays=na)
        MVEConfig(bh_segment_bits=8)


# ---------------------------------------------------------------------------
# SRAM model monotonicity
# ---------------------------------------------------------------------------

class TestSRAMModel:
    def test_energy_grows_with_bitlines(self):
        a = sram.estimate(sram.SRAMSpec(bitlines=128))
        b = sram.estimate(sram.SRAMSpec(bitlines=256))
        c = sram.estimate(sram.SRAMSpec(bitlines=512))
        assert a.compute_cycle_pj < b.compute_cycle_pj < c.compute_cycle_pj
        assert a.total_area_mm2 < b.total_area_mm2 < c.total_area_mm2

    def test_energy_grows_with_wordlines(self):
        a = sram.estimate(sram.SRAMSpec(wordlines=128))
        b = sram.estimate(sram.SRAMSpec(wordlines=256))
        c = sram.estimate(sram.SRAMSpec(wordlines=1024))
        # deeper bitlines -> more capacitance per access, more cells
        assert a.compute_cycle_pj < b.compute_cycle_pj < c.compute_cycle_pj
        assert a.total_area_mm2 < b.total_area_mm2 < c.total_area_mm2
        assert a.leakage_mw < b.leakage_mw < c.leakage_mw

    def test_shrinks_with_tech_node(self):
        small = sram.estimate(sram.SRAMSpec(tech_nm=7.0))
        big = sram.estimate(sram.SRAMSpec(tech_nm=16.0))
        assert small.compute_cycle_pj < big.compute_cycle_pj
        assert small.total_area_mm2 < big.total_area_mm2
        assert small.read_pj_per_byte < big.read_pj_per_byte

    def test_non_physical_spec_rejected(self):
        with pytest.raises(ValueError):
            sram.SRAMSpec(bitlines=0)
        with pytest.raises(ValueError):
            sram.SRAMSpec(tech_nm=-7.0)

    def test_memoized_identity(self):
        # equal specs return the *same* object — the x/x == 1.0 anchor
        assert sram.estimate(sram.SRAMSpec()) is sram.estimate(
            sram.SRAMSpec())


# ---------------------------------------------------------------------------
# Derived EnergyParams: calibration contract + scheme factors
# ---------------------------------------------------------------------------

class TestDerivedParams:
    def test_default_geometry_is_byte_identical(self):
        ep, source = params.derived_energy(DEFAULT)
        assert ep == cost.DEFAULT_ENERGY
        assert source.startswith("derived:")

    def test_derive_classmethod(self):
        assert cost.EnergyParams.derive(DEFAULT) == cost.DEFAULT_ENERGY

    def test_scheme_factors_order(self):
        by_scheme = {s: params.derived_energy(DEFAULT, s)[0]
                     for s in ("bs", "bp", "bh", "ac")}
        e = {s: p.e_array_cycle for s, p in by_scheme.items()}
        # bs is the anchor; peripheral-heavier schemes cost more per cycle
        assert e["bs"] < e["bh"] < e["bp"] < e["ac"]
        # horizontal layouts skip (part of) the TMU transpose
        assert by_scheme["bp"].e_l2_byte < by_scheme["bh"].e_l2_byte \
            < by_scheme["bs"].e_l2_byte

    def test_core_constants_never_scale(self):
        ep, _ = params.derived_energy(MVEConfig(num_arrays=64,
                                                bitlines=512))
        d = cost.DEFAULT_ENERGY
        assert (ep.e_scalar, ep.e_simd_op, ep.e_l1_byte) == \
            (d.e_scalar, d.e_simd_op, d.e_l1_byte)
        assert (ep.e_gpu_flop, ep.e_gpu_launch, ep.e_gpu_copy_byte) == \
            (d.e_gpu_flop, d.e_gpu_launch, d.e_gpu_copy_byte)

    def test_geometry_scales_in_cache_constants(self):
        big, _ = params.derived_energy(MVEConfig(bitlines=512))
        assert big.e_array_cycle > cost.DEFAULT_ENERGY.e_array_cycle

    def test_digest_distinguishes_points(self):
        a = params.geometry_digest(DEFAULT, "bs")
        b = params.geometry_digest(DEFAULT, "bp")
        c = params.geometry_digest(MVEConfig(bitlines=512), "bs")
        assert len({a, b, c}) == 3

    def test_unknown_scheme_rejected(self):
        with pytest.raises(KeyError):
            params.derived_energy(DEFAULT, "quantum")


# ---------------------------------------------------------------------------
# params_source provenance through targets
# ---------------------------------------------------------------------------

class TestProvenance:
    def test_incache_reports_derived(self):
        import repro.targets as targets
        from repro.core.patterns import PATTERNS
        run = PATTERNS["daxpy"]()
        art = targets.compile(run.program, target="mve-bs")
        rep = art.energy()
        assert rep.params_source == params.derived_energy(DEFAULT, "bs")[1]

    def test_neon_reports_default(self):
        import repro.targets as targets
        from repro.core.patterns import PATTERNS
        run = PATTERNS["daxpy"]()
        assert targets.compile(run.program,
                               target="neon").energy().params_source \
            == "default"

    def test_explicit_params_opt_out(self):
        import repro.targets as targets
        custom = dataclasses.replace(cost.DEFAULT_ENERGY, e_issue=60.0)
        tgt = targets.InCacheTarget("adhoc-fixed", scheme="bs",
                                    energy_params=custom)
        ep, source = tgt.energy_model(DEFAULT)
        assert ep is custom and source == "default"


# ---------------------------------------------------------------------------
# Golden preservation: derived default == frozen fig7/table2 rows
# ---------------------------------------------------------------------------

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_traces.json")


class TestGoldenPreservation:
    def test_goldens_byte_identical_with_derived(self, monkeypatch):
        """Re-price the frozen claims with *derived* params: rows must
        match the golden file byte-for-byte (the calibration contract
        end-to-end, not just params equality)."""
        from benchmarks import paper_claims
        derived = cost.EnergyParams.derive(DEFAULT)
        monkeypatch.setattr(paper_claims, "EP", derived)
        with open(GOLDEN_PATH) as fh:
            golden = json.load(fh)
        got = {"table2": {name: [us, text] for name, us, text
                          in paper_claims.table2_latencies()},
               "fig7": {name: [us, text] for name, us, text
                        in paper_claims.fig7_neon()}}
        for section in ("table2", "fig7"):
            assert golden[section], section
            for name, row in golden[section].items():
                assert got[section][name] == row, name


# ---------------------------------------------------------------------------
# Area report
# ---------------------------------------------------------------------------

class TestArea:
    def test_default_matches_table_v(self):
        ar = area.area_report()
        for k, v in area.TABLE_V_MM2_7NM.items():
            assert ar.components[k] == pytest.approx(v, rel=1e-12)
        assert 2.0 <= ar.overhead_pct <= 6.0
        assert ar.overhead_pct == pytest.approx(3.56, abs=0.05)
        assert ar.neon_overhead_pct == pytest.approx(16.27, abs=0.1)

    def test_area_scales_with_geometry(self):
        small = area.area_report(MVEConfig(num_arrays=16))
        big = area.area_report(MVEConfig(num_arrays=64))
        assert small.added_mm2 < area.area_report().added_mm2 \
            < big.added_mm2

    def test_area_shrinks_with_node(self):
        assert area.area_report(tech_nm=5.0).added_mm2 \
            < area.area_report(tech_nm=7.0).added_mm2

    def test_storage_arrays_amortize(self):
        plain = area.area_report()
        split = area.area_report(storage_arrays=32)
        assert split.added_mm2 == plain.added_mm2          # same additions
        assert split.l2_mm2 > plain.l2_mm2                 # bigger macro
        assert split.overhead_vs_cache_pct < plain.overhead_vs_cache_pct

    def test_bicameral_target_registered(self):
        import repro.targets as targets
        assert "mve-bicameral" in targets.list_targets()
        tgt = targets.get_target("mve-bicameral")
        ar = tgt.area_report()
        assert ar.overhead_vs_cache_pct \
            < area.area_report().overhead_vs_cache_pct

    def test_bicameral_bit_exact_and_equal_priced(self):
        """The compute partition IS the default machine: identical
        results *and* identical pricing to mve-bs."""
        import numpy as np
        import repro.targets as targets
        from repro.core.patterns import PATTERNS
        run = PATTERNS["daxpy"]()
        a = targets.compile(run.program, target="mve-bs")
        b = targets.compile(run.program, target="mve-bicameral")
        ma, _ = a.run(run.memory)
        mb, _ = b.run(run.memory)
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
        assert a.timeline().total_cycles == b.timeline().total_cycles
        assert a.energy().total_pj == b.energy().total_pj


# ---------------------------------------------------------------------------
# Sweep cache
# ---------------------------------------------------------------------------

class TestSweepCache:
    def test_cold_equals_warm(self, tmp_path):
        path = str(tmp_path / "records.json")
        cold = sweep.sweep(cache_path=path)
        assert os.path.exists(path)
        warm = sweep.sweep(cache_path=path)
        assert warm == cold
        assert len(cold) == len(sweep.default_grid())

    def test_version_mismatch_invalidates(self, tmp_path):
        path = str(tmp_path / "records.json")
        sweep.sweep(cache_path=path)
        with open(path) as fh:
            doc = json.load(fh)
        doc["model_version"] = "0-stale"
        with open(path, "w") as fh:
            json.dump(doc, fh)
        assert sweep.load_cache(path) is None
        again = sweep.sweep(cache_path=path)        # recomputes + rewrites
        assert sweep.load_cache(path) is not None
        assert again == sweep.sweep(cache_path=path)

    def test_corrupt_cache_recovers(self, tmp_path):
        path = str(tmp_path / "records.json")
        with open(path, "w") as fh:
            fh.write("{not json")
        records = sweep.sweep(cache_path=path)
        assert len(records) == len(sweep.default_grid())

    def test_subset_served_from_cache(self, tmp_path):
        path = str(tmp_path / "records.json")
        full = sweep.sweep(cache_path=path)
        point = sweep.default_grid()[0]
        sub = sweep.sweep(points=[point], cache_path=path)
        assert sub[point.key] == full[point.key]


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

QUICK_CANDIDATES = [
    autotune.Candidate(scheme=s, num_arrays=na, bitlines=bl)
    for s in ("bs", "bp") for na, bl in ((32, 256), (64, 256))
]


class TestAutotune:
    def test_deterministic(self):
        a = autotune.autotune("daxpy", QUICK_CANDIDATES)
        b = autotune.autotune("daxpy", QUICK_CANDIDATES)
        assert a == b

    def test_front_is_non_dominated(self):
        res = autotune.autotune("daxpy", QUICK_CANDIDATES)
        assert res.front
        for p in res.front:
            for q in res.points:
                assert not (q.cycles <= p.cycles
                            and q.energy_pj <= p.energy_pj
                            and q.area_mm2 <= p.area_mm2
                            and (q.cycles < p.cycles
                                 or q.energy_pj < p.energy_pj
                                 or q.area_mm2 < p.area_mm2))

    def test_default_candidates_meet_floor(self):
        cands = autotune.default_candidates()
        assert len(cands) >= 24
        assert all(c.num_arrays * c.bitlines >= autotune.MIN_LANES
                   for c in cands)

    def test_stream_weights_matter(self):
        light = autotune.autotune_stream([("daxpy", 1)], QUICK_CANDIDATES)
        heavy = autotune.autotune_stream([("daxpy", 5)], QUICK_CANDIDATES)
        for lp, hp in zip(light.points, heavy.points):
            assert hp.cycles == pytest.approx(5 * lp.cycles)

    def test_points_carry_derived_provenance(self):
        res = autotune.autotune("daxpy", QUICK_CANDIDATES)
        for p in res.points:
            assert p.params_source == params.derived_energy(
                p.candidate.cfg())[1]

    def test_best_respects_key(self):
        res = autotune.autotune("daxpy", QUICK_CANDIDATES)
        assert res.best("cycles").cycles == min(p.cycles for p in res.front)
        assert res.best("energy_pj").energy_pj == min(p.energy_pj
                                                      for p in res.front)
