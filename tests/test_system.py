"""End-to-end behaviour: the paper's pipeline (MVE programs -> cost model
-> claims) and the framework pipeline (data -> train -> serve) both work."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.core import MVEInterpreter, cost, rvv
from repro.core.patterns import PATTERNS
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.launch.train import TrainLoopConfig, train_loop
from repro.optim import AdamWConfig


def test_paper_pipeline_end_to_end():
    """Run a real kernel (GEMM w/ replication) through the full MVE stack:
    program -> interpreter (correctness) -> trace -> BS cost model ->
    ISA comparison, like the paper's Figure 10 flow."""
    run = PATTERNS["gemm"](n_rows=64, k=8, m=64)
    interp = MVEInterpreter()
    mem_after, state = interp.run(run.program, run.memory)
    run.check(np.asarray(mem_after), state)

    tl_mve = cost.simulate(state.trace, interp.cfg)
    trace_rvv, stats = rvv.compile_to_rvv(run.program)
    tl_rvv = cost.simulate(trace_rvv, interp.cfg)

    assert tl_rvv.total_cycles > 2 * tl_mve.total_cycles
    mve_stats = rvv.mve_stats(run.program)
    assert stats.vector_instructions > 2 * mve_stats.vector_instructions
    assert tl_mve.lane_utilization > tl_rvv.lane_utilization


def test_framework_pipeline_train_then_serve(tmp_path):
    """Train a tiny model for a few dozen steps (loss must drop), then
    serve it with batched requests through the MVE-masked engine."""
    cfg = get_config("qwen2-0.5b", reduced=True)
    cfg = dataclasses.replace(cfg, num_layers=1)
    cell = ShapeCell("sys", 64, 4, "train")
    opt = AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=25)
    metrics = train_loop(cfg, cell,
                         TrainLoopConfig(steps=25, log_every=100,
                                         ckpt_dir=str(tmp_path),
                                         ckpt_every=25),
                         opt_cfg=opt, seed=1)
    assert metrics["loss"] < 6.0      # well below ln(512)=6.24 at init

    # restore the trained params and serve
    from repro.checkpoint import load_checkpoint
    from repro.models import LM
    model = LM(cfg)
    p_tmpl = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                          model.abstract_params())
    state, _ = load_checkpoint(
        str(tmp_path), {"params": p_tmpl,
                        "opt": {"m": p_tmpl, "v": p_tmpl,
                                "step": np.zeros((), np.int32)}})
    params = jax.tree.map(jnp.asarray, state["params"])

    engine = ContinuousBatchingEngine(cfg, params, batch_slots=2,
                                      max_seq=24)
    rng = np.random.default_rng(0)
    for i in range(3):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=3))
    done = engine.run_until_drained()
    assert len(done) == 3
    for r in done.values():
        assert len(r.output) == 3
        assert all(0 <= t < cfg.vocab_size for t in r.output)
