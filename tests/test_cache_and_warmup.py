"""Compile-cache observability + AOT warmup paths.

``engine.cache_info()`` counters, program-LRU eviction, and the
``CompiledProgram.warmup()`` / ``vm.prewarm`` ahead-of-time paths were
previously exercised only by the benchmarks; these tests pin their
contracts (ISSUE 3 satellite).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MVEConfig, cache_info, compile_program, engine,
                        isa)
from repro.core import vm as vm_mod
from repro.core.isa import DType
from repro.core.patterns import PATTERNS
from repro.runtime.scheduler import MVEScheduler

CFG = MVEConfig()


def _tiny_program(k: int):
    return [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsetdup(DType.DW, 0, k)]


def test_cache_info_hit_miss_counters():
    engine.clear_cache()
    base = cache_info()
    assert (base.program_hits, base.program_misses,
            base.program_size) == (0, 0, 0)
    p = _tiny_program(1)
    a = compile_program(p, CFG)
    info = cache_info()
    assert info.program_misses == 1 and info.program_size == 1
    b = compile_program(list(p), CFG)          # equal program, fresh list
    assert b is a
    info = cache_info()
    assert info.program_hits == 1 and info.program_misses == 1
    # a different mode is a different cache entry
    c = compile_program(p, CFG, mode="fused")
    assert c is not a
    assert cache_info().program_misses == 2


def test_program_lru_eviction(monkeypatch):
    engine.clear_cache()
    monkeypatch.setattr(engine, "_CACHE_CAPACITY", 4)
    cps = [compile_program(_tiny_program(k), CFG) for k in range(6)]
    info = cache_info()
    assert info.program_size == 4
    assert info.program_evictions == 2
    # oldest entries were evicted: recompiling program 0 is a miss...
    misses = info.program_misses
    again = compile_program(_tiny_program(0), CFG)
    assert again is not cps[0]
    assert cache_info().program_misses == misses + 1
    # ...while the most recent entry is still a hit
    assert compile_program(_tiny_program(5), CFG) is cps[5]
    # and hot entries are protected: touching program 3 before two new
    # compiles keeps it resident (LRU order, not FIFO)
    compile_program(_tiny_program(3), CFG)
    compile_program(_tiny_program(6), CFG)
    compile_program(_tiny_program(7), CFG)
    assert compile_program(_tiny_program(3), CFG) is cps[3]


def test_vm_fallback_aliases_fused_entry():
    """A VM-unsupported program compiled under mode="vm" answers the
    explicit mode="fused" lookup from the cache (no recompile)."""
    engine.clear_cache()
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8)]
    for r in range(vm_mod.N_REGS + 2):
        prog.append(isa.vsetdup(DType.DW, r, r))
    a = compile_program(prog, CFG, mode="vm")
    assert a.mode == "fused"
    hits = cache_info().program_hits
    assert compile_program(prog, CFG, mode="fused") is a
    assert cache_info().program_hits == hits + 1


def test_warmup_batch_path_no_new_compiles():
    """warmup(batch=N) AOT-compiles the vmapped executable in both
    modes; the following run_batch adds no XLA compilation."""
    run = PATTERNS["daxpy"]()
    mems = np.stack([run.memory] * 4)
    for mode in ("vm", "fused"):
        cp = compile_program(run.program, CFG, mode=mode)
        cp.warmup(run.memory.shape[0], batch=4)
        jit = (vm_mod._executor(cp._vm._signature(run.memory.shape[0]))
               .batch if mode == "vm" else cp._get_batch_jit())
        assert jit._aot, "warmup(batch=) must stash an AOT executable"
        compiles = jit.compiles
        mem_b, _, _ = cp.run_batch(mems)
        assert mem_b.shape[0] == 4
        assert jit.compiles == compiles


def test_warmup_nonfloat_dtype_warms_fused_path():
    """In vm mode, warmup() follows the same dtype routing as run():
    an int32 image geometry warms the fused executable."""
    run = PATTERNS["daxpy"]()
    cp = compile_program(run.program, CFG, mode="vm")
    before = len(cp._jit._aot)
    cp.warmup(run.memory.shape[0], dtype=jnp.int32)
    assert len(cp._jit._aot) == before + 1


def test_prewarm_background_thread():
    """prewarm(block=False) compiles on a daemon thread; after join the
    default-signature executor serves without further compiles."""
    t = vm_mod.prewarm(CFG, block=False)
    assert t is not None
    t.join(timeout=300)
    assert not t.is_alive()
    sig = vm_mod.default_signature(CFG)
    ex = vm_mod._executor(sig)
    assert ex.single._aot, "prewarm must stash the AOT executable"
    compiles = ex.single.compiles
    run = PATTERNS["daxpy"]()
    cp = compile_program(run.program, CFG, mode="vm")
    assert cp._vm._signature(run.memory.shape[0]) == sig
    cp.run(run.memory)
    assert ex.single.compiles == compiles
    # blocking prewarm is idempotent and returns None
    assert vm_mod.prewarm(CFG) is None


def test_vm_cache_counters_flow_into_engine_info():
    info = cache_info()
    v = vm_mod.cache_info()
    assert info.vm_signatures == v.signatures
    assert info.vm_hits == v.hits
    assert info.vm_xla_compiles == v.xla_compiles


def test_scheduler_shares_program_lru():
    """Scheduler submissions and fused-tier promotions land in the same
    program LRU that cache_info() reports."""
    engine.clear_cache()
    run = PATTERNS["daxpy"]()
    sched = MVEScheduler(CFG, promote_after=2)
    assert sched.cache_info() == cache_info()
    sched.submit(run.program, run.memory)
    info = cache_info()
    assert info.program_misses == 1
    sched.submit(run.program, run.memory)       # same program: LRU hit
    assert cache_info().program_hits >= 1
    sched.drain()                               # promotion compiles fused
    assert sched.stats.promotions == 1
    assert cache_info().program_size == 2       # vm entry + fused entry
