"""Golden pipeline-model regression suite.

Freezes, for every Section-IV pattern, what the pipeline model says on
the two headline uarch configs — ``mve-bs`` (the in-cache controller,
via the ``mve-bs-timed`` target) and ``mobile-core`` (via
``neon-timed``): total cycles, the per-cause stall breakdown, and the
verification envelope.  A model regression — a hazard that silently
stops being tracked, a chaining change, a duration drift — shows up as
an exact-value diff here rather than an unexplained shift in
BENCH_engine.json's ``timing`` section.

Regenerating after an *intentional* model change:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_timing_goldens.py

Cycle totals and stall counts are rounded to 2 decimals before
comparison, so equality is exact and platform-stable.
"""
import json
import os
import pathlib

import pytest

from repro import targets
from repro.core.patterns import PATTERNS

GOLDEN = pathlib.Path(__file__).parent / "data" / "timing_goldens.json"
REGEN = bool(os.environ.get("REPRO_REGEN_GOLDEN"))

#: target -> uarch config the satellite pins (docs/TIMING.md).
CONFIGS = {"mve-bs-timed": "mve-bs", "neon-timed": "mobile-core"}


def _pattern_entry(name: str) -> dict:
    run = PATTERNS[name]()
    entry = {}
    for tname, uarch in CONFIGS.items():
        art = targets.compile(run.program, target=tname)
        tl = art.timeline()                     # static trace: exact for
        assert tl.uarch == uarch                # every golden pattern
        entry[uarch] = {
            "cycles": round(tl.total_cycles, 2),
            "stalls": {k: round(v, 2) for k, v in sorted(tl.stalls.items())},
            "lower_bound": round(tl.lower_bound, 2),
            "upper_bound": round(tl.upper_bound, 2),
        }
    return entry


def _current() -> dict:
    return {"configs": sorted(CONFIGS.values()),
            "patterns": {n: _pattern_entry(n) for n in sorted(PATTERNS)}}


@pytest.fixture(scope="module")
def golden():
    if REGEN:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_current(), indent=1, sort_keys=True))
    assert GOLDEN.exists(), \
        "golden file missing - regenerate with REPRO_REGEN_GOLDEN=1"
    return json.loads(GOLDEN.read_text())


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_timing_frozen(golden, name):
    """Exact per-pattern cycles, stall breakdown, and envelope."""
    assert _pattern_entry(name) == golden["patterns"][name], \
        f"{name}: pipeline-model timing drifted"


def test_golden_covers_all_patterns_and_configs(golden):
    assert sorted(golden["patterns"]) == sorted(PATTERNS)
    assert golden["configs"] == sorted(CONFIGS.values())
    for name, entry in golden["patterns"].items():
        for uarch, rec in entry.items():
            assert rec["lower_bound"] <= rec["cycles"] \
                <= rec["upper_bound"], f"{name}/{uarch} outside envelope"
            assert set(rec["stalls"]) == {"dependency", "frontend",
                                          "memory-port", "structural"}


def test_pipeline_model_finds_overlap(golden):
    """Acceptance: across the sweep the pipeline model must price below
    the fully-serialized bound (the machine overlaps *something*) while
    staying above the ideal-issue bound."""
    for uarch in golden["configs"]:
        total = sum(e[uarch]["cycles"] for e in golden["patterns"].values())
        ub = sum(e[uarch]["upper_bound"]
                 for e in golden["patterns"].values())
        lb = sum(e[uarch]["lower_bound"]
                 for e in golden["patterns"].values())
        assert lb < total < ub, uarch
