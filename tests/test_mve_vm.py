"""MVE virtual-machine semantics vs a straight-loop numpy oracle."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import MVEConfig, MVEInterpreter, isa
from repro.core.isa import DType
from repro.core.machine import (ControlState, cbs_touched, flatten_indices,
                                lane_dim_mask)

CFG = MVEConfig()
INTERP = MVEInterpreter(CFG)


def oracle_strided_load(mem, base, dims, strides, lanes):
    """Algorithm 1 as literal nested loops."""
    out = np.zeros(lanes)
    total = int(np.prod(dims))
    for lane in range(min(total, lanes)):
        rem, addr = lane, base
        for d, (ln, s) in enumerate(zip(dims, strides)):
            addr += (rem % ln) * s
            rem //= ln
        out[lane] = mem[addr]
    return out, min(total, lanes)


@st.composite
def dims_and_strides(draw):
    ndim = draw(st.integers(1, 4))
    dims, strides = [], []
    total = 1
    for d in range(ndim):
        ln = draw(st.integers(1, 8))
        total *= ln
        dims.append(ln)
        strides.append(draw(st.sampled_from([0, 1, 2, 3, 5, 7])))
    return dims, strides


@settings(max_examples=25, deadline=None)
@given(dims_and_strides(), st.integers(0, 16))
def test_strided_load_matches_oracle(ds, base):
    dims, strides = ds
    span = base + sum((l - 1) * s for l, s in zip(dims, strides)) + 1
    mem = np.arange(span + 4, dtype=np.float64) * 1.5 + 3
    prog = [isa.vsetdimc(len(dims))]
    for d, ln in enumerate(dims):
        prog.append(isa.vsetdiml(d, ln))
    for d, s in enumerate(strides):
        prog.append(isa.vsetldstr(d, s))
    prog.append(isa.vsld(DType.F, 0, base, *([3] * len(dims))))
    _, state = INTERP.run(prog, mem)
    got = np.asarray(state.regs[0])
    want, n = oracle_strided_load(mem, base, dims, strides, CFG.lanes)
    np.testing.assert_allclose(got[:n], want[:n].astype(np.float32),
                               rtol=1e-6)


def test_stride_modes():
    """Mode 0 -> 0, mode 1 -> 1, mode 2 -> derived, mode 3 -> CR."""
    ctrl = ControlState()
    ctrl.dim_count = 3
    ctrl.dim_lens[:3] = [4, 5, 6]
    ctrl.ld_strides[:3] = [9, 9, 9]
    assert ctrl.resolve_strides((1, 2, 2), False) == (1, 4, 20)
    assert ctrl.resolve_strides((0, 1, 3), False) == (0, 1, 9)
    assert ctrl.resolve_strides((3, 0, 2), False) == (9, 0, 0)


def test_replication_stride_zero():
    """S=0 replicates an element across a dimension (Figure 3)."""
    mem = np.arange(64, dtype=np.float64)
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 3), isa.vsetdiml(1, 5),
            isa.vsld(DType.F, 0, 10, 1, 0)]
    _, state = INTERP.run(prog, mem)
    got = np.asarray(state.regs[0][:15]).reshape(5, 3)
    for row in got:
        np.testing.assert_array_equal(row, [10, 11, 12])


def test_random_load_eq1():
    """Equation 1: random base per highest-dim element, strided inner."""
    mem = np.zeros(256)
    mem[:100] = np.arange(100) * 2
    ptrs = [40, 7, 22]
    mem[200:203] = ptrs
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 4), isa.vsetdiml(1, 3),
            isa.vrld(DType.F, 0, 200, 1)]
    _, state = INTERP.run(prog, mem)
    got = np.asarray(state.regs[0][:12]).reshape(3, 4)
    for w, p in enumerate(ptrs):
        np.testing.assert_array_equal(got[w], mem[p:p + 4])


def test_dimension_level_masking():
    """vunsetmask drops whole highest-dim elements from stores."""
    mem = np.zeros(64)
    mem[:32] = np.arange(32)
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 8), isa.vsetdiml(1, 4),
            isa.vsld(DType.F, 0, 0, 1, 2),
            isa.vunsetmask(1), isa.vunsetmask(3),
            isa.vsst(DType.F, 0, 32, 1, 2)]
    mem_after, _ = INTERP.run(prog, mem)
    mem_after = np.asarray(mem_after)
    np.testing.assert_array_equal(mem_after[32:40], np.arange(8))   # w=0
    np.testing.assert_array_equal(mem_after[40:48], 0)              # w=1 off
    np.testing.assert_array_equal(mem_after[48:56], np.arange(16, 24))
    np.testing.assert_array_equal(mem_after[56:64], 0)              # w=3 off


def test_masked_compute_preserves_old_value():
    mem = np.zeros(64)
    prog = [isa.vsetdimc(2), isa.vsetdiml(0, 4), isa.vsetdiml(1, 4),
            isa.vsetdup(DType.DW, 0, 5),
            isa.vunsetmask(2),
            isa.vsetdup(DType.DW, 0, 9)]
    _, state = INTERP.run(prog, mem)
    got = np.asarray(state.regs[0][:16]).reshape(4, 4)
    np.testing.assert_array_equal(got[2], 5)        # masked kept old
    np.testing.assert_array_equal(got[0], 9)


def test_predicated_execution_tag_latch():
    mem = np.zeros(8)
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsetdup(DType.DW, 0, 3),
            isa.vsetdup(DType.DW, 1, 0)]
    # lane-varying value via strided load of iota
    mem[:8] = np.arange(8)
    prog += [isa.vsld(DType.DW, 1, 0, 1),
             isa.vcmp(isa.Op.GT, DType.DW, 1, 0),     # tag = (iota > 3)
             isa.vsetdup(DType.DW, 2, 1),
             isa.vadd(DType.DW, 1, 1, 2, predicated=True)]
    _, state = INTERP.run(prog, mem)
    got = np.asarray(state.regs[1][:8])
    want = np.arange(8) + (np.arange(8) > 3)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype,start,wrap", [
    (DType.B, 255, 256),          # unsigned byte wraps 255+2 -> 1
    (DType.W, 32767, 65536),      # signed 16-bit wraps to negative
])
def test_integer_wraparound(dtype, start, wrap):
    mem = np.zeros(8)
    mem[0] = start
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 4),
            isa.vsld(dtype, 0, 0, 0),          # replicate mem[0]
            isa.vsetdup(dtype, 1, 2),
            isa.vadd(dtype, 2, 0, 1)]
    _, state = INTERP.run(prog, mem)
    got = int(np.asarray(state.regs[2][0]).astype(np.int64)) % wrap
    assert got == (start + 2) % wrap


def test_flatten_indices_bijective():
    dims = (3, 4, 5)
    coords = flatten_indices(dims, 128)
    total = 60
    recon = (coords[:total, 0] + coords[:total, 1] * 3 +
             coords[:total, 2] * 12)
    np.testing.assert_array_equal(recon, np.arange(total))
    assert (coords[total:] == -1).all()


def test_cb_masking_skips_blocks():
    """A fully-masked CB never participates (Section V-B bit-vector)."""
    ctrl_mask = np.ones(256, dtype=bool)
    ctrl_mask[0] = False
    dims = (CFG.lanes_per_cb, 8)   # each top element spans exactly one CB
    cbm = cbs_touched(dims, ctrl_mask, CFG)
    assert not cbm[0] and cbm[1:].all()


def test_variable_register_count():
    assert CFG.num_physical_registers(32) == 8
    assert CFG.num_physical_registers(8) == 32
    assert CFG.effective_lanes(32) == 8192


def test_remaining_ops_cvt_min_max_rot_shr():
    """Coverage for vcvt/vmin/vmax/vroti/vshr semantics."""
    mem = np.zeros(64)
    mem[:8] = [5, -3, 7, 0, 2, 9, -8, 4]
    mem[8:16] = [1, 1, 2, 2, 0, 3, 1, 0]
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(DType.DW, 0, 0, 1),
            isa.vsld(DType.DW, 1, 8, 1),
            isa.vmin(DType.DW, 2, 0, 1),
            isa.vmax(DType.DW, 3, 0, 1),
            isa.vshr_reg(DType.DW, 4, 0, 1),      # a << b
            isa.vcvt(DType.F, 5, 0),
            isa.Instr(isa.Op.ROTI, dtype=DType.DW, vd=6, vs1=0, imm=4)]
    _, state = INTERP.run(prog, mem)
    a = mem[:8].astype(np.int64)
    b = mem[8:16].astype(np.int64)
    np.testing.assert_array_equal(
        np.asarray(state.regs[2][:8]), np.minimum(a, b))
    np.testing.assert_array_equal(
        np.asarray(state.regs[3][:8]), np.maximum(a, b))
    np.testing.assert_array_equal(
        np.asarray(state.regs[4][:8]).astype(np.int64),
        (a.astype(np.int32) << b.astype(np.int32)))
    np.testing.assert_allclose(np.asarray(state.regs[5][:8]),
                               a.astype(np.float32))
    want_rot = ((a.astype(np.uint32) << 4) |
                (a.astype(np.uint32) >> 28)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(state.regs[6][:8]), want_rot)
