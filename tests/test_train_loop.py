"""Training-driver behaviors: loss decreases, checkpoint restart resumes."""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.launch.train import TrainLoopConfig, train_loop
from repro.optim import AdamWConfig


def _cfg():
    return get_config("qwen2-0.5b", reduced=True)


def test_train_loss_decreases(tmp_path):
    cell = ShapeCell("t", 64, 4, "train")
    loop = TrainLoopConfig(steps=12, ckpt_dir=None, log_every=100)
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=12)
    m = train_loop(_cfg(), cell, loop, opt_cfg=opt, seed=0)
    assert np.isfinite(m["loss"])
    # compare against the step-1 loss by re-running 1 step
    m1 = train_loop(_cfg(), ShapeCell("t", 64, 4, "train"),
                    TrainLoopConfig(steps=1, log_every=100),
                    opt_cfg=opt, seed=0)
    assert m["loss"] < m1["loss"] - 0.2, (m["loss"], m1["loss"])


def test_checkpoint_restart_exact_resume(tmp_path):
    cell = ShapeCell("t", 32, 4, "train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    # run 1: 10 steps straight through, checkpoint every 5
    d1 = str(tmp_path / "a")
    m_full = train_loop(_cfg(), cell,
                        TrainLoopConfig(steps=10, ckpt_dir=d1,
                                        ckpt_every=5, log_every=100),
                        opt_cfg=opt, seed=0)

    # run 2: 5 steps, then a NEW train_loop call restarts from the ckpt
    d2 = str(tmp_path / "b")
    train_loop(_cfg(), cell,
               TrainLoopConfig(steps=5, ckpt_dir=d2, ckpt_every=5,
                               log_every=100), opt_cfg=opt, seed=0)
    m_resumed = train_loop(_cfg(), cell,
                           TrainLoopConfig(steps=10, ckpt_dir=d2,
                                           ckpt_every=5, log_every=100),
                           opt_cfg=opt, seed=0)
    assert abs(m_full["loss"] - m_resumed["loss"]) < 5e-3, \
        (m_full["loss"], m_resumed["loss"])


def test_grad_accum_equivalence():
    """grad_accum=2 gives (numerically close) same first-step loss."""
    import dataclasses
    cell = ShapeCell("t", 32, 4, "train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=3)
    m1 = train_loop(_cfg(), cell, TrainLoopConfig(steps=3, log_every=100),
                    opt_cfg=opt, seed=0)
    cfg2 = dataclasses.replace(_cfg(), grad_accum=2)
    m2 = train_loop(cfg2, cell, TrainLoopConfig(steps=3, log_every=100),
                    opt_cfg=opt, seed=0)
    assert abs(m1["loss"] - m2["loss"]) < 5e-2, (m1["loss"], m2["loss"])
