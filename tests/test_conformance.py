"""Cross-executor differential fuzzer: interp == fused == VM == scheduler.

Extends the random-program strategy of ``tests/test_vm.py`` with the
addressing features that suite leaves out, and adds the fourth executor —
the signature-batched scheduler (:mod:`repro.runtime.scheduler`) — to the
equivalence contract:

* **CB-masked stores**: dimension-mask bits dropped around stores, so the
  blend and sorted-unique scatter paths run partially masked (the mask
  expands to control-block masks, Section V-B);
* **random-base gathers** (Eq. 1): ``vrld`` walks pointer arrays placed
  in memory, so addresses are data-dependent in every executor;
* **random-base scatters**: ``vrst`` stores through per-row pointers;
* **saturating narrow-int reads**: B/W loads from a "wild" region holding
  huge/negative/fractional floats, which must clamp identically in the
  eager casts, the VM's clamp-then-convert, and the vmapped batch.

Every seeded program is executed on several memory variants; each variant
must come back bit-identical (memory, registers, Tag) from all four
executors, with the stepwise interpreter as the oracle.  The scheduler is
exercised through both tiers: the vmapped VM batch (``promote_after=None``)
and the fused batch (``promote_after=1``).

The optimizer (:mod:`repro.opt`) is part of the same equivalence class:
every random program and random frontend kernel is additionally pushed
through each pipeline prefix, and the optimized text must reproduce the
*unoptimized* oracle bit for bit (docs/OPTIMIZER.md) — an optimizer bug
surfaces here as a conformance failure, not a silent miscompile.

The ``*-timed`` pipeline-model targets (:mod:`repro.timing`,
docs/TIMING.md) join the class with an *envelope* property: for every
random program and random frontend kernel, the pipeline model's cycles
must lie within ``[ideal-issue lower bound, fully-serialized upper
bound]`` recomputed from the same TimedOp stream — and timed execution
stays bit-exact vs. the stepwise oracle (the timing layer must never
touch functional semantics).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import opt, targets
from repro.core import MVEConfig, MVEInterpreter, compile_program, isa, rvv
from repro.core.isa import DType, Op
from repro.core.patterns import PATTERNS, RVV_COMPARISON_SET
from repro.frontend import BCAST, DERIVED, SEQ, KernelBuilder
from repro.runtime.scheduler import MVEScheduler

CFG = MVEConfig()
ORACLE = MVEInterpreter(CFG, compiled=False)

# memory map of the fuzzed image
_MEM = 4096
_IN = 0            # [0, 1024): small non-negative ints (safe for any dtype)
_WILD = 1024       # [1024, 1536): huge/negative/fractional floats
_PTR = 1536        # [1536, 2560): pointer arrays for random-base accesses
_OUT = 3072        # [3072, 4096): store targets
_INT_DT = [DType.B, DType.W, DType.DW, DType.QW]


def _random_program_ex(seed, variants=3):
    """One random program + ``variants`` memory images it must serve
    identically.  Pointer arrays are identical across variants (they are
    addressing state); input and wild values differ per variant."""
    rng = np.random.default_rng(seed)
    mems = [np.zeros(_MEM) for _ in range(variants)]
    for v, mem in enumerate(mems):
        vr = np.random.default_rng((seed, v))
        mem[_IN:_IN + 1024] = vr.integers(0, 100, size=1024)
        wild = vr.uniform(-1e6, 1e6, size=512)
        wild[::7] = vr.integers(-300, 70000, size=len(wild[::7]))
        mem[_WILD:_WILD + 512] = np.round(wild, 2)
    prog = [isa.vsetwidth(32)]
    stored = {}                       # reg -> "int" | "float"
    lens = []
    ptr_cursor = _PTR
    masked_now = []

    def set_dims():
        nonlocal lens
        nd = int(rng.integers(1, 3))
        lens = [int(rng.integers(2, 17)) for _ in range(nd)]
        prog.append(isa.vsetdimc(nd))
        for d, ln in enumerate(lens):
            prog.append(isa.vsetdiml(d, ln))

    def total():
        return int(np.prod(lens))

    def inner():
        return int(np.prod(lens[:-1]))

    def int_reg():
        cands = [r for r, k in stored.items() if k == "int"]
        return int(rng.choice(cands)) if cands else None

    def any_reg():
        return int(rng.choice(list(stored))) if stored else None

    def alloc_ptrs(targets):
        """Write a pointer array (same in every variant) into the pointer
        region; returns its base or None when the region is full."""
        nonlocal ptr_cursor
        if ptr_cursor + len(targets) > _OUT - 512:
            return None
        base = ptr_cursor
        ptr_cursor += len(targets)
        for mem in mems:
            mem[base:base + len(targets)] = targets
        return base

    def mask_store_window():
        """CB-masked store coverage: drop a few top-dim elements."""
        idxs = sorted(rng.choice(min(lens[-1], 256),
                                 size=int(rng.integers(1, 3)),
                                 replace=False))
        for i in idxs:
            prog.append(isa.vunsetmask(int(i)))
        masked_now.extend(int(i) for i in idxs)

    def maybe_unmask():
        while masked_now and rng.random() < 0.7:
            prog.append(isa.vsetmask(masked_now.pop()))

    set_dims()
    for _ in range(int(rng.integers(12, 32))):
        c = int(rng.integers(0, 14))
        rd = int(rng.integers(0, 7))
        if c == 0:
            set_dims()
            masked_now.clear()        # fresh dims, fresh mask relevance
        elif c == 1:                                # strided load
            if rng.random() < 0.5:                  # saturating narrow read
                dt = _INT_DT[int(rng.integers(0, 2))]
                base = int(rng.integers(_WILD, max(_WILD + 512 - total(),
                                                   _WILD + 1)))
            else:
                dt = [DType.DW, DType.QW, DType.F,
                      DType.HF][int(rng.integers(0, 4))]
                base = int(rng.integers(0, max(2048 - total(), 1)))
            prog.append(isa.vsld(dt, rd, base,
                                 *([1] + [2] * (len(lens) - 1))))
            stored[rd] = "float" if dt.is_float else "int"
        elif c == 2:                                # random-base gather
            top = lens[-1]
            targets = rng.integers(0, max(768 - inner(), 1), size=top)
            base = alloc_ptrs(targets)
            if base is None:
                continue
            dt = [DType.B, DType.W, DType.F][int(rng.integers(0, 3))]
            prog.append(isa.vrld(dt, rd, base,
                                 *([1] + [2] * (len(lens) - 2))))
            stored[rd] = "float" if dt.is_float else "int"
        elif c == 3:                                # store (maybe CB-masked)
            src = any_reg()
            if src is None:
                continue
            if rng.random() < 0.5:
                mask_store_window()
            dt = DType.F if stored[src] == "float" else DType.DW
            if rng.random() < 0.3:                  # strided -> scatter path
                prog.append(isa.vsetststr(0, 2))
                base = int(rng.integers(_OUT, _MEM - 2 * total()))
                prog.append(isa.vsst(dt, src, base,
                                     *([3] + [2] * (len(lens) - 1))))
            else:
                base = int(rng.integers(_OUT, _MEM - total()))
                prog.append(isa.vsst(dt, src, base,
                                     *([1] + [2] * (len(lens) - 1))))
            maybe_unmask()
        elif c == 4:                                # random-base scatter
            src = any_reg()
            if src is None:
                continue
            top = lens[-1]
            stride = max(inner(), 1)
            if _OUT + top * stride >= _MEM:
                continue
            targets = _OUT + rng.permutation(top) * stride
            base = alloc_ptrs(targets)
            if base is None:
                continue
            if rng.random() < 0.4:
                mask_store_window()
            dt = DType.F if stored[src] == "float" else DType.DW
            prog.append(isa.vrst(dt, src, base,
                                 *([1] + [2] * (len(lens) - 2))))
            maybe_unmask()
        elif c == 5:                                # setdup
            if rng.random() < 0.5:
                prog.append(isa.vsetdup(DType.DW, rd,
                                        int(rng.integers(-50, 50))))
                stored[rd] = "int"
            else:
                prog.append(isa.vsetdup(
                    DType.F, rd, float(np.round(rng.normal(), 3))))
                stored[rd] = "float"
        elif c == 6:                                # narrow int binop
            a, b = int_reg(), int_reg()
            if a is None or b is None:
                continue
            dt = _INT_DT[int(rng.integers(0, 4))]
            op = [isa.vadd, isa.vsub, isa.vmul, isa.vmin, isa.vmax,
                  isa.vxor, isa.vand, isa.vor][int(rng.integers(0, 8))]
            prog.append(op(dt, rd, a, b))
            stored[rd] = "int"
        elif c == 7:                                # 32-bit op, any sources
            a, b = any_reg(), any_reg()
            if a is None or b is None:
                continue
            dt = DType.DW if rng.random() < 0.5 else DType.F
            op = [isa.vadd, isa.vsub, isa.vmul, isa.vmin,
                  isa.vmax][int(rng.integers(0, 5))]
            prog.append(op(dt, rd, a, b,
                           predicated=bool(rng.random() < 0.25)))
            stored[rd] = "float" if dt.is_float else "int"
        elif c == 8:                                # compare (writes Tag)
            a, b = any_reg(), any_reg()
            if a is None or b is None:
                continue
            dt = DType.F if (stored[a] == "float" or stored[b] == "float") \
                else DType.DW
            cmp = [Op.GT, Op.GTE, Op.LT, Op.LTE, Op.EQ,
                   Op.NEQ][int(rng.integers(0, 6))]
            prog.append(isa.vcmp(cmp, dt, a, b))
        elif c == 9:                                # shift immediate
            a = int_reg()
            if a is None:
                continue
            dt = _INT_DT[int(rng.integers(0, 4))]
            prog.append(isa.vshi(dt, rd, a, int(rng.integers(-3, 4))))
            stored[rd] = "int"
        elif c == 10:                               # rotate
            a = int_reg()
            if a is None:
                continue
            dt = _INT_DT[int(rng.integers(0, 3))]
            prog.append(isa.Instr(Op.ROTI, dtype=dt, vd=rd, vs1=a,
                                  imm=int(rng.integers(1, dt.bits))))
            stored[rd] = "int"
        elif c == 11:                               # dim-mask toggles
            idx = int(rng.integers(0, min(lens[-1], 256)))
            prog.append(isa.vunsetmask(idx) if rng.random() < 0.5
                        else isa.vsetmask(idx))
        else:                                       # cvt / cpy
            a = any_reg()
            if a is None:
                continue
            dt = [DType.F, DType.HF, DType.DW, DType.W,
                  DType.B][int(rng.integers(0, 5))]
            prog.append(isa.vcvt(dt, rd, a))
            stored[rd] = "float" if dt.is_float else "int"
    # observable tail store
    src = any_reg()
    if src is not None:
        dt = DType.F if stored[src] == "float" else DType.DW
        prog.append(isa.vsst(dt, src, _OUT,
                             *([1] + [2] * (len(lens) - 1))))
    return prog, mems


def _assert_result_equal(st_i, mem_i, res):
    np.testing.assert_array_equal(np.asarray(mem_i), np.asarray(res.memory))
    assert set(st_i.regs) == set(res.regs)
    for r in st_i.regs:
        np.testing.assert_array_equal(np.asarray(st_i.regs[r]),
                                      np.asarray(res.regs[r]))
    np.testing.assert_array_equal(np.asarray(st_i.tag),
                                  np.asarray(res.tag))


_TIMED_TARGETS = ("mve-bs-timed", "mve-bp-timed", "mve-bh-timed",
                  "mve-ac-timed", "rvv-1d-timed", "neon-timed")


def _check_timed_envelope(prog, mem, oracle=None,
                          target_names=_TIMED_TARGETS):
    """Bit-exactness + the timing envelope contract for timed targets.

    The executed trace is priced through the pipeline model; its total
    must sit inside the ``[lower_bound, upper_bound]`` bracket, which is
    re-derived here from the same TimedOp stream via
    :func:`repro.timing.envelope` (not trusted from the timeline)."""
    from repro import timing

    mem_i, st_i = oracle if oracle is not None \
        else ORACLE.run_stepwise(prog, mem)
    for tname in target_names:
        art = targets.compile(prog, target=tname)
        mem_t, st_t = art.run(mem)
        _assert_result_equal(st_i, mem_i, st_t)          # semantics intact
        tl = art.timeline(st_t)                          # exact trace
        ops, _ = art.target.timed_ops(art.program, art.cfg, st_t.trace)
        lo, hi = timing.envelope(ops, art.target.uarch)
        assert (lo, hi) == (tl.lower_bound, tl.upper_bound), tname
        assert lo - 1e-6 <= tl.total_cycles <= hi + 1e-6, \
            f"{tname}: {tl.total_cycles} outside envelope [{lo}, {hi}]"
        assert {"dependency", "structural",
                "memory-port", "frontend"} <= set(tl.stalls), tname


def _check_all_executors(prog, mems):
    """interp == VM == fused (per image) and == scheduler (batched, both
    tiers), bit for bit."""
    oracle = [ORACLE.run_stepwise(prog, m) for m in mems]
    for mode in ("vm", "fused"):
        cp = compile_program(prog, CFG, mode=mode)
        assert cp.mode == mode
        for (mem_i, st_i), m in zip(oracle, mems):
            mem_e, st_e = cp.run(m)
            _assert_result_equal(st_i, mem_i, st_e)
    # scheduler: same program over all variants coalesces into one
    # vmapped dispatch per tier
    for sched in (MVEScheduler(CFG, promote_after=None),     # VM tier
                  MVEScheduler(CFG, promote_after=1)):       # fused tier
        tickets = [sched.submit(prog, m) for m in mems]
        sched.drain()
        for (mem_i, st_i), t in zip(oracle, tickets):
            _assert_result_equal(st_i, mem_i, t.result())
        assert sched.stats.dispatches < max(len(mems), 2), \
            "variants of one program must share a batched dispatch"
    # the fifth member of the equivalence class: the optimizer — every
    # pipeline prefix of this program must reproduce the same oracle
    # (VM executor; the full pipeline additionally on fused)
    for prefix in opt.pipeline_prefixes():
        full = len(prefix) == len(opt.DEFAULT_PIPELINE)
        opt.verify_optimized(prog, list(mems), passes=prefix, cfg=CFG,
                             modes=("vm", "fused") if full else ("vm",),
                             oracle=oracle)
    # the sixth member: pipeline-model pricing — bit-exact execution,
    # cycles inside the analytic envelope (one aligned-dependence and
    # one synthesized-dependence timed target; the full timed matrix is
    # swept by test_timed_targets_envelope_*)
    _check_timed_envelope(prog, mems[0], oracle=oracle[0],
                          target_names=("mve-bs-timed", "rvv-1d-timed"))


@pytest.mark.parametrize("seed", range(8))
def test_conformance_random_programs(seed):
    """Seeded differential fuzz across all four executors."""
    prog, mems = _random_program_ex(seed)
    _check_all_executors(prog, mems)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**9))
def test_conformance_random_programs_property(seed):
    """Hypothesis-driven version (skips when hypothesis is absent)."""
    prog, mems = _random_program_ex(seed, variants=2)
    _check_all_executors(prog, mems)


# ---------------------------------------------------------------------------
# Deterministic coverage the fuzzer cannot guarantee per-seed.
# ---------------------------------------------------------------------------

def test_cb_masked_store_all_executors():
    """Both store layouts (blend + sorted-unique scatter) under dropped
    dimension-mask bits."""
    mem = np.zeros(_MEM)
    mem[:64] = np.arange(64)
    prog = [isa.vsetwidth(32),
            isa.vsetdimc(2), isa.vsetdiml(0, 8), isa.vsetdiml(1, 8),
            isa.vsld(DType.F, 0, 0, 1, 2),
            isa.vunsetmask(2), isa.vunsetmask(5),
            isa.vsst(DType.F, 0, _OUT, 1, 2),          # masked blend
            isa.vsetststr(0, 2),
            isa.vsst(DType.F, 0, _OUT + 256, 3, 2),    # masked scatter
            isa.vsetmask(2)]
    _check_all_executors(prog, [mem])


def test_random_base_gather_batched_pointer_tables():
    """Random-base pointers are data: the same upsample program with
    *different* shuffled row-pointer tables must batch correctly."""
    runs = [PATTERNS["upsample"](seed=s) for s in (0, 7, 11)]
    assert all(r.program == runs[0].program for r in runs)
    for sched in (MVEScheduler(CFG, promote_after=None),
                  MVEScheduler(CFG, promote_after=1)):
        tickets = [sched.submit(r.program, r.memory) for r in runs]
        sched.drain()
        for r, t in zip(runs, tickets):
            res = t.result()
            assert res.batch_size == len(runs)
            r.check(np.asarray(res.memory), res)


def test_scheduler_mixed_stream_matches_engine():
    """A mixed-signature stream (incl. data-dependent spmm programs)
    served batched == per-request engine runs."""
    reqs = []
    for name, seeds in (("daxpy", (0, 1, 2)), ("spmm", (3, 4)),
                        ("xor_cipher", (0, 5))):
        reqs += [PATTERNS[name](seed=s) for s in seeds]
    sched = MVEScheduler(CFG, promote_after=2)
    tickets = [sched.submit(r.program, r.memory) for r in reqs]
    sched.drain()
    for r, t in zip(reqs, tickets):
        res = t.result()
        mem_e, st_e = compile_program(r.program, CFG).run(r.memory)
        np.testing.assert_array_equal(np.asarray(mem_e),
                                      np.asarray(res.memory))
        r.check(np.asarray(res.memory), res)
    st = sched.stats
    assert st.requests == len(reqs)
    assert st.dispatches < len(reqs)          # batching actually happened
    assert st.batch_efficiency > 1.0


def test_scheduler_background_mode():
    """Async serving: tickets resolve without an explicit drain()."""
    runs = [PATTERNS["daxpy"](seed=s) for s in range(3)]
    with MVEScheduler(CFG, background=True, max_wait_ms=20.0,
                      promote_after=None) as sched:
        tickets = [sched.submit(r.program, r.memory) for r in runs]
        for r, t in zip(runs, tickets):
            res = t.result(timeout=120)
            r.check(np.asarray(res.memory), res)
    assert sched.stats.requests == 3
    with pytest.raises(RuntimeError):
        sched.submit(runs[0].program, runs[0].memory)


def test_cross_target_random_programs():
    """The fuzzer's random programs are also bit-exact across every
    registered target (the targets all execute the shared functional
    engine; docs/TARGETS.md)."""
    for seed in range(3):
        prog, mems = _random_program_ex(seed, variants=1)
        mem_i, st_i = ORACLE.run_stepwise(prog, mems[0])
        for tname in targets.list_targets():
            art = targets.compile(prog, target=tname)
            mem_t, st_t = art.run(mems[0])
            _assert_result_equal(st_i, mem_i, st_t)
        # ...and so is the fully-optimized text, on every target
        opt.verify_across_targets(prog, mems[0], level=opt.MAX_OPT_LEVEL)


# ---------------------------------------------------------------------------
# Cross-target conformance: the RVV path is the same access, sliced —
# bit-exactness across mve-*, rvv-1d and the interp oracle is a tested
# invariant, per pattern and per random frontend kernel.
# ---------------------------------------------------------------------------

_IN_CACHE_TARGETS = ("mve-bs", "mve-bp", "mve-bh", "mve-ac", "rvv-1d")


@pytest.mark.parametrize("name", RVV_COMPARISON_SET)
def test_cross_target_rvv_comparison_set(name):
    run = PATTERNS[name]()
    mem_i, st_i = ORACLE.run_stepwise(run.program, run.memory)
    for tname in _IN_CACHE_TARGETS:
        art = targets.compile(run.program, target=tname)
        mem_t, st_t = art.run(run.memory)
        _assert_result_equal(st_i, mem_i, st_t)
        run.check(np.asarray(mem_t), st_t)


def _random_frontend_kernel(seed: int):
    """A small random @mve.kernel-style build: random dimensionality,
    random stride-mode mix, a few arithmetic ops, masked stores."""
    rng = np.random.default_rng(seed)
    nd = int(rng.integers(1, 4))
    lens = [int(rng.integers(2, 9)) for _ in range(nd)]
    total = int(np.prod(lens))
    b = KernelBuilder(f"fuzz_{seed}")
    x = b.input("x", (total,), DType.F,
                init=rng.standard_normal(total).astype(np.float32))
    y = b.inout("y", (total,), DType.F,
                init=rng.standard_normal(total).astype(np.float32))
    out = b.output("out", (total,), DType.F)
    b.width(32)
    dense = (SEQ,) + (DERIVED,) * (nd - 1)
    with b.dims(*lens):
        vx = x.load(*dense)
        vy = y.load(*dense)
        if nd > 1 and rng.random() < 0.5:
            # replicate x along the top dimension (stride-0 broadcast)
            vx = x.load(*((SEQ,) + (DERIVED,) * (nd - 2) + (BCAST,)))
        acc = vx * vy
        for _ in range(int(rng.integers(1, 4))):
            op = rng.choice(["add", "mul", "min", "max"])
            operand = [vx, vy, acc][int(rng.integers(0, 3))]
            if op == "add":
                acc = acc + operand
            elif op == "mul":
                acc = acc * float(np.round(rng.normal(), 2))
            elif op == "min":
                acc = acc.min(operand)
            else:
                acc = acc.max(operand)
        if lens[-1] > 2 and rng.random() < 0.5:
            with b.masked_off(int(rng.integers(0, lens[-1]))):
                out.store(acc, *dense)
        else:
            out.store(acc, *dense)
    return b.build()


@pytest.mark.parametrize("seed", range(6))
def test_cross_target_random_frontend_kernels(seed):
    k = _random_frontend_kernel(seed)
    mem0 = k.pack()
    mem_i, st_i = ORACLE.run_stepwise(k.program, mem0)
    for tname in _IN_CACHE_TARGETS:
        art = targets.compile(k, target=tname)
        mem_t, st_t = art.run(mem0)
        _assert_result_equal(st_i, mem_i, st_t)
    # frontend kernels go through every optimizer pipeline prefix too
    opt.verify_prefixes(k.program, mem0, cfg=CFG, modes=("vm",))
    # ...and through the pipeline-model envelope contract
    _check_timed_envelope(k.program, mem0, oracle=(mem_i, st_i),
                          target_names=("mve-bs-timed", "rvv-1d-timed"))


# ---------------------------------------------------------------------------
# repro.nn model blocks join the equivalence class (docs/MODELS.md):
# random-shape instances of every zoo family through interp == fused ==
# VM == scheduler == every opt-pipeline prefix == timed envelope.
# ---------------------------------------------------------------------------

def _random_nn_block(seed: int):
    """A randomly-shaped instance of one zoo block family (family cycles
    with the seed so six seeds cover all six)."""
    from repro import nn

    rng = np.random.default_rng(seed)
    family = seed % 6
    if family == 0:
        window = int(2 ** rng.integers(2, 4))
        return nn.kv_gather(window=window, n_kv=int(rng.integers(1, 4)),
                            head_dim=int(2 ** rng.integers(1, 4)),
                            max_seq=2 * window,
                            pos0=int(rng.integers(0, window)), seed=seed)
    if family == 1:
        window = int(2 ** rng.integers(2, 4))
        return nn.kv_scatter(window=window, n_kv=int(rng.integers(1, 4)),
                             head_dim=int(2 ** rng.integers(1, 4)),
                             max_seq=2 * window,
                             pos0=int(rng.integers(0, window)), seed=seed)
    if family == 2:
        chunk = int(2 ** rng.integers(1, 3))
        return nn.attn_tile(tq=int(2 ** rng.integers(2, 4)),
                            tk=chunk * int(rng.integers(1, 3)),
                            d=int(2 ** rng.integers(1, 3)),
                            chunk=chunk, seed=seed)
    if family == 3:
        return nn.gemm_tile(n=int(2 ** rng.integers(2, 5)),
                            kdim=int(rng.integers(2, 5)),
                            m=int(2 ** rng.integers(2, 5)), seed=seed)
    if family == 4:
        return nn.ssm_scan(n_state=int(2 ** rng.integers(2, 4)),
                           d_inner=int(2 ** rng.integers(2, 5)), seed=seed)
    return nn.moe_gather(tokens=int(2 ** rng.integers(2, 5)),
                         d_expert=int(2 ** rng.integers(2, 4)),
                         n_experts=int(2 ** rng.integers(1, 4)),
                         topk=int(rng.integers(1, 4)), seed=seed)


@pytest.mark.parametrize("seed", range(6))
def test_conformance_random_nn_blocks(seed):
    """Every zoo family, random shapes, full equivalence class — plus
    the block's own jnp-oracle check on the oracle executor's result."""
    run = _random_nn_block(seed)
    mem0 = run.kernel.pack()
    mem_i, st_i = ORACLE.run_stepwise(run.kernel.program, mem0)
    run.check(np.asarray(mem_i), st_i)
    _check_all_executors(run.kernel.program, [mem0])


@pytest.mark.parametrize("seed", range(4))
def test_timed_targets_envelope_random_programs(seed):
    """The full timed matrix: every timed target executes the fuzzer's
    random programs bit-exactly and prices them inside the envelope."""
    prog, mems = _random_program_ex(seed, variants=1)
    _check_timed_envelope(prog, mems[0])


@pytest.mark.parametrize("seed", range(3))
def test_timed_targets_envelope_random_frontend_kernels(seed):
    k = _random_frontend_kernel(seed)
    _check_timed_envelope(k.program, k.pack())


# ---------------------------------------------------------------------------
# The Section III-C segment-count formula, as a property of the lowered
# RVV trace:  #segments = ceil(active_lanes / len(inner 1D segment)).
# ---------------------------------------------------------------------------

def _check_segment_formula(seed: int):
    rng = np.random.default_rng(seed)
    nd = int(rng.integers(1, 5))
    lens = [int(rng.integers(1, 17)) for _ in range(nd)]
    modes = [int(rng.integers(0, 4)) for _ in range(nd)]
    prog = [isa.vsetwidth(32), isa.vsetdimc(nd)]
    for d, ln in enumerate(lens):
        prog.append(isa.vsetdiml(d, ln))
        prog.append(isa.vsetldstr(d, int(rng.integers(0, 64))))
    prog.append(isa.vsld(DType.F, 0, 0, *modes))
    trace, stats = rvv.compile_to_rvv(prog, CFG)

    loads = [ev for ev in trace if ev.op is Op.SLD]
    diml_cfg = [ev for ev in trace if ev.op is Op.SET_DIML]
    active = min(int(np.prod(lens)), CFG.lanes)
    assert len(loads) >= 1
    inner = loads[0].contiguous_run
    assert all(ev.contiguous_run == inner for ev in loads)
    # the paper's decomposition count, recomputed from the trace alone
    assert len(loads) == -(-active // inner)
    # one vsetvl/predicate config precedes every partial access (the
    # program's own nd vsetdiml config writes pass through 1:1 on top)
    assert len(diml_cfg) == len(loads) + nd
    assert stats.mask_instructions == len(loads)
    # and the recorded per-access log agrees with the emitted trace
    assert stats.segment_log == [(len(loads), inner, active)]
    assert stats.memory_instructions == len(loads)
    assert stats.move_instructions == len(loads)


@pytest.mark.parametrize("seed", range(12))
def test_rvv_segment_count_formula_seeded(seed):
    _check_segment_formula(seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**9))
def test_rvv_segment_count_formula_property(seed):
    """Hypothesis-driven version (skips when hypothesis is absent)."""
    _check_segment_formula(seed)


def test_scheduler_nonfloat_memory_routes_fused():
    """Non-float32-canonical images keep exact integer semantics through
    the scheduler (the VM rejects them; the fused path serves them)."""
    mem = np.zeros(256, dtype=np.int32)
    mem[:8] = (1 << 24) + 1
    prog = [isa.vsetdimc(1), isa.vsetdiml(0, 8),
            isa.vsld(DType.DW, 0, 0, 1),
            isa.vsst(DType.DW, 0, 16, 1)]
    mem_i, st_i = ORACLE.run_stepwise(prog, mem)
    sched = MVEScheduler(CFG, promote_after=None)
    tickets = [sched.submit(prog, mem) for _ in range(2)]
    sched.drain()
    for t in tickets:
        res = t.result()
        assert np.asarray(res.memory).dtype == np.int32
        # fused-routed despite promotion being off: the full fused batch
        # cap applies and the dispatch is accounted to the fused tier
        assert res.tier == "fused" and res.batch_size == 2
        _assert_result_equal(st_i, mem_i, res)
    assert sched.stats.fused_batches == 1
    assert sched.stats.vm_batches == 0
