"""Substrate tests: optimizer, data pipeline, checkpoint, runtime, packing."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              reshard_tree, save_checkpoint)
from repro.checkpoint.store import latest_step
from repro.core.packing import LaneGrid, pack_documents
from repro.data import DataConfig, make_train_batches
from repro.data.pipeline import SyntheticTextSource
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime import (HeartbeatMonitor, StragglerDetector,
                           plan_elastic_remesh)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.05,
                      clip_norm=1e9, total_steps=100, min_lr_ratio=1.0)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = adamw_init(p)
    new_p, state, metrics = adamw_update(cfg, p, g, state)
    # numpy reference
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.05 * np.array([0.1, 0.2, -0.3]) ** 2
    mh, vh = m / 0.1, v / 0.05
    want = np.array([1.0, -2.0, 3.0]) - 1e-2 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.05 * np.array([1.0, -2.0, 3.0]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert float(metrics["grad_norm"]) > 0


def test_adamw_clipping():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, clip_norm=0.1,
                      weight_decay=0.0)
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    state = adamw_init(p)
    new_p, _, metrics = adamw_update(cfg, p, g, state)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # clipped update is bounded by lr * (1 + wd-ish)
    assert np.abs(np.asarray(new_p["w"]) - 1.0).max() < 0.02


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_documents_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
    s1, s2 = SyntheticTextSource(cfg), SyntheticTextSource(cfg)
    for i in (0, 7, 123):
        np.testing.assert_array_equal(s1.document(i), s2.document(i))


def test_batches_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=500, seq_len=32, global_batch=4)
    b1 = list(next(make_train_batches(cfg)) for _ in range(1))[0]
    it = make_train_batches(cfg)
    b2 = next(it)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    # resume from next_doc reproduces the following batch
    nxt = int(b2["next_doc"])
    b3 = next(it)
    b3r = next(make_train_batches(cfg, start_doc=nxt))
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])


def test_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=500, seq_len=32, global_batch=8)
    a = next(make_train_batches(cfg, host=0, num_hosts=2))
    b = next(make_train_batches(cfg, host=1, num_hosts=2))
    assert a["tokens"].shape[0] == 4
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_packing_preserves_tokens_and_masks():
    docs = [np.arange(1, 10), np.arange(100, 140), np.arange(7, 12)]
    tokens, segs, pos = pack_documents(docs, seq_len=24)
    # all document tokens appear
    packed = tokens[segs > 0]
    all_docs = np.concatenate([d for d in docs])
    assert sorted(packed.tolist()) == sorted(all_docs.tolist())
    # positions restart per segment
    for r in range(tokens.shape[0]):
        for sid in np.unique(segs[r]):
            if sid == 0:
                continue
            sel = pos[r][segs[r] == sid]
            np.testing.assert_array_equal(sel, np.arange(len(sel)))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, {"note": "x"})
    restored, meta = load_checkpoint(str(tmp_path), t)
    assert meta["note"] == "x"
    np.testing.assert_array_equal(restored["a"], t["a"])
    np.testing.assert_array_equal(restored["b"]["c"], t["b"]["c"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones(5, jnp.int32)}}
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), bad)


def test_manager_async_retention_and_emergency(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save_async(s, _tree(), {"step": s})
    mgr.wait()
    assert latest_step(str(tmp_path)) == 30
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert len(steps) == 2 and 10 not in steps
    path = mgr.save_emergency(31, _tree(), {"step": 31})
    assert os.path.exists(os.path.join(path, "manifest.json"))
    _, meta = load_checkpoint(str(tmp_path), _tree(), step=31)
    assert meta["emergency"] is True


def test_reshard_tree_roundtrip():
    t = _tree()
    shard = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    r = reshard_tree(t, shard)
    np.testing.assert_array_equal(r["a"], t["a"])


# ---------------------------------------------------------------------------
# runtime health
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_host():
    clock = {"t": 0.0}
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10,
                           clock=lambda: clock["t"])
    clock["t"] = 5
    mon.beat("h0")
    clock["t"] = 12
    assert mon.dead_hosts() == ["h1"]
    mon.beat("h1")               # recovery
    assert mon.healthy()


def test_straggler_detection_persistent_outlier():
    det = StragglerDetector(window=4, mad_threshold=3.0, persistence=2)
    for step in range(8):
        for h in range(6):
            det.record(f"h{h}", 1.0 + 0.01 * h)
        det.record("slow", 5.0)
        out = det.stragglers()
    assert out == ["slow"]


def test_elastic_plan():
    p = plan_elastic_remesh(512, model_parallel=16, chips_per_pod=256)
    assert (p.pods, p.data, p.model) == (2, 16, 16)
    # lose 13 chips from one pod -> drop to one full pod + biggest DP
    p = plan_elastic_remesh(499, model_parallel=16, chips_per_pod=256)
    assert p.model == 16 and p.chips <= 499
    assert p.data >= 8
    with pytest.raises(ValueError):
        plan_elastic_remesh(8, model_parallel=16)


# ---------------------------------------------------------------------------
# LaneGrid (MVE dimension-level masking applied to serving)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.sampled_from(["alloc", "free"]), min_size=1,
                max_size=60))
def test_lane_grid_invariants(ops):
    grid = LaneGrid((16, 8))
    live = {}
    for i, op in enumerate(ops):
        if op == "alloc":
            slot = grid.allocate(f"p{i}")
            if slot is not None:
                assert slot not in live
                live[slot] = f"p{i}"
            else:
                assert len(live) == 8
        elif live:
            slot = sorted(live)[0]
            payload = grid.release(slot)
            assert payload == live.pop(slot)
    assert set(grid.active_slots()) == set(live)
    assert grid.occupancy() == pytest.approx(len(live) / 8)
    lm = grid.lane_mask()
    assert lm.sum() == len(live) * 16


def test_lane_grid_mask_cr_capacity():
    with pytest.raises(ValueError):
        LaneGrid((4, 512))       # top dim exceeds the 256-entry mask CR


def test_adamw_int8_state_tracks_fp32():
    """Block-quantized moments converge close to fp32 Adam."""
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y = x @ w_true

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    finals = {}
    for fmt in ("fp32", "int8"):
        cfg = AdamWConfig(lr=5e-2, warmup_steps=0, weight_decay=0.0,
                          total_steps=100, min_lr_ratio=1.0,
                          state_format=fmt)
        p = {"w": jnp.zeros((8, 8))}
        st = adamw_init(p, fmt)
        for _ in range(60):
            g = jax.grad(loss_fn)(p)
            p, st, _ = adamw_update(cfg, p, g, st)
        finals[fmt] = float(loss_fn(p))
    assert finals["int8"] < 0.1
    assert finals["int8"] < finals["fp32"] * 20 + 0.05
    # and the state really is int8
    st_leaves = jax.tree.leaves(
        adamw_init({"w": jnp.zeros((8, 8))}, "int8")["m"])
    assert any(l.dtype == jnp.int8 for l in st_leaves)


def test_checkpoint_roundtrip_int8_opt_state(tmp_path):
    """The quantized optimizer state (nested {q,s} moments) checkpoints."""
    p = {"w": jnp.arange(32.0).reshape(4, 8).astype(jnp.bfloat16)}
    st = adamw_init(p, "int8")
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, state_format="int8")
    g = {"w": jnp.ones((4, 8))}
    p, st, _ = adamw_update(cfg, p, g, st)
    save_checkpoint(str(tmp_path), 3, {"params": p, "opt": st})
    restored, _ = load_checkpoint(str(tmp_path), {"params": p, "opt": st})
    np.testing.assert_array_equal(
        np.asarray(restored["opt"]["m"]["w"]["q"]),
        np.asarray(st["m"]["w"]["q"]))
    np.testing.assert_allclose(
        np.asarray(restored["opt"]["m"]["w"]["s"]),
        np.asarray(st["m"]["w"]["s"]))
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"], np.float32),
        np.asarray(p["w"], np.float32))
