"""Import ``hypothesis`` if available, else degrade gracefully.

The property-based tests use a small surface of hypothesis (``given``,
``settings``, ``strategies`` with ``integers`` / ``sampled_from`` /
``lists`` / ``composite``).  When the package is missing (it is an
optional dev dependency, see requirements-dev.txt) this module provides
stand-ins so the modules still *collect*: strategy constructors return
opaque placeholders and ``@given`` turns the test into an explicit
``pytest.skip`` instead of an import error.

Usage in tests::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools

    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Opaque placeholder for a hypothesis search strategy."""

        def __init__(self, *args, **kwargs):
            pass

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

    class _StrategiesModule:
        """Any ``st.<name>(...)`` call yields a placeholder strategy."""

        @staticmethod
        def composite(fn):
            @functools.wraps(fn)
            def build(*args, **kwargs):
                return _Strategy()
            return build

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return _Strategy()
            return make

    st = _StrategiesModule()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # NOTE: no functools.wraps — pytest would follow __wrapped__
            # to the original signature and demand fixtures for the
            # strategy parameters.  The skipper must look zero-arg.
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate
