"""Section IV data-parallel patterns: correctness + ISA-comparison claims."""
import numpy as np
import pytest

from repro.core import MVEConfig, MVEInterpreter, cost, rvv
from repro.core.patterns import PATTERNS, RVV_COMPARISON_SET

INTERP = MVEInterpreter()


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_pattern_correct(name):
    run = PATTERNS[name]()
    mem_after, state = INTERP.run(run.program, run.memory)
    run.check(np.asarray(mem_after), state)


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_rvv_lowering_counts(name):
    """The 1D lowering must expand every multi-dim access into
    (mask cfg + partial access + move) x segments (Section III-C)."""
    run = PATTERNS[name]()
    _, stats = rvv.compile_to_rvv(run.program)
    mve = rvv.mve_stats(run.program)
    assert stats.memory_instructions >= mve.memory_instructions
    # every RVV partial access carries a mask/cfg and a move
    assert stats.mask_instructions >= stats.memory_instructions - \
        mve.memory_instructions
    assert stats.vector_instructions >= mve.vector_instructions


def test_multidim_patterns_speed_up():
    """Figure 10: kernels whose accesses a 1D ISA cannot express in one
    instruction (replication / random-base / multi-level strides) speed
    up strongly; dense-collapsible patterns must at least never lose."""
    cfg = MVEConfig()
    strong = ("gemm", "upsample", "xor_cipher", "png_up", "intra_pred")
    weak = ("transpose", "audio_mix", "alpha_blend")
    for name in strong + weak:
        run = PATTERNS[name]()
        _, state = INTERP.run(run.program, run.memory)
        mve_t = cost.simulate(state.trace, cfg).total_cycles
        tr, _ = rvv.compile_to_rvv(run.program)
        rvv_t = cost.simulate(tr, cfg).total_cycles
        bound = 1.5 if name in strong else 1.0
        assert rvv_t / mve_t > bound, (name, rvv_t / mve_t)


def test_average_speedup_in_paper_band():
    """Figures 10/13 (BS): paper reports 2.0x (kernel avg) to 3.8x
    (scheme avg) MVE over RVV; our kernel set must land in that band."""
    cfg = MVEConfig()
    ratios = []
    for name in RVV_COMPARISON_SET:
        run = PATTERNS[name]()
        _, state = INTERP.run(run.program, run.memory)
        mve_t = cost.simulate(state.trace, cfg).total_cycles
        tr, _ = rvv.compile_to_rvv(run.program)
        ratios.append(cost.simulate(tr, cfg).total_cycles / mve_t)
    geo = float(np.exp(np.mean(np.log(ratios))))
    assert 2.0 < geo < 4.5, geo


def test_lane_utilization_claim():
    """Section VII-C: RVV drops BS lane utilization (paper: 23% vs 60%).
    With our optimized-1D RVV baseline the gap is smaller but the
    ordering and a sizeable margin must hold."""
    cfg = MVEConfig()
    mve_u, rvv_u = [], []
    for name in RVV_COMPARISON_SET:
        run = PATTERNS[name]()
        _, state = INTERP.run(run.program, run.memory)
        mve_u.append(cost.simulate(state.trace, cfg).lane_utilization)
        tr, _ = rvv.compile_to_rvv(run.program)
        rvv_u.append(cost.simulate(tr, cfg).lane_utilization)
    assert np.mean(rvv_u) < 0.55
    assert np.mean(mve_u) > 0.60
    assert np.mean(mve_u) > 1.5 * np.mean(rvv_u)


def test_transpose_iteration_count():
    """Section IV: a 512x49 transpose takes 4 iterations (vs 49 in 1D)."""
    run = PATTERNS["transpose"](m=512, n=49)
    loads = [i for i in run.program if i.op.name == "SLD"]
    assert len(loads) == 4
