"""Unit + property tests for the pipeline model (:mod:`repro.timing`).

Hand-built :class:`TimedOp` streams pin the hazard semantics exactly:
RAW/WAR/WAW scoreboard waits, chaining overlap (on a *different* unit)
vs. full serialization, and structural/memory-port conflicts stalling
by exactly the configured penalty.  The hypothesis suite (gated through
``_hypothesis_compat`` like every property suite here) fuzzes the
contractual properties: determinism, config monotonicity (wider issue
or more ports never slows the machine down), and the analytic envelope.
"""
import dataclasses

import pytest
from _hypothesis_compat import given, settings, st

from repro.timing import (CTRL_REG, TAG_REG, Scoreboard, TimedOp,
                          UarchConfig, UARCH_CONFIGS, build_timed_ops,
                          envelope, get_uarch, list_uarchs,
                          simulate_pipeline)

#: A laboratory machine: no front-end or issue-hop latency, wide issue,
#: two array pipes — so only the behavior under test moves the clock.
LAB = UarchConfig.from_dict("lab", {
    "fetch_rate": 64, "decode_latency": 0.0, "issue_width": 8,
    "issue_latency": 0.0, "chaining": False, "chain_latency": 2.0,
    "mem_ports": 1, "fus": {"array": {"pipes": 2}},
})


def _arr(duration, defs=(), uses=()):
    return TimedOp("array", float(duration), defs=defs, uses=uses)


def _load(duration, defs=(), uses=()):
    return TimedOp("mem", float(duration), defs=defs, uses=uses)


# ---------------------------------------------------------------------------
# Scoreboard hazards on hand-built streams.
# ---------------------------------------------------------------------------

def test_raw_hazard_serializes_consumer():
    tl = simulate_pipeline([_arr(10, defs=(1,)), _arr(5, uses=(1,))], LAB)
    assert tl.total_cycles == 15.0
    assert tl.stalls["dependency"] == 10.0
    # independent ops overlap on the two pipes instead
    free = simulate_pipeline([_arr(10, defs=(1,)), _arr(5, uses=(2,))], LAB)
    assert free.total_cycles == 10.0
    assert free.stalls["dependency"] == 0.0


def test_waw_hazard_orders_writers():
    tl = simulate_pipeline([_arr(10, defs=(1,)), _arr(3, defs=(1,))], LAB)
    assert tl.total_cycles == 13.0          # 2nd write waits for the 1st
    assert tl.stalls["dependency"] == 10.0
    free = simulate_pipeline([_arr(10, defs=(1,)), _arr(3, defs=(2,))], LAB)
    assert free.total_cycles == 10.0


def test_war_hazard_writer_waits_for_reader():
    tl = simulate_pipeline([_arr(10, uses=(1,)), _arr(1, defs=(1,))], LAB)
    assert tl.total_cycles == 11.0          # write held until read done
    assert tl.stalls["dependency"] == 10.0
    free = simulate_pipeline([_arr(10, uses=(1,)), _arr(1, defs=(2,))], LAB)
    assert free.total_cycles == 10.0


def test_war_tracking_resets_after_write():
    """Readers gate only the *next* writer, not every later one."""
    sb = Scoreboard(chaining=False)
    rd = _arr(10, uses=(1,))
    sb.commit(rd, 0.0, 10.0)
    wr = _arr(1, defs=(1,))
    assert sb.ready_time(wr) == 10.0        # WAR
    sb.commit(wr, 10.0, 11.0)
    wr2 = _arr(1, defs=(1,))
    assert sb.ready_time(wr2) == 11.0       # WAW vs wr, no stale WAR


def test_scoreboard_virtual_ctrl_register():
    """Config writes serialize against in-flight vector consumers."""
    ops = [TimedOp("ctrl", 1.0, defs=(CTRL_REG,)),
           _arr(10, defs=(1,), uses=(CTRL_REG,)),
           TimedOp("ctrl", 1.0, defs=(CTRL_REG,))]
    tl = simulate_pipeline(ops, LAB)
    # 2nd config waits for the vector op (WAR on the CR file):
    # ctrl@0..1, arr@1..11, ctrl@11..12.
    assert tl.total_cycles == 12.0


# ---------------------------------------------------------------------------
# Chaining.
# ---------------------------------------------------------------------------

CHAINED = dataclasses.replace(LAB, chaining=True, chain_latency=2.0)


def test_chaining_overlaps_dependent_ops_across_units():
    ops = [_load(10, defs=(1,)), _arr(20, uses=(1,))]
    on = simulate_pipeline(ops, CHAINED)
    off = simulate_pipeline(ops, LAB)
    assert on.total_cycles == 22.0    # consumer starts at chain point 2
    assert off.total_cycles == 30.0   # consumer waits for full completion
    assert on.stalls["dependency"] == 2.0
    assert off.stalls["dependency"] == 10.0


def test_chaining_never_beats_completion():
    """A chained consumer of a *short* producer still can't start
    before the producer would have completed anyway."""
    slow_chain = dataclasses.replace(CHAINED, chain_latency=50.0)
    ops = [_load(10, defs=(1,)), _arr(5, uses=(1,))]
    tl = simulate_pipeline(ops, slow_chain)
    assert tl.total_cycles == 15.0    # min(complete, start+50) = 10


def test_chaining_not_through_ctrl():
    """Config results don't chain — consumers wait for completion."""
    ops = [TimedOp("ctrl", 10.0, defs=(CTRL_REG,)),
           _arr(5, uses=(CTRL_REG,))]
    assert (simulate_pipeline(ops, CHAINED).total_cycles
            == simulate_pipeline(ops, LAB).total_cycles == 15.0)


# ---------------------------------------------------------------------------
# Structural hazards.
# ---------------------------------------------------------------------------

def test_two_loads_one_port_stall_exactly_the_access_latency():
    ops = [_load(10, defs=(1,)), _load(10, defs=(2,))]
    tl = simulate_pipeline(ops, LAB)            # mem_ports=1
    assert tl.stalls["memory-port"] == 10.0     # exactly one access
    assert tl.total_cycles == 20.0
    two = simulate_pipeline(
        ops, dataclasses.replace(LAB, mem_ports=2))
    assert two.stalls["memory-port"] == 0.0
    assert two.total_cycles == 10.0


def test_array_pipe_structural_stall():
    ops = [_arr(10), _arr(10), _arr(10)]        # 2 pipes, 3 ops
    tl = simulate_pipeline(ops, LAB)
    assert tl.stalls["structural"] == 10.0      # third op waits one slot
    assert tl.total_cycles == 20.0


def test_issue_width_limits_per_cycle_issue():
    narrow = dataclasses.replace(LAB, issue_width=1)
    ops = [_arr(1), _arr(1)]
    tl = simulate_pipeline(ops, narrow)
    assert tl.stalls["frontend"] == 1.0         # 2nd op bumped a cycle
    assert tl.total_cycles == 2.0
    wide = simulate_pipeline(ops, LAB)
    assert wide.total_cycles == 1.0


def test_issue_hop_and_frontend_floor():
    ua = dataclasses.replace(LAB, issue_latency=16.0, decode_latency=1.0)
    tl = simulate_pipeline([_arr(4)], ua)
    assert tl.total_cycles == 21.0              # decode 1 + hop 16 + 4
    # scalar-core ops skip the core->engine hop
    ts = simulate_pipeline([TimedOp("scalar", 4.0)], ua)
    assert ts.total_cycles == 5.0


# ---------------------------------------------------------------------------
# Surface: timeline bookkeeping, uarch configs, builders.
# ---------------------------------------------------------------------------

def test_stall_keys_always_present_and_breakdown_sums():
    tl = simulate_pipeline([_arr(3)], LAB)
    assert set(tl.stalls) == {"frontend", "dependency", "structural",
                              "memory-port"}
    assert tl.stall_cycles == sum(tl.stalls.values())
    assert tl.lower_bound <= tl.total_cycles <= tl.upper_bound


def test_empty_stream():
    tl = simulate_pipeline([], LAB)
    assert tl.total_cycles == 0.0
    assert envelope([], LAB) == (0.0, 0.0)


def test_shipped_uarch_configs_resolve():
    names = list_uarchs()
    for required in ("mobile-core", "mve-bs", "mve-bp", "mve-bh",
                     "mve-ac", "rvv-1d"):
        assert required in names
        ua = get_uarch(required)
        assert ua.name == required
        # YAML-style round trip
        again = UarchConfig.from_dict(required, ua.to_dict())
        assert again == ua
    assert get_uarch(get_uarch("mve-bs")) is get_uarch("mve-bs")
    assert get_uarch(UARCH_CONFIGS["mve-bs"]).name == "custom"


def test_unknown_uarch_and_unknown_keys_raise():
    with pytest.raises(ValueError):
        get_uarch("cray-1")
    with pytest.raises(ValueError):
        UarchConfig.from_dict("typo", {"fetch_rte": 4})


def test_build_timed_ops_aligned_with_program():
    from repro.core import MVEConfig, compile_program
    from repro.core.patterns import PATTERNS
    run = PATTERNS["daxpy"]()
    cfg = MVEConfig()
    trace = compile_program(run.program, cfg).static_trace
    ops, lanes = build_timed_ops(run.program, trace, cfg)
    assert len(ops) == len(run.program)         # 1:1 static trace
    assert lanes == float(cfg.lanes)
    tags = {op.fu for op in ops}
    assert "mem" in tags and "array" in tags and "ctrl" in tags
    # every vector op reads the control-register file
    for op in ops:
        if op.fu in ("array", "mem"):
            assert CTRL_REG in op.uses


def test_compare_writes_tag_predication_reads_it():
    from repro.core import MVEConfig, compile_program, isa
    F = isa.DType.F
    cfg = MVEConfig()
    prog = isa.Program([
        isa.vsetwidth(32), isa.vsetdimc(1), isa.vsetdiml(0, 8),
        isa.vsld(F, 0, 0, 1),
        isa.vbinary(isa.Op.GT, F, 1, 0, 0),
        isa.vbinary(isa.Op.ADD, F, 2, 0, 0, predicated=True),
    ])
    trace = compile_program(prog, cfg).static_trace
    ops, _ = build_timed_ops(prog, trace, cfg)
    assert TAG_REG in ops[4].defs
    assert TAG_REG in ops[5].uses


# ---------------------------------------------------------------------------
# Properties: determinism, monotonicity, envelope (hypothesis-gated).
# ---------------------------------------------------------------------------

@st.composite
def op_streams(draw):
    n = draw(st.integers(min_value=1, max_value=40))
    ops = []
    for _ in range(n):
        fu = draw(st.sampled_from(["array", "mem", "ctrl", "scalar"]))
        dur = float(draw(st.sampled_from([1, 2, 5, 16, 100])))
        defs = tuple(draw(st.lists(
            st.integers(min_value=-3, max_value=7), max_size=1)))
        uses = tuple(draw(st.lists(
            st.integers(min_value=-3, max_value=7), max_size=2)))
        ops.append(TimedOp(fu, dur, defs=defs, uses=uses))
    return ops


@st.composite
def uarches(draw):
    base = get_uarch(draw(st.sampled_from(
        ["mve-bs", "mve-bp", "mve-ac", "mobile-core"])))
    return dataclasses.replace(
        base,
        issue_width=draw(st.integers(min_value=1, max_value=4)),
        mem_ports=draw(st.integers(min_value=1, max_value=3)),
        chaining=draw(st.booleans()),
        chain_latency=float(draw(st.integers(min_value=0, max_value=20))))


@given(ops=op_streams(), ua=uarches())
@settings(max_examples=60, deadline=None)
def test_pipeline_deterministic_and_inside_envelope(ops, ua):
    a = simulate_pipeline(ops, ua)
    b = simulate_pipeline(ops, ua)
    assert a.total_cycles == b.total_cycles
    assert a.stalls == b.stalls
    lo, hi = envelope(ops, ua)
    assert lo - 1e-9 <= a.total_cycles <= hi + 1e-9
    assert (a.lower_bound, a.upper_bound) == (lo, hi)


@given(ops=op_streams(), ua=uarches())
@settings(max_examples=60, deadline=None)
def test_pipeline_monotone_in_issue_width_and_ports(ops, ua):
    base = simulate_pipeline(ops, ua).total_cycles
    wider = dataclasses.replace(ua, issue_width=ua.issue_width + 1)
    assert simulate_pipeline(ops, wider).total_cycles <= base + 1e-9
    ported = dataclasses.replace(ua, mem_ports=ua.mem_ports + 1)
    assert simulate_pipeline(ops, ported).total_cycles <= base + 1e-9
