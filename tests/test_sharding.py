"""Sharding rules + multi-device behavior (subprocess with host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.parallel.axes import DEFAULT_RULES, spec_for

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_for_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible -> sharded
    s = spec_for(("embed", "heads"), (8192, 8192), mesh, DEFAULT_RULES)
    assert tuple(s) == ("data", "model")
    # a projection dim not divisible by 16 -> replicated
    s = spec_for(("embed", "heads"), (896, 14 * 9), mesh, DEFAULT_RULES)
    assert tuple(s) == ("data", None)
    # each mesh axis used at most once
    s = spec_for(("act_heads", "seq"), (64, 4096), mesh, DEFAULT_RULES)
    assert tuple(s) == ("model", None)
    # fallback cascade: heads fail -> seq takes model
    s = spec_for(("act_heads", "seq"), (56, 4096), mesh, DEFAULT_RULES)
    assert tuple(s) == (None, "model")


def test_spec_for_pod_axis_dropped_on_single_pod():
    mesh = FakeMesh({"data": 16, "model": 16})
    s = spec_for(("batch", None), (256, 128), mesh, DEFAULT_RULES)
    assert tuple(s) == (("data",), None) or tuple(s) == ("data", None)
    mesh2 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    s2 = spec_for(("batch", None), (256, 128), mesh2, DEFAULT_RULES)
    assert tuple(s2)[0] == ("pod", "data")


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_data_parallel_loss_matches_single_device():
    """The sharded train step computes the same loss as 1 device."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import jitted_cell
        from repro.models import LM
        from repro.optim import adamw_init
        from repro.parallel.axes import sharding_context

        cfg = get_config("qwen2-0.5b", reduced=True)
        cell = ShapeCell("t", 32, 8, "train")
        model = LM(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 1, 500),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 1, 500),
            "loss_mask": jnp.ones((8, 32), jnp.float32),
            "positions": jnp.tile(jnp.arange(32), (8, 1)),
            "segment_ids": jnp.ones((8, 32), jnp.int32),
        }
        losses = []
        for shape in ({"data": 1, "model": 1}, {"data": 4, "model": 2}):
            mesh = make_mesh(shape)
            with sharding_context(mesh) as ctx:
                step, _ = jitted_cell(cfg, cell, ctx)
                p, o, m = step(jax.tree.map(jnp.copy, params),
                               adamw_init(params), dict(batch))
                losses.append(float(m["loss"]))
        print("LOSSES", losses[0], losses[1])
        assert abs(losses[0] - losses[1]) < 2e-2, losses
    """)
    assert "LOSSES" in out


@pytest.mark.slow
def test_compressed_allreduce_subprocess():
    """int8 all-gather mean over 4 devices: small error vs exact."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.parallel.compression import compressed_allreduce_mean
        import inspect
        try:
            shard_map = jax.shard_map
        except AttributeError:
            from jax.experimental.shard_map import shard_map
        # replication checking kwarg was renamed check_rep -> check_vma
        sig = inspect.signature(shard_map).parameters
        kw = {k: False for k in ("check_vma", "check_rep") if k in sig}
        mesh = make_mesh({"data": 4})
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((4, 64)).astype(np.float32))
        f = shard_map(lambda v: compressed_allreduce_mean(v[0], "data"),
                      mesh=mesh, in_specs=P("data"), out_specs=P(), **kw)
        got = f(x)
        want = x.mean(axis=0)
        err = float(jnp.abs(got - want).max())
        amax = float(jnp.abs(x).max())
        print("ERR", err, "BOUND", amax / 127 * 2)
        assert err <= amax / 127.0 * 2 + 1e-6
    """, devices=4)
    assert "ERR" in out


@pytest.mark.slow
def test_dryrun_entrypoint_small():
    """The dry-run module itself runs end-to-end (tiny mesh via env)."""
    out = _run_subprocess("""
        import os, dataclasses, jax
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import jitted_cell
        from repro.launch import hlo_analysis
        from repro.parallel.axes import sharding_context

        cfg = get_config("qwen2-0.5b", reduced=True)
        cell = ShapeCell("t", 64, 8, "train")
        mesh = make_mesh({"data": 2, "model": 4})
        with sharding_context(mesh) as ctx:
            step, args = jitted_cell(cfg, cell, ctx)
            compiled = step.lower(*args).compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            coll = hlo_analysis.collective_bytes(compiled.as_text())
            assert cost.get("flops", 0) > 0
            assert coll["total"] > 0, coll
            mem = compiled.memory_analysis()
            assert mem.temp_size_in_bytes > 0
        print("DRYRUN_OK", coll["total"])
    """)
    assert "DRYRUN_OK" in out


def test_collective_bytes_parser():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
      %all-reduce.1 = f32[1024,8]{1,0} all-reduce(f32[1024,8]{1,0} %x)
      %ag = bf16[16,256]{1,0} all-gather(bf16[2,256]{1,0} %y)
      %t = (f32[128]{0}, f32[64]{0}) all-reduce(%a, %b)
      %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z)
      %fusion.2 = f32[9]{0} fusion(%w), calls=%all_reduce_like
      %cp = u8[100]{0} collective-permute(u8[100]{0} %q)
    """
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 1024 * 8 * 4 + 128 * 4 + 64 * 4
    assert got["all-gather"] == 16 * 256 * 2
    assert got["reduce-scatter"] == 32 * 4
    assert got["collective-permute"] == 100
    assert got["total"] == sum(got[k] for k in
                               ("all-reduce", "all-gather",
                                "reduce-scatter", "all-to-all",
                                "collective-permute"))


from _hypothesis_compat import given, settings, st


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["embed", "heads", "mlp", "vocab",
                                 "batch", "seq", None]),
                min_size=1, max_size=4),
       st.lists(st.sampled_from([1, 8, 14, 16, 56, 256, 4096]),
                min_size=1, max_size=4))
def test_spec_for_never_reuses_axes_and_always_divides(names, sizes):
    """Property: any logical spec resolves to a valid PartitionSpec —
    every mesh axis used at most once, every sharded dim divisible."""
    n = min(len(names), len(sizes))
    names, sizes = names[:n], sizes[:n]
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = spec_for(tuple(names), tuple(sizes), mesh, DEFAULT_RULES)
    used = []
    for part, size in zip(tuple(spec), sizes):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        extent = 1
        for a in axes:
            assert a not in used, (spec, names, sizes)
            used.append(a)
            extent *= mesh.shape[a]
        assert size % extent == 0, (spec, names, sizes)


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    """4-stage GPipe over 4 host devices: forward AND grads match the
    sequential composition; bubble math sane."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import bubble_fraction, pipeline_apply

        S, M, MB, D = 4, 8, 2, 16
        mesh = make_mesh({"stage": S})
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, D, D)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

        def stage_fn(ws, h):
            return jnp.tanh(h @ ws["w"])

        apply = pipeline_apply(stage_fn, mesh, S)

        def pp_loss(params, xs):
            y = apply(params, xs)
            return jnp.mean(y ** 2), y

        (pl, py), pg = jax.value_and_grad(pp_loss, has_aux=True)(
            {"w": w}, x)

        def seq_loss(params, xs):
            h = xs.reshape(M * MB, D)
            for s in range(S):
                h = jnp.tanh(h @ params["w"][s])
            return jnp.mean(h ** 2), h.reshape(M, MB, D)

        (sl, sy), sg = jax.value_and_grad(seq_loss, has_aux=True)(
            {"w": w}, x)

        np.testing.assert_allclose(np.asarray(py), np.asarray(sy),
                                   rtol=1e-5, atol=1e-5)
        assert abs(float(pl) - float(sl)) < 1e-6
        np.testing.assert_allclose(np.asarray(pg["w"]),
                                   np.asarray(sg["w"]),
                                   rtol=1e-4, atol=1e-5)
        assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
        print("PIPELINE_OK", float(pl))
    """, devices=4)
    assert "PIPELINE_OK" in out
