"""Golden optimizer-effect regression suite.

Freezes, for every Section-IV pattern, what the pass pipeline *does*:
per-prefix instruction counts, the per-pass removal audit, and the
static-trace :class:`Timeline` totals of the unoptimized (level 0) and
fully-optimized programs.  A pass regression — an optimization that
silently stops firing, or one that starts increasing modeled cycles —
shows up as an exact-value diff here rather than an unexplained shift in
BENCH_engine.json's ``opt`` section.

Regenerating after an *intentional* pass change:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_opt_goldens.py

Counts and cycle totals are integers, so equality is exact.
"""
import json
import os
import pathlib

import pytest

from repro import opt
from repro.core import MVEConfig, compile_program, cost
from repro.core.patterns import PATTERNS

GOLDEN = pathlib.Path(__file__).parent / "data" / "opt_goldens.json"
REGEN = bool(os.environ.get("REPRO_REGEN_GOLDEN"))
CFG = MVEConfig()


def _pattern_entry(name: str) -> dict:
    run = PATTERNS[name]()
    res = opt.optimize_result(run.program, level=opt.MAX_OPT_LEVEL)
    prefix_counts = {
        "+".join(prefix) or "none":
            len(opt.optimize(run.program, passes=prefix))
        for prefix in opt.pipeline_prefixes()
    }
    tl0 = cost.simulate(
        compile_program(run.program, CFG, mode="vm").static_trace, CFG)
    tl3 = cost.simulate(
        compile_program(res.program, CFG, mode="vm").static_trace, CFG)
    return {
        "instructions": {"level0": len(res.source),
                         "full": len(res.program)},
        "prefix_instructions": prefix_counts,
        "removed_by_pass": {r.name: r.removed for r in res.reports},
        "cycles": {"level0": int(tl0.total_cycles),
                   "full": int(tl3.total_cycles)},
    }


def _current() -> dict:
    return {"pipeline": list(opt.DEFAULT_PIPELINE),
            "patterns": {n: _pattern_entry(n) for n in sorted(PATTERNS)}}


@pytest.fixture(scope="module")
def golden():
    if REGEN:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_current(), indent=1, sort_keys=True))
    assert GOLDEN.exists(), \
        "golden file missing - regenerate with REPRO_REGEN_GOLDEN=1"
    return json.loads(GOLDEN.read_text())


@pytest.mark.parametrize("name", sorted(PATTERNS))
def test_opt_effect_frozen(golden, name):
    """Exact per-prefix counts, per-pass removals and cycle totals."""
    assert _pattern_entry(name) == golden["patterns"][name], \
        f"{name}: optimizer effect drifted"


def test_golden_pipeline_matches_registry(golden):
    assert golden["pipeline"] == list(opt.DEFAULT_PIPELINE)
    assert sorted(golden["patterns"]) == sorted(PATTERNS)


def test_optimizer_never_regresses_and_wins_overall(golden):
    """Acceptance: monotone per pattern, strict win on the sweep — for
    both instruction count and modeled cycles."""
    t_i0 = t_if = t_c0 = t_cf = 0
    for name, e in golden["patterns"].items():
        assert e["instructions"]["full"] <= e["instructions"]["level0"], name
        assert e["cycles"]["full"] <= e["cycles"]["level0"], name
        counts = e["prefix_instructions"]
        assert counts["none"] == e["instructions"]["level0"], name
        full_key = "+".join(golden["pipeline"])
        assert counts[full_key] == e["instructions"]["full"], name
        t_i0 += e["instructions"]["level0"]
        t_if += e["instructions"]["full"]
        t_c0 += e["cycles"]["level0"]
        t_cf += e["cycles"]["full"]
    assert t_if < t_i0, "pipeline stopped reducing sweep instruction count"
    assert t_cf < t_c0, "pipeline stopped reducing sweep modeled cycles"
