"""Section IV's data-parallel patterns, executed and priced.

Runs every Swan-library pattern — all of them built with the tracing
kernel frontend (docs/FRONTEND.md) — through the MVE execution engine
(docs/ENGINE.md; the default program-as-data VM shares one XLA executable
across the whole sweep, validating numerics per pattern), prices it on
the bit-serial engine vs the 1-D RVV lowering, and shows the same
multi-dim access executed by the Pallas TPU kernels (gather + scatter =
the transpose pattern).

    PYTHONPATH=src python examples/mve_patterns.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import MVEConfig, cache_info, cost, rvv
from repro.core.patterns import PATTERNS, run_pattern
from repro.kernels.mdgather import mdgather
from repro.kernels.mdscatter import mdscatter


def main():
    cfg = MVEConfig()
    print(f"{'pattern':14s} {'library':12s} {'dim':4s} "
          f"{'mve_us':>8s} {'rvv_us':>8s} {'speedup':>8s}")
    for name in sorted(PATTERNS):
        run = PATTERNS[name]()
        mem_after, state = run_pattern(run, cfg)     # compiled engine
        run.check(np.asarray(mem_after), state)      # always validate
        tl = cost.simulate(state.trace, cfg)
        trace_rvv, _ = rvv.compile_to_rvv(run.program)
        tl_rvv = cost.simulate(trace_rvv, cfg)
        print(f"{name:14s} {run.library:12s} {run.dim:4s} "
              f"{tl.us(2.8):8.2f} {tl_rvv.us(2.8):8.2f} "
              f"{tl_rvv.total_cycles / tl.total_cycles:7.2f}x")

    info = cache_info()
    print(f"\n{len(PATTERNS)} programs executed through "
          f"{info.vm_signatures} VM signature(s) / "
          f"{info.vm_xla_compiles} XLA compilation(s)")

    print("\nPallas kernels: matrix transpose via mdgather + mdscatter")
    m = jnp.arange(64.0, dtype=jnp.float32)
    cols = mdgather(m, dims=(8, 8), strides=(8, 1), base=0)
    out = mdscatter(jnp.zeros_like(m), cols, dims=(8, 8),
                    strides=(1, 8), base=0)
    np.testing.assert_allclose(np.asarray(out).reshape(8, 8),
                               np.asarray(m).reshape(8, 8).T)
    print("  8x8 transpose through the TMU-analogue kernels: OK")


if __name__ == "__main__":
    main()
