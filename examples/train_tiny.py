"""Train a small LM end-to-end on CPU with the production driver:
sharded step, packed data pipeline, async checkpoints, restart.

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]

Use --arch to pick any of the 10 assigned architectures (reduced size);
--full-shapes runs a larger variant (~15M params) for a real loss curve.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeCell
from repro.launch.train import TrainLoopConfig, train_loop
from repro.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-shapes", action="store_true",
                    help="~15M params instead of the smoke config")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if args.full_shapes:
        cfg = dataclasses.replace(cfg, d_model=256, num_layers=4,
                                  d_ff=1024, vocab_size=8192)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"ckpts -> {ckpt_dir}")
    metrics = train_loop(
        cfg, ShapeCell("tiny", args.seq, args.batch, "train"),
        TrainLoopConfig(steps=args.steps, ckpt_dir=ckpt_dir,
                        ckpt_every=50, log_every=10),
        opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=10,
                            total_steps=args.steps))
    print(f"done: loss={metrics['loss']:.4f} "
          f"({metrics['step_time_s']*1e3:.0f} ms/step)")
    print("restart demo: rerunning resumes from the checkpoint")
    metrics2 = train_loop(
        cfg, ShapeCell("tiny", args.seq, args.batch, "train"),
        TrainLoopConfig(steps=args.steps, ckpt_dir=ckpt_dir,
                        ckpt_every=50, log_every=10),
        opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=10,
                            total_steps=args.steps))
    print(f"resumed run final loss: {metrics2['loss']:.4f}")


if __name__ == "__main__":
    main()
