"""Quickstart: the MVE ISA in 60 lines.

Builds the paper's Figure-3 example (a 3D strided load with replication),
executes it on the functional in-cache machine model (through the
program-as-data VM by default — docs/ENGINE.md; ISA reference in
docs/ISA.md), and prices it on the bit-serial engine vs the 1D-RVV
baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MVEConfig, MVEInterpreter, cost, isa, rvv
from repro.core.isa import DType

# -- an "image": 4 rows of 3 reference pixels (Figure 3's 2D layout) -----
refs = np.arange(12, dtype=np.float64).reshape(4, 3)
mem = np.zeros(64)
mem[:12] = refs.ravel()

# -- MVE program: load 2D -> 3D logical register with replication --------
# PR[w][y][x] = MEM[w*3 + x]  : S = (1, 0, 3)   (stride mode 0 replicates)
prog = [
    isa.vsetwidth(32),
    isa.vsetdimc(3),
    isa.vsetdiml(0, 3),      # x: 3 pixels per row
    isa.vsetdiml(1, 3),      # y: replicate each row down a 3x3 block
    isa.vsetdiml(2, 4),      # w: 4 blocks
    isa.vsetldstr(2, 3),
    isa.vsld(DType.F, 0, 0, 1, 0, 3),
    isa.vshi(DType.DW, 1, 0, 1),            # some compute on all lanes
    isa.vsst(DType.F, 0, 16, 1, 2, 2),      # store 3D -> dense
]

interp = MVEInterpreter(MVEConfig())
mem_after, state = interp.run(prog, mem)

got = np.asarray(mem_after[16:16 + 36]).reshape(4, 3, 3)
print("block 0 (row replicated 3x):\n", got[0])
assert (got[0] == refs[0]).all()

# -- cost: one instruction vs the 1D lowering ----------------------------
tl = cost.simulate(state.trace, interp.cfg)
trace_rvv, stats = rvv.compile_to_rvv(prog)
tl_rvv = cost.simulate(trace_rvv, interp.cfg)
ms = rvv.mve_stats(prog)

print(f"\nMVE : {ms.vector_instructions} vector instructions, "
      f"{tl.total_cycles:.0f} cycles")
print(f"RVV : {stats.vector_instructions} vector instructions, "
      f"{tl_rvv.total_cycles:.0f} cycles")
print(f"speedup {tl_rvv.total_cycles / tl.total_cycles:.2f}x, "
      f"lane utilization {tl.lane_utilization:.2f} vs "
      f"{tl_rvv.lane_utilization:.2f}")
