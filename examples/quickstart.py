"""Quickstart: an MVE kernel in 30 lines, no registers, no offsets.

Builds the paper's Figure-3 example (a 3D strided load with replication)
with the tracing kernel frontend (docs/FRONTEND.md): named operands,
a dimension scope, and stride-mode mnemonics instead of hand-assigned
register numbers and raw base addresses.  The built kernel lowers to the
standard ISA program (docs/ISA.md), executes through the program-as-data
VM by default (docs/ENGINE.md), and is priced on the bit-serial engine
vs the 1D-RVV baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.frontend as mve
from repro.core import MVEConfig, cost, rvv
from repro.core.isa import DType
from repro.frontend import BCAST, CR, DERIVED, SEQ


# -- MVE kernel: load 2D refs -> 3D logical register with replication ----
# PR[w][y][x] = refs[w][x]  : S = (1, 0, CR)   (stride mode 0 replicates)
@mve.kernel
def intra_blocks(b, blocks=4, bs=3):
    refs = b.input("refs", (blocks, bs), DType.F)
    pred = b.output("pred", (blocks, bs, bs), DType.F)
    b.width(32)
    with b.dims(bs, bs, blocks, ld_strides={2: bs}):
        row = refs.load(SEQ, BCAST, CR)     # each ref row fills a block
        shifted = row.astype(DType.DW) << 1  # some compute on all lanes
        b.keep(shifted)
        pred.store(row, SEQ, DERIVED, DERIVED)


k = intra_blocks()
print("the built kernel (registers assigned by the allocator):")
print(k.dump())
print(f"\noperand plan: {k.plan}")

refs = np.arange(12, dtype=np.float64).reshape(4, 3)
out, state = k.run({"refs": refs})
print("\nblock 0 (row replicated 3x):\n", out["pred"][0])
assert (out["pred"][0] == refs[0]).all()

# -- cost: one multi-dim instruction vs the 1D lowering ------------------
cfg = MVEConfig()
tl = cost.simulate(state.trace, cfg)
trace_rvv, stats = rvv.compile_to_rvv(k.program)
tl_rvv = cost.simulate(trace_rvv, cfg)
ms = rvv.mve_stats(k.program)

print(f"\nMVE : {ms.vector_instructions} vector instructions, "
      f"{tl.total_cycles:.0f} cycles")
print(f"RVV : {stats.vector_instructions} vector instructions, "
      f"{tl_rvv.total_cycles:.0f} cycles")
print(f"speedup {tl_rvv.total_cycles / tl.total_cycles:.2f}x, "
      f"lane utilization {tl.lane_utilization:.2f} vs "
      f"{tl_rvv.lane_utilization:.2f}")
