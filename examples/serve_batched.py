"""End-to-end serving driver (the paper-kind e2e example).

Trains a small LM briefly, then serves a stream of batched requests
through the continuous-batching engine whose slot management is the MVE
dimension-level mask (one mask bit per request, Section III-E).

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.launch.train import TrainLoopConfig, train_loop
from repro.models import LM
from repro.optim import AdamWConfig


def main():
    cfg = get_config("qwen2-0.5b", reduced=True)
    cfg = dataclasses.replace(cfg, num_layers=2)

    print("== quick training pass (synthetic data) ==")
    metrics = train_loop(
        cfg, ShapeCell("serve-demo", 64, 4, "train"),
        TrainLoopConfig(steps=30, log_every=10),
        opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=30))
    print(f"final train loss: {metrics['loss']:.3f}")

    print("\n== continuous batching ==")
    model = LM(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ContinuousBatchingEngine(cfg, params, batch_slots=4,
                                      max_seq=48)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(10):
        ln = int(rng.integers(2, 8))
        engine.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, ln)
            .astype(np.int32), max_new_tokens=int(rng.integers(2, 6))))
    done = engine.run_until_drained()
    dt = time.time() - t0

    n_tokens = sum(len(r.output) for r in done.values())
    print(f"completed {len(done)} requests, {n_tokens} tokens "
          f"in {dt:.1f}s")
    for rid in sorted(done):
        r = done[rid]
        ttft = (r.first_token_at - r.submitted_at)
        print(f"  req {rid}: prompt={len(r.prompt)} out={r.output} "
              f"ttft={ttft*1e3:.0f}ms")
    print(f"peak slot occupancy used the MVE mask CR: "
          f"{engine.grid.top} slots")


if __name__ == "__main__":
    main()
