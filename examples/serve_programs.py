"""Multi-tenant MVE program serving demo (docs/SERVING.md).

Replays a mixed Section-IV pattern stream — concurrent tenants
submitting recurring *and* data-dependent programs — through the
signature-batched scheduler, and prints the tier/batching decisions,
throughput vs sequential execution, and the shared compile-cache state.

    PYTHONPATH=src python examples/serve_programs.py
"""
import time

import numpy as np

from repro.core import MVEConfig, compile_program
from repro.core import vm
from repro.core.patterns import PATTERNS
from repro.launch.serve import MVEProgramServer

MIX = [("daxpy", 4), ("gemm", 3), ("alpha_blend", 3), ("memcpy", 3),
       ("spmm", 3), ("fir", 2)]          # spmm/fir: a new program per seed


def build_stream():
    stream = []
    for name, count in MIX:
        for i in range(count):
            stream.append((name, PATTERNS[name](seed=i + 1)))
    return stream


def main():
    cfg = MVEConfig()
    vm.prewarm(cfg)                      # the one shared datapath compile
    stream = build_stream()
    print(f"stream: {len(stream)} requests over {len(MIX)} pattern "
          f"families (spmm/fir arrive as fresh programs per request)")

    server = MVEProgramServer(cfg=cfg, promote_after=2, max_batch=16)
    print("\n== replay 1: cold — VM tier, no per-program XLA compiles ==")
    t0 = time.perf_counter()
    for _, r in stream:
        # frontend kernels submit directly — named operands, no flat
        # memory image at the call site (docs/FRONTEND.md)
        server.submit(r.kernel)
    done = server.run_until_drained()
    print(f"served {len(done)} requests in "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

    print("\n== replay 2-3: hot programs promoted to fused batches ==")
    for _ in range(2):
        for _, r in stream:
            server.submit(r.kernel)
        t0 = time.perf_counter()
        server.run_until_drained()
        wall = time.perf_counter() - t0
    print(f"steady replay: {wall * 1e3:.0f} ms "
          f"({len(stream) / wall:.0f} req/s)")
    lat = server.latency_stats(last=len(stream))
    print(f"latency p50={lat['p50'] * 1e3:.1f} ms "
          f"p95={lat['p95'] * 1e3:.1f} ms")

    st = server.scheduler.stats
    print(f"\nscheduler: {st.requests} requests in {st.dispatches} "
          f"dispatches (batch efficiency {st.batch_efficiency:.1f}x), "
          f"{st.promotions} programs promoted, "
          f"{st.signature_buckets} signature buckets")
    print(f"shared caches: {server.scheduler.cache_info()}")

    # sequential baseline + bit-exactness spot check
    cps = [compile_program(r.program, cfg) for _, r in stream]
    for cp, (_, r) in zip(cps, stream):
        cp.run(r.memory)
    t0 = time.perf_counter()
    seq = [cp.run(r.memory)[0] for cp, (_, r) in zip(cps, stream)]
    seq_wall = time.perf_counter() - t0
    print(f"\nsequential per-request run(): {seq_wall * 1e3:.0f} ms "
          f"-> scheduler speedup {seq_wall / wall:.1f}x")
    for (rid, req), mem in zip(sorted(done.items()), seq):
        np.testing.assert_array_equal(np.asarray(mem),
                                      req.result.memory)
    print("results bit-identical to per-request execution")
    first = done[min(done)]
    name, arr = next(iter(first.result.operands.items()))
    print(f"named results: request 0 operand {name!r} shape "
          f"{arr.shape} read back by name")


if __name__ == "__main__":
    main()
