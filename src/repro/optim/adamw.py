"""AdamW with decoupled weight decay, global-norm clipping and a
linear-warmup + cosine-decay schedule.

Optimizer state (m, v in fp32) is sharded exactly like the parameters
(ZeRO style — the launcher maps the same logical axes onto the state
tree), so the memory per device stays O(params / chips).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # "fp32" keeps m/v in float32 (8 bytes/param).  "int8" stores both
    # moments block-quantized (per-row absmax scales, ~2 bytes/param) —
    # the bit-serial paper's low-precision lesson applied to optimizer
    # state; this is what fits 480B-param training state on 512 chips.
    state_format: str = "fp32"


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    progress = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _scale_shape(shape):
    return (shape[:-1] + (1,)) if shape else (1,)


def _quantize_moment(x: jnp.ndarray, signed: bool) -> Dict[str, jnp.ndarray]:
    """Per-row absmax int8 quantization of one moment tensor."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True) if x.ndim else \
        jnp.abs(x)[None]
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127 if signed else 0, 127)
    return {"q": q.astype(jnp.int8),
            "s": scale.astype(jnp.float32).reshape(_scale_shape(x.shape))}


def _dequantize_moment(st: Dict[str, jnp.ndarray],
                       shape) -> jnp.ndarray:
    s = st["s"] if len(shape) else st["s"].reshape(())
    return st["q"].astype(jnp.float32).reshape(shape) * s


def adamw_init(params, state_format: str = "fp32") -> Dict[str, Any]:
    if state_format == "int8":
        def zq(p):
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.zeros(_scale_shape(p.shape), jnp.float32)}
        return {"m": jax.tree.map(zq, params),
                "v": jax.tree.map(zq, params),
                "step": jnp.zeros((), jnp.int32)}
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    quant = cfg.state_format == "int8"

    def upd(p, g, m, v):
        v_floor = 0.0
        if quant:
            # entries of v below half a quantization step read back as 0;
            # floor the denominator by the step's sqrt so those rows take
            # a bounded (not eps-divided) update
            v_floor = jnp.sqrt(v["s"] / bc2)
            if p.shape:
                v_floor = jnp.broadcast_to(v_floor, p.shape)
            else:
                v_floor = v_floor.reshape(())
            m = _dequantize_moment(m, p.shape)
            v = _dequantize_moment(v, p.shape)
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + v_floor + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        if quant:
            m = _quantize_moment(m, signed=True)
            v = _quantize_moment(v, signed=True)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_moment = (lambda x: isinstance(x, dict) and set(x) == {"q", "s"}) \
        if quant else None
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_moment)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_moment)[0]
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params, new_state, metrics
