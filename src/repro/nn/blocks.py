"""Per-layer model workloads assembled from the block kernel zoo.

:func:`model_blocks` maps a registered architecture config
(:mod:`repro.configs`) onto the zoo: each :class:`BlockSpec` pairs one
built :class:`~repro.nn.kernels.BlockRun` (a power-of-two *tile* of the
real layer shapes — the engine's lane grid and the frontend's
power-of-two tree reduction set the tiling) with the first-order
``tiles_per_layer`` multiplier that scales the tile's priced
cycles/energy back up to one full transformer layer.  The formulas are
deliberately first-order (perfect tiling, no edge tiles, no inter-tile
reuse) and documented in docs/MODELS.md — the bench reports per-tile
numbers alongside the multiplier rather than hiding the model.

The attention/KV blocks tile the default arch (qwen2-0.5b class); the
SSM step borrows its state dims from the mamba2-2.7b config and the MoE
gather its routing shape from llama4-scout — one zoo pricing all three
LM families on identical hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from ..configs import get_config
from .kernels import (BLOCK_KERNELS, MULTIDIM_BLOCKS, BlockRun, attn_tile,
                      gemm_tile, kv_gather, kv_scatter, moe_gather,
                      ssm_scan)


def _pow2_floor(x: int, cap: int) -> int:
    """Largest power of two <= min(x, cap) (tile sizes must be pow2)."""
    x = min(int(x), int(cap))
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


@dataclasses.dataclass
class BlockSpec:
    """One priced workload row: a built tile + its per-layer multiplier."""

    name: str
    run: BlockRun
    tiles_per_layer: float
    arch: str
    note: str = ""

    @property
    def multidim(self) -> bool:
        return self.run.name in MULTIDIM_BLOCKS


def model_blocks(arch: str = "qwen2-0.5b", seq_len: int = 128,
                 quick: bool = False) -> List[BlockSpec]:
    """Build the per-layer block workloads for ``arch`` at decode step
    ``seq_len`` (the KV history length a decode token touches).

    Returns seven specs: the KV gather/scatter pair, the attention score
    tile, the QKV and MLP GEMM tiles, the SSM decode step (mamba2 dims)
    and the MoE expert gather (llama4-scout routing).  ``quick`` shrinks
    every tile for smoke runs; tile-count formulas are unchanged.
    """
    cfg = get_config(arch, reduced=quick)
    ssm_cfg = get_config("mamba2-2.7b", reduced=quick)
    moe_cfg = get_config("llama4-scout-17b-a16e", reduced=quick)

    hd = _pow2_floor(cfg.resolved_head_dim, 16 if quick else 64)
    n_kv = _pow2_floor(max(cfg.num_kv_heads, 1), 2 if quick else 4)
    window = _pow2_floor(seq_len, 16 if quick else 64)
    max_seq = 2 * window
    # attention tile: tq query rows x tk cached keys per (head, tile)
    tq = 16 if quick else 64
    tk = 8 if quick else 32
    chunk = 4 if quick else 16
    d_attn = _pow2_floor(cfg.resolved_head_dim, 8 if quick else 16)
    # GEMM tiles: N tokens x K contraction x M output columns
    gn, gk, gm = (16, 4, 16) if quick else (64, 8, 64)
    # SSM: state width must be a power of two for the tree reduction
    ns = _pow2_floor(max(ssm_cfg.ssm_state, 4), 8 if quick else 64)
    di = _pow2_floor(ssm_cfg.d_inner, 32 if quick else 128)
    # MoE: llama4-scout routes each token to 1 expert + 1 shared
    topk = max(moe_cfg.experts_per_token, 1) + 1
    ne = _pow2_floor(max(moe_cfg.num_experts, 2), 4 if quick else 16)
    tokens = 16 if quick else 64
    de = 16 if quick else 32

    kv_elems = seq_len * cfg.num_kv_heads * cfg.resolved_head_dim
    tile_elems = window * n_kv * hd
    kv_tiles = max(1.0, kv_elems / tile_elems)

    attn_tiles = (cfg.num_heads *
                  max(1.0, seq_len / tk) * max(1.0, 1 / tq))

    hd_full = cfg.resolved_head_dim
    qkv_k = cfg.d_model
    qkv_m = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd_full
    qkv_tiles = max(1.0, (1 * qkv_k * qkv_m) / (gn * gk * gm))
    mlp_macs = 3 * cfg.d_model * cfg.d_ff          # gated SwiGLU
    mlp_tiles = max(1.0, mlp_macs / (gn * gk * gm))

    ssm_tiles = max(1.0, (ssm_cfg.d_inner * ssm_cfg.ssm_state) / (di * ns))
    moe_tiles = max(1.0, moe_cfg.d_model / de)

    return [
        BlockSpec("kv_gather",
                  kv_gather(window=window, n_kv=n_kv, head_dim=hd,
                            max_seq=max_seq, pos0=window // 4),
                  kv_tiles, arch,
                  note=f"decode step reads {seq_len}x{cfg.num_kv_heads}"
                       f"x{cfg.resolved_head_dim} KV history"),
        BlockSpec("kv_scatter",
                  kv_scatter(window=window, n_kv=n_kv, head_dim=hd,
                             max_seq=max_seq, pos0=window // 4),
                  kv_tiles, arch,
                  note="cache append / page compaction write side"),
        BlockSpec("attn_tile",
                  attn_tile(tq=tq, tk=tk, d=d_attn, chunk=chunk),
                  attn_tiles, arch,
                  note=f"{cfg.num_heads} heads x ceil({seq_len}/{tk}) "
                       "kv chunks"),
        BlockSpec("qkv_gemm", gemm_tile(n=gn, kdim=gk, m=gm, seed=30),
                  qkv_tiles, arch,
                  note=f"QKV projection {qkv_k}->{qkv_m} per token"),
        BlockSpec("mlp_gemm", gemm_tile(n=gn, kdim=gk, m=gm, seed=31),
                  mlp_tiles, arch,
                  note=f"gated MLP 3x{cfg.d_model}x{cfg.d_ff} MACs"),
        BlockSpec("ssm_scan", ssm_scan(n_state=ns, d_inner=di),
                  ssm_tiles, ssm_cfg.name,
                  note=f"mamba2 decode step {ssm_cfg.d_inner}"
                       f"x{ssm_cfg.ssm_state} state"),
        BlockSpec("moe_gather",
                  moe_gather(tokens=tokens, d_expert=de, n_experts=ne,
                             topk=topk),
                  moe_tiles, moe_cfg.name,
                  note=f"llama4-scout top-{topk} of "
                       f"{moe_cfg.num_experts} experts"),
    ]
