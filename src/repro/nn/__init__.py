"""repro.nn — model-block kernel zoo on the MVE frontend.

Real LM building blocks (KV-cache gather/scatter, online-softmax
attention, bit-plane int GEMM, SSM decode step, MoE expert gather)
written against :class:`repro.frontend.KernelBuilder`, validated
against the pure-jnp oracles in :mod:`repro.kernels.ref`, and priced
end-to-end on every registered target (docs/MODELS.md).

  ops      — composite numerics the base ISA lacks: exp polynomial,
             Newton reciprocal, cross-dimension tree reduction
  kernels  — the zoo: six block-kernel factories returning
             :class:`BlockRun` (kernel + memory + oracle check)
  blocks   — per-layer workload assembly from repro.configs models
"""
from .kernels import (ATTN_ATOL, ATTN_RTOL, BLOCK_KERNELS,
                      MULTIDIM_BLOCKS, BlockRun, attn_tile, gemm_tile,
                      kv_gather, kv_scatter, moe_gather, ssm_scan)
from .blocks import BlockSpec, model_blocks
from . import ops

__all__ = [
    "ATTN_ATOL", "ATTN_RTOL", "BLOCK_KERNELS", "MULTIDIM_BLOCKS",
    "BlockRun", "BlockSpec", "attn_tile", "gemm_tile", "kv_gather",
    "kv_scatter", "model_blocks", "moe_gather", "ops", "ssm_scan",
]
