"""Composite numeric ops for model blocks, built from Table-II MVE ops.

The MVE ISA has no divide, no transcendentals, and no cross-dimension
reduction — the three gaps real LM blocks hit immediately (softmax needs
``exp`` and ``1/sum``; attention scores and SSM outputs reduce over the
*fastest* dimension, while the Section-IV masked tree only halves the
top one).  This module closes each gap by composition, with the
oracle/conformance discipline of the rest of the stack:

* :func:`exp_approx` — ``exp(x)`` for ``x <= 0`` (the post-max-subtract
  domain): Tag-predicated product reduction strips the integer-ish part
  of ``x`` into a product of ``exp(-2**j)`` constants, then a degree-5
  Taylor polynomial covers the ``(-0.25, 0]`` residual — ~45 vector
  ops, relative error ~1e-6 over ``[-60, 0]`` (measured,
  ``tests/test_nn.py``; bound policy in docs/MODELS.md).
* :func:`recip_approx` — ``1/s`` for ``s in [1, max_val]``: predicated
  halving (compare writes the Tag latch; ``s *= 0.5`` where ``s >= 2``)
  range-reduces into ``[1, 2)`` while mirroring the factor into the
  result, then Newton–Raphson ``r <- r * (2 - s*r)`` converges
  quadratically from ``r0 = 2/3`` (error ``(1/3)**2**iters``).
* :func:`tree_reduce_dim0` — log-tree reduction over dimension 0 via a
  scratch region: each step loads two halves with a per-row CR stride
  and combines, leaving one value per top-dim row.

Every helper traces through the ordinary :class:`KernelBuilder` API, so
the emitted programs stay inside the existing ISA/executors/targets —
no new opcodes, and the whole equivalence class (interp == fused == VM
== scheduler == targets == opt prefixes) applies unchanged
(``tests/test_conformance.py``).
"""
from __future__ import annotations

import numpy as np

from ..core.isa import DType
from ..frontend import CR, SEQ
from ..frontend.builder import KernelBuilder, VectorHandle
from ..frontend.operands import Operand

#: Inputs below this are flushed toward exp(-60) ~ 8.8e-27 — zero at
#: fp32 softmax scale, and safely inside the reduction's range.
EXP_CLAMP_LO = -60.0

#: Greedy binary reduction steps: conditionally strip 2**j from |x| and
#: fold exp(-2**j) into the product.  Sums to 63.75, covering the clamp
#: domain; the residual lands in (-0.25, 0].
_EXP_STEPS = (32.0, 16.0, 8.0, 4.0, 2.0, 1.0, 0.5, 0.25)

#: Degree-5 Taylor coefficients of exp, Horner order after the 1/120
#: head: (c4, c3, c2, c1, c0).
_EXP_TAIL = (1.0 / 24.0, 1.0 / 6.0, 0.5, 1.0, 1.0)


def exp_approx(b: KernelBuilder, x: VectorHandle,
               clamp_lo: float = EXP_CLAMP_LO) -> VectorHandle:
    """``exp(x)`` for ``x <= 0`` via predicated product reduction.

    The classic reduce-then-square scheme amplifies both truncation and
    fp32 rounding ``2**s``-fold (a ~5e-5 floor at best); instead the
    integer-ish part of ``x`` is peeled *multiplicatively*: for each
    step ``v`` in 32, 16, ... 0.25, a compare writes the Tag latch and
    two Tag-predicated in-place ops strip ``v`` from ``x`` while
    folding the constant ``exp(-v)`` into the running product — no
    error amplification anywhere.  The residual lies in ``(-0.25, 0]``,
    where a degree-5 Taylor polynomial is accurate to ``r**6/720 ~
    3e-7``; total measured relative error is ~1e-6 over ``[-60, 0]``
    (``tests/test_nn.py``), and ``exp_approx(0) == 1.0`` exactly.
    """
    x = x.max(b.const(DType.F, float(clamp_lo)))   # fresh reg: safe to
    p = b.const(DType.F, 1.0)                      # mutate in place
    for v in _EXP_STEPS:
        x.lte(b.const(DType.F, -v))                # Tag := x <= -v
        b.add(x, b.const(DType.F, v), predicated=True, in_place=True)
        b.mul(p, b.const(DType.F, float(np.exp(-v))),
              predicated=True, in_place=True)
    poly = b.const(DType.F, 1.0 / 120.0)
    for coef in _EXP_TAIL:
        poly *= x
        poly += coef
    return p * poly


def recip_approx(b: KernelBuilder, s: VectorHandle, max_val: float,
                 newton_iters: int = 4) -> VectorHandle:
    """``1/s`` for ``s in [1, max_val]`` without a divide instruction.

    The range reduction runs ``ceil(log2(max_val))`` predicated steps:
    each compares ``s >= 2`` into the Tag latch, then conditionally
    halves both ``s`` and the mirror factor ``r`` (Tag-predicated
    in-place multiplies — masked lanes keep their previous contents).
    Newton–Raphson then refines ``rn = 1/s_reduced`` from ``rn0 = 2/3``;
    with ``s_reduced in [1, 2)`` the initial error is at most 1/3, so
    4 iterations land below fp32 epsilon.  The result is ``rn * r``.
    """
    steps = max(1, int(np.ceil(np.log2(float(max_val)))))
    half = b.const(DType.F, 0.5)
    two = b.const(DType.F, 2.0)
    r = b.const(DType.F, 1.0)
    sr = s.copy()                       # keep the caller's register intact
    for _ in range(steps):
        sr.gte(two)                     # Tag := s_reduced >= 2
        b.mul(sr, half, predicated=True, in_place=True)
        b.mul(r, half, predicated=True, in_place=True)
    rn = b.const(DType.F, 2.0 / 3.0)
    for _ in range(newton_iters):
        t = sr * rn
        t = b.sub(two, t)               # 2 - s*r
        rn *= t
    return rn * r


def tree_reduce_dim0(b: KernelBuilder, src: Operand, dst: Operand,
                     n: int, rows: int, op: str = "add") -> None:
    """Reduce dimension 0 of a ``(rows, n)`` row-major region.

    ``src`` and ``dst`` are scratch operands of shape ``(rows, n)``.
    Each step halves the reduced length: two half-rows load with a CR
    row stride of ``n``, combine (``add``/``max``/``min``), and the
    result stores into ``dst``'s low half.  After ``log2(n)`` steps the
    per-row reductions sit at ``dst[r, 0]`` (element stride ``n`` —
    reload with a CR stride, or ``(BCAST, ...)`` to broadcast them).

    ``n`` must be a power of two and ``(n // 2) * rows`` must fit the
    lane grid; combination order is the pairwise tree that
    :func:`repro.kernels.ref.tree_sum_ref` mirrors, which is what makes
    integer and fp32 blocks bit-exact against their oracles.
    """
    if n < 2 or n & (n - 1):
        raise ValueError(f"tree_reduce_dim0 needs a power-of-two length "
                         f">= 2, got {n}")
    cur, length = src, n
    while length > 1:
        halfn = length // 2
        b.dims(halfn, rows, ld_strides={1: n}, st_strides={1: n})
        va = cur.at(0, 0).load(SEQ, CR)
        vb = cur.at(0, halfn).load(SEQ, CR)
        if op == "add":
            va += vb
        elif op == "max":
            va = va.max(vb)
        elif op == "min":
            va = va.min(vb)
        else:
            raise ValueError(f"unknown reduce op {op!r}")
        dst.at(0, 0).store(va, SEQ, CR)
        cur, length = dst, halfn
