"""The model-block kernel zoo: real LM blocks on the ``@mve.kernel``
frontend.

Six block families cover the per-layer compute of a small LM
(docs/MODELS.md):

  kv_gather    — multi-dimensional strided KV-cache read (the paper's
                 vsld story): a (head_dim, window, kv_heads) tile pulled
                 from a (seq, kv_heads, head_dim) cache in one access
  kv_scatter   — the write side (vsst with CR strides): a new tile
                 scattered into the cache layout
  attn_tile    — attention score + online softmax + PV accumulate
                 (after ``kernels/flash_attention.py``): chunked over
                 kv with running max/sum and exp-rescale correction
  gemm_tile    — tiled int8 GEMM in bit-plane form (after
                 ``bitplane_gemm``): weights as unsigned bytes, planes
                 shifted/masked out with vshi/vand and accumulated with
                 two's-complement sign on plane 7
  ssm_scan     — one diagonal-SSM (Mamba2/SSD-style) decode step:
                 elementwise state decay + input inject, then a
                 cross-dimension tree reduction for the output
  moe_gather   — top-k expert gather through random-base pointer
                 tables (Eq. 1), gate-weighted accumulate

Every block validates against its pure-jnp oracle in
:mod:`repro.kernels.ref` — bit-exact for the integer and
copy/elementwise blocks (the oracles mirror the kernel's combination
order, see ``tree_sum_ref``), and within the documented relative-error
bound for the softmax block (:data:`ATTN_RTOL`; the bound policy lives
in docs/MODELS.md).  Every block builds to a plain
:class:`~repro.core.isa.Program`, so the whole executor/target/optimizer
equivalence class applies unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from ..core.machine import MVEConfig
from ..core.isa import DType
from ..frontend import BCAST, CR, DERIVED, SEQ, Kernel, KernelBuilder
from ..kernels import ref
from .ops import exp_approx, recip_approx, tree_reduce_dim0

LANES = MVEConfig().lanes  # 8192

#: Documented accuracy bound for the softmax/exp path (docs/MODELS.md):
#: exp_approx contributes ~3e-6 relative, recip_approx is fp32-exact,
#: and the fp32 accumulation order differs from the oracle's — measured
#: worst-case relative error is ~1e-5; the asserted bound keeps 20x
#: margin without hiding a real numeric regression.
ATTN_RTOL = 2e-4
ATTN_ATOL = 2e-5


@dataclasses.dataclass
class BlockRun:
    """One built model block: kernel + memory + oracle check.

    The ``check`` callable asserts the executed memory image against the
    block's :mod:`repro.kernels.ref` oracle; ``exactness`` records the
    contract it enforces (``"bit"`` or the documented rtol bound).
    ``error_of`` (when present) returns the measured max relative error
    for bench reporting.
    """

    name: str
    family: str                 # memory | attention | gemm | ssm | moe
    dim: str                    # multi-dimensionality label, like patterns
    kernel: Kernel
    memory: np.ndarray
    check: Callable[[np.ndarray, object], None]
    exactness: str
    flops: float = 0.0
    error_of: Optional[Callable[[np.ndarray], float]] = None

    @property
    def program(self):
        return self.kernel.program


# ---------------------------------------------------------------------------
# kv_gather / kv_scatter — the multi-dimensional random-access story.
# ---------------------------------------------------------------------------

def kv_gather(window: int = 32, n_kv: int = 2, head_dim: int = 16,
              max_seq: int = 64, pos0: int = 8, seed: int = 0) -> BlockRun:
    """Gather a (head_dim, window, n_kv) KV tile from a
    (max_seq, n_kv, head_dim) cache in a single 3-D strided load."""
    rng = np.random.default_rng(seed)
    cache = rng.standard_normal(max_seq * n_kv * head_dim
                                ).astype(np.float32)
    dims = (head_dim, window, n_kv)
    strides = (1, n_kv * head_dim, head_dim)
    base = pos0 * n_kv * head_dim
    expected = np.asarray(ref.mdgather_ref(cache, dims, strides, base))

    b = KernelBuilder("kv_gather")
    co = b.input("cache", (max_seq * n_kv * head_dim,), DType.F,
                 init=cache)
    out = b.output("tile", (n_kv, window, head_dim), DType.F)
    b.width(32)
    with b.dims(*dims, ld_strides={1: strides[1], 2: strides[2]}):
        b.scalar(4)
        v = co.at(base).load(SEQ, CR, CR)
        out.store(v, SEQ, DERIVED, DERIVED)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["tile"].ravel()
        np.testing.assert_array_equal(got, expected)

    return BlockRun("kv_gather", "memory", "3D", k, k.pack(), check,
                    exactness="bit")


def kv_scatter(window: int = 32, n_kv: int = 2, head_dim: int = 16,
               max_seq: int = 64, pos0: int = 8, seed: int = 1
               ) -> BlockRun:
    """Scatter a new (head_dim, window, n_kv) tile into the cache layout
    through store-side CR strides (the vsst path)."""
    rng = np.random.default_rng(seed)
    cache = rng.standard_normal(max_seq * n_kv * head_dim
                                ).astype(np.float32)
    vals = rng.standard_normal((n_kv, window, head_dim)
                               ).astype(np.float32)
    dims = (head_dim, window, n_kv)
    strides = (1, n_kv * head_dim, head_dim)
    base = pos0 * n_kv * head_dim
    import jax.numpy as jnp
    expected = np.asarray(ref.mdscatter_ref(
        jnp.asarray(cache), jnp.asarray(vals.ravel()), dims, strides,
        base))

    b = KernelBuilder("kv_scatter")
    vo = b.input("tile", (n_kv, window, head_dim), DType.F, init=vals)
    co = b.inout("cache", (max_seq * n_kv * head_dim,), DType.F,
                 init=cache)
    b.width(32)
    with b.dims(*dims, st_strides={1: strides[1], 2: strides[2]}):
        b.scalar(4)
        v = vo.load(SEQ, DERIVED, DERIVED)
        co.at(base).store(v, SEQ, CR, CR)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["cache"]
        np.testing.assert_array_equal(got, expected)

    return BlockRun("kv_scatter", "memory", "3D", k, k.pack(), check,
                    exactness="bit")


# ---------------------------------------------------------------------------
# attn_tile — score + online softmax + PV accumulate.
# ---------------------------------------------------------------------------

def attn_tile(tq: int = 64, tk: int = 32, d: int = 16, chunk: int = 16,
              seed: int = 2, scale: Optional[float] = None) -> BlockRun:
    """One attention tile, online-softmax style: kv arrives in chunks;
    a running max/sum pair and an exp correction factor keep the
    partial output consistent (after ``kernels/flash_attention.py``).

    Lane layouts per pass: scores in (chunk, tq), per-row state in
    (tq,), output accumulation in (d, tq) — the accumulator register
    survives layout switches because reconfiguring dimensions never
    touches register contents.
    """
    if tk % chunk or chunk & (chunk - 1):
        raise ValueError("tk must be a multiple of chunk, chunk a power "
                         f"of two; got tk={tk} chunk={chunk}")
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq, d)).astype(np.float32)
    kk_ = rng.standard_normal((tk, d)).astype(np.float32)
    v = rng.standard_normal((tk, d)).astype(np.float32)
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    expected = np.asarray(ref.flash_attention_ref(
        q[None, None], kk_[None, None], v[None, None],
        causal=False, scale=scale))[0, 0]

    b = KernelBuilder("attn_tile")
    qo = b.input("q", (tq, d), DType.F, init=q)
    ko = b.input("k", (tk, d), DType.F, init=kk_)
    vo = b.input("v", (tk, d), DType.F, init=v)
    oo = b.output("o", (tq, d), DType.F)
    so = b.scratch("scores", (tq, chunk), DType.F)
    ro = b.scratch("reduce", (tq, chunk), DType.F)
    mo = b.scratch("m_run", (tq,), DType.F)
    lo = b.scratch("l_run", (tq,), DType.F)
    ao = b.scratch("row_tmp", (tq,), DType.F)
    b.width(32)
    o_acc = None
    for c in range(tk // chunk):
        k0 = c * chunk
        # scores s[kk, q] = scale * sum_d K[k0+kk, d] * Q[q, d]
        b.dims(chunk, tq, ld_strides={0: d, 1: d},
               st_strides={1: chunk})
        b.scalar(6)
        acc = b.const(DType.F, 0.0)
        for dd in range(d):
            kcol = ko.at(k0, dd).load(CR, BCAST)
            qcol = qo.at(0, dd).load(BCAST, CR)
            acc += kcol * qcol
        acc *= scale
        so.at(0, 0).store(acc, SEQ, CR)
        tree_reduce_dim0(b, so, ro, chunk, tq, op="max")
        # running max update + correction factor alpha (per-q lanes)
        b.dims(tq, ld_strides={0: chunk})
        b.scalar(3)
        m_c = ro.at(0, 0).load(CR)
        if c == 0:
            mo.store(m_c, SEQ)
            alpha = None
        else:
            m_old = mo.load(SEQ)
            m_new = m_old.max(m_c)
            mo.store(m_new, SEQ)
            alpha = exp_approx(b, m_old - m_new)
            ao.store(alpha, SEQ)
        # p = exp(s - m_new), back into the score scratch
        b.dims(chunk, tq, st_strides={1: chunk})
        mrow = mo.load(BCAST, SEQ)
        p = exp_approx(b, acc - mrow)
        so.at(0, 0).store(p, SEQ, CR)
        tree_reduce_dim0(b, so, ro, chunk, tq, op="add")
        # running sum update (per-q lanes)
        b.dims(tq, ld_strides={0: chunk})
        b.scalar(2)
        l_c = ro.at(0, 0).load(CR)
        if c == 0:
            lo.store(l_c, SEQ)
        else:
            l_old = lo.load(SEQ)
            l_old *= alpha
            l_old += l_c
            lo.store(l_old, SEQ)
        # O accumulate in (d, q) lanes; rescale past chunks by alpha
        b.dims(d, tq, ld_strides={1: chunk})
        b.scalar(4)
        if c == 0:
            o_acc = b.const(DType.F, 0.0)
            b.keep(o_acc)
        else:
            o_acc *= ao.load(BCAST, SEQ)
        for kk in range(chunk):
            prow = so.at(0, kk).load(BCAST, CR)
            vrow = vo.at(k0 + kk, 0).load(SEQ, BCAST)
            o_acc += prow * vrow
    # normalize: o /= l  (reciprocal composed from existing ops)
    b.dims(tq)
    b.scalar(2)
    r = recip_approx(b, lo.load(SEQ), max_val=tk)
    ao.store(r, SEQ)
    b.dims(d, tq)
    o_acc *= ao.load(BCAST, SEQ)
    oo.store(o_acc, SEQ, DERIVED)
    k = b.build()

    def _got(mem_after):
        return k.unpack(mem_after)["o"]

    def check(mem_after, state):
        np.testing.assert_allclose(_got(mem_after), expected,
                                   rtol=ATTN_RTOL, atol=ATTN_ATOL)

    def error_of(mem_after):
        # true relative error over outputs of meaningful magnitude;
        # smaller outputs sit under the atol term of the contract
        got = _got(mem_after)
        mask = np.abs(expected) >= 1e-2
        return float(np.max(np.abs(got - expected)[mask] /
                            np.abs(expected)[mask]))

    return BlockRun("attn_tile", "attention", "2D", k, k.pack(), check,
                    exactness=f"rtol={ATTN_RTOL:g}",
                    flops=2.0 * tq * tk * (2 * d + 3),
                    error_of=error_of)


# ---------------------------------------------------------------------------
# gemm_tile — bit-plane int8 GEMM (after kernels/bitplane_gemm.py).
# ---------------------------------------------------------------------------

def gemm_tile(n: int = 64, kdim: int = 8, m: int = 64, seed: int = 3
              ) -> BlockRun:
    """C[N,M] = A[N,K] @ W[K,M] on int8 inputs, weight planes peeled
    bit-serially: W lives in memory as unsigned bytes; per plane ``p``
    the kernel shifts/masks the bit out (vshi/vand), scales it back by
    ``2**p`` and accumulates ``A-column * plane`` — subtracting on plane
    7 (two's complement).  Bit-exact against both int8 matmul oracles.
    """
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (n, kdim)).astype(np.int32)
    w = rng.integers(-128, 128, (kdim, m)).astype(np.int32)
    expected = np.asarray(ref.bitplane_matmul_ref(a, w))
    rows_per_iter = min(LANES // m, n, 256)

    b = KernelBuilder("gemm_tile")
    ao = b.input("a", (n, kdim), DType.DW, init=a)
    wo = b.input("w_u8", (kdim, m), DType.DW, init=w & 0xFF)
    co = b.output("c", (n, m), DType.DW)
    b.width(32)
    with b.dims(m, rows_per_iter, ld_strides={1: kdim}):
        one = b.const(DType.DW, 1)
        for n0 in range(0, n, rows_per_iter):
            b.scalar(6)
            acc = b.const(DType.DW, 0)
            for kk in range(kdim):
                b.scalar(4)
                col = ao.at(n0, kk).load(BCAST, CR)
                wrow = wo.at(kk, 0).load(SEQ, BCAST)
                for bit in range(8):
                    plane = wrow >> bit if bit else wrow.copy()
                    plane &= one
                    if bit:
                        plane <<= bit
                    term = col * plane
                    if bit == 7:
                        acc -= term
                    else:
                        acc += term
            co.at(n0, 0).store(acc, SEQ, DERIVED)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["c"].astype(np.int64)
        np.testing.assert_array_equal(got, expected)
        np.testing.assert_array_equal(
            got, np.asarray(ref.int8_matmul_ref(a, w)))

    return BlockRun("gemm_tile", "gemm", "2D", k, k.pack(), check,
                    exactness="bit", flops=2.0 * n * kdim * m)


# ---------------------------------------------------------------------------
# ssm_scan — one diagonal-SSM decode step (models/ssm.py family).
# ---------------------------------------------------------------------------

def ssm_scan(n_state: int = 16, d_inner: int = 64, seed: int = 4
             ) -> BlockRun:
    """h' = a * h + b ⊗ x (elementwise, state-major lanes), then
    y[p] = tree-sum_n c[n] * h'[p, n] — the cross-dimension reduction
    the base ISA lacks, supplied by :func:`tree_reduce_dim0`."""
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((d_inner, n_state)).astype(np.float32)
    a = rng.uniform(0.0, 1.0, (d_inner, n_state)).astype(np.float32)
    bvec = rng.standard_normal(n_state).astype(np.float32)
    x = rng.standard_normal(d_inner).astype(np.float32)
    cvec = rng.standard_normal(n_state).astype(np.float32)
    exp_h, exp_y = (np.asarray(r) for r in
                    ref.ssm_scan_ref(h, a, bvec, x, cvec))

    b = KernelBuilder("ssm_scan")
    ho = b.inout("h", (d_inner, n_state), DType.F, init=h)
    ao = b.input("a", (d_inner, n_state), DType.F, init=a)
    bo = b.input("b", (n_state,), DType.F, init=bvec)
    xo = b.input("x", (d_inner,), DType.F, init=x)
    co = b.input("c", (n_state,), DType.F, init=cvec)
    yo = b.output("y", (d_inner,), DType.F)
    so = b.scratch("prod", (d_inner, n_state), DType.F)
    ro = b.scratch("reduce", (d_inner, n_state), DType.F)
    b.width(32)
    with b.dims(n_state, d_inner):
        b.scalar(5)
        t = bo.load(SEQ, BCAST) * xo.load(BCAST, SEQ)
        hn = ao.load(SEQ, DERIVED) * ho.load(SEQ, DERIVED)
        hn += t
        ho.store(hn, SEQ, DERIVED)
        w = co.load(SEQ, BCAST) * hn
        so.store(w, SEQ, DERIVED)
    tree_reduce_dim0(b, so, ro, n_state, d_inner, op="add")
    b.dims(d_inner, ld_strides={0: n_state})
    b.scalar(2)
    yo.store(ro.at(0, 0).load(CR), SEQ)
    k = b.build()

    def check(mem_after, state):
        out = k.unpack(mem_after)
        np.testing.assert_array_equal(out["h"], exp_h)
        np.testing.assert_array_equal(out["y"], exp_y)

    return BlockRun("ssm_scan", "ssm", "2D", k, k.pack(), check,
                    exactness="bit", flops=5.0 * d_inner * n_state)


# ---------------------------------------------------------------------------
# moe_gather — top-k expert gather through pointer tables (Eq. 1).
# ---------------------------------------------------------------------------

def moe_gather(tokens: int = 64, d_expert: int = 32, n_experts: int = 8,
               topk: int = 2, seed: int = 5) -> BlockRun:
    """y[t] = sum_j gate[t,j] * W[expert[t,j], :]: per-token expert rows
    arrive through random-base loads walking a pointer table built from
    the routing decision — the paper's 4th, "random" dimension."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((n_experts, d_expert)).astype(np.float32)
    experts = rng.integers(0, n_experts, (tokens, topk))
    gates = rng.uniform(0.1, 1.0, (tokens, topk)).astype(np.float32)
    gates = (gates / gates.sum(axis=1, keepdims=True)).astype(np.float32)
    expected = np.asarray(ref.moe_gather_ref(w, experts, gates))

    b = KernelBuilder("moe_gather")
    wo = b.input("w", (n_experts, d_expert), DType.F, init=w)
    go = b.input("gates", (tokens, topk), DType.F, init=gates)
    ptrs = [b.input(f"ptrs{j}", (tokens,), DType.F,
                    init=wo.addr(experts[:, j] * d_expert))
            for j in range(topk)]
    yo = b.output("y", (tokens, d_expert), DType.F)
    b.width(32)
    with b.dims(d_expert, tokens, ld_strides={1: topk}):
        b.scalar(4 + 2 * topk)
        acc = b.const(DType.F, 0.0)
        for j in range(topk):
            row = ptrs[j].rload(SEQ)
            gate = go.at(0, j).load(BCAST, CR)
            acc += row * gate
        yo.store(acc, SEQ, DERIVED)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["y"]
        np.testing.assert_array_equal(got, expected)

    return BlockRun("moe_gather", "moe", "2D+rnd", k, k.pack(), check,
                    exactness="bit", flops=2.0 * tokens * topk * d_expert)


#: The zoo registry, mirroring ``core.patterns.PATTERNS``.
BLOCK_KERNELS: Dict[str, Callable[..., BlockRun]] = {
    "kv_gather": kv_gather,
    "kv_scatter": kv_scatter,
    "attn_tile": attn_tile,
    "gemm_tile": gemm_tile,
    "ssm_scan": ssm_scan,
    "moe_gather": moe_gather,
}

#: The paper's multi-dimensional access story: blocks where MVE must
#: beat the 1D ISA (the models bench asserts this geomean).
MULTIDIM_BLOCKS = ("kv_gather", "kv_scatter", "attn_tile")
