"""Pipeline parallelism: a GPipe schedule as a shard_map program.

Each device along the ``stage`` mesh axis holds one stage's parameters;
microbatches stream through ``jax.lax.ppermute`` in a ``lax.scan`` over
M + S - 1 schedule slots (the classic GPipe bubble).  Because ppermute is
differentiable (its transpose is the reverse permutation), ``jax.grad``
through :func:`pipeline_apply` yields correct per-stage parameter
gradients — no hand-written backward schedule is needed for this
forward-checkpointed formulation.

This complements the DP/FSDP/TP/SP/EP shardings in ``parallel/axes.py``:
on pods larger than the 16-way TP sweet spot, stages replace depth-wise
FSDP regathering with point-to-point activation transfers (bubble
fraction (S-1)/(M+S-1), amortized by microbatch count).

Used by ``examples``/tests on host devices; the same program lowers for
TPU meshes with a ('stage',) or ('stage', 'data') topology.
"""
from __future__ import annotations

from typing import Callable

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

try:
    shard_map = jax.shard_map
except AttributeError:                      # pragma: no cover
    from jax.experimental.shard_map import shard_map

# replication-checking kwarg was renamed check_rep -> check_vma in jax
_NO_CHECK = {k: False for k in ("check_vma", "check_rep")
             if k in inspect.signature(shard_map).parameters}


def pipeline_apply(stage_fn: Callable, mesh: Mesh, num_stages: int,
                   axis: str = "stage"):
    """Returns ``apply(stacked_params, micro_x) -> (M, mb, ...)`` where
    ``stacked_params`` has a leading stage dim (sharded over ``axis``) and
    ``micro_x`` is (M, mb, ...) microbatches (replicated).

    ``stage_fn(params_slice, x) -> y`` must keep the activation shape
    (a residual-block stack), so it can flow through every stage.
    """

    def body(params, micro_x):
        # shard_map gives each stage params with a leading dim of 1
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        s_count = jax.lax.psum(1, axis)
        m = micro_x.shape[0]
        slots = m + num_stages - 1
        perm = [(s, s + 1) for s in range(num_stages - 1)]

        def step(buf, t):
            i = t - stage                       # microbatch index here
            active = jnp.logical_and(i >= 0, i < m)
            x_in = jnp.where(stage == 0,
                             micro_x[jnp.clip(i, 0, m - 1)], buf)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            out = jnp.where(
                jnp.logical_and(stage == s_count - 1, active),
                y, jnp.zeros_like(y))
            nxt = jax.lax.ppermute(y, axis, perm)
            return nxt, out

        zero = jnp.zeros_like(micro_x[0])
        _, outs = jax.lax.scan(step, zero, jnp.arange(slots))
        # only the last stage produced outputs; replicate via psum
        outs = jax.lax.psum(outs, axis)
        # slot t on the last stage carried microbatch t - (S-1)
        return outs[num_stages - 1:]

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        **_NO_CHECK,
    )


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (num_stages - 1) / (num_micro + num_stages - 1)
