"""Logical-axis sharding: one rule table maps model-level axis names onto
mesh axes (DP/FSDP/TP/SP/EP), with automatic divisibility fallback.

Models annotate parameters and activations with *logical* names; the
launcher binds a mesh + rule table via :func:`sharding_context`.  Outside a
context every constraint is a no-op, so the same model code runs on one
CPU device (smoke tests) and on the 512-chip production mesh (dry-run).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Logical axis -> tuple of mesh axes.  'pod' only exists on the multi-pod
# mesh; missing mesh axes are dropped at resolution time.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # parameter axes
    "embed": ("data",),        # FSDP (ZeRO-3) over the data axis
    "vocab": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "ssm_inner": ("model",),
    "conv_dim": ("model",),
    # activation axes
    "batch": ("pod", "data"),
    "seq": ("model",),         # sequence parallelism on the residual stream
    "act_heads": ("model",),
    "kv_seq": ("model",),      # decode KV-cache sequence sharding
    "act_vocab": ("model",),
    "act_expert": ("model",),
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]]


_TLS = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh,
                     rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = current_ctx()
    _TLS.ctx = ShardingCtx(mesh, dict(rules or DEFAULT_RULES))
    try:
        with mesh:
            yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def _resolve_dim(name: Optional[str], size: int, mesh: Mesh,
                 rules: Dict[str, Tuple[str, ...]]):
    """Mesh axes for one logical dim; falls back to replication when the
    dim size does not divide the mesh extent (e.g. 14 heads on 16-way TP)."""
    if name is None:
        return None
    axes = tuple(a for a in rules.get(name, ()) if a in mesh.shape)
    if not axes:
        return None
    extent = int(np.prod([mesh.shape[a] for a in axes]))
    if size % extent != 0:
        # try a prefix of the axes (e.g. drop 'data' keep 'pod')
        for end in range(len(axes) - 1, 0, -1):
            sub = axes[:end]
            ext = int(np.prod([mesh.shape[a] for a in sub]))
            if size % ext == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_for(logical: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules: Dict[str, Tuple[str, ...]]) -> P:
    assert len(logical) == len(shape), (logical, shape)
    used = set()
    parts = []
    for name, size in zip(logical, shape):
        r = _resolve_dim(name, size, mesh, rules)
        # a mesh axis may appear at most once in a spec
        if r is not None:
            axes = (r,) if isinstance(r, str) else tuple(r)
            if any(a in used for a in axes):
                r = None
            else:
                used.update(axes)
        parts.append(r)
    return P(*parts)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op outside a ctx."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(logical, x.shape, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(logical: Sequence[Optional[str]], shape: Sequence[int],
                   ctx: Optional[ShardingCtx] = None) -> NamedSharding:
    ctx = ctx or current_ctx()
    assert ctx is not None, "named_sharding requires a sharding context"
    return NamedSharding(ctx.mesh,
                         spec_for(logical, shape, ctx.mesh, ctx.rules))
