"""Distribution substrate: logical-axis sharding + collectives tricks."""
from . import axes, compression  # noqa: F401
from .axes import (DEFAULT_RULES, constrain, named_sharding,  # noqa: F401
                   sharding_context, spec_for)
