"""Gradient compression: int8 all-gather all-reduce with error feedback.

Distributed-optimization trick for the cross-pod (DCN) gradient sync: the
pod axis has ~10x less bandwidth than ICI, so gradients crossing it are
quantized to int8 with a psum-shared scale.  An all-gather of int8 shards
moves half the bytes of a bf16 ring all-reduce at pod count 2 (and the
error-feedback residual keeps SGD unbiased in expectation).

Two entry points:

  * :func:`compressed_allreduce_mean` — collective primitive, used inside
    ``shard_map`` (tests run it on a host-device mesh);
  * :func:`make_dp_train_step` — a shard_map data-parallel trainer for
    replicated-parameter models (used by examples/tests to demonstrate
    end-to-end compressed sync + error feedback).
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.6
    shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover
    from jax.experimental.shard_map import shard_map

# replication-checking kwarg was renamed check_rep -> check_vma in jax
_NO_CHECK = {k: False for k in ("check_vma", "check_rep")
             if k in inspect.signature(shard_map).parameters}


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compressed_allreduce_mean(x: jnp.ndarray, axis_name: str
                              ) -> jnp.ndarray:
    """Mean over ``axis_name`` with int8 wire format (shard_map body)."""
    n = jax.lax.psum(1, axis_name)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = quantize_int8(x.astype(jnp.float32), scale)
    gathered = jax.lax.all_gather(q, axis_name)        # int8 on the wire
    return gathered.astype(jnp.float32).sum(axis=0) * scale / n


def compress_with_feedback(grads, residual):
    """Apply error feedback: g' = g + residual; the caller transmits
    quantize(g') and keeps the new residual g' - dequant(quant(g'))."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        amax = jnp.max(jnp.abs(gf))
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = quantize_int8(gf, scale)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_r


def make_dp_train_step(loss_fn: Callable, optimizer_update: Callable,
                       mesh: Mesh, axis: str = "data",
                       compress: bool = True):
    """Pure-DP trainer: params replicated, batch sharded over ``axis``,
    gradient mean over ``axis`` int8-compressed with error feedback.

    loss_fn(params, batch) -> scalar; optimizer_update(params, grads,
    opt_state) -> (params, opt_state).
    """

    def step(params, opt_state, residual, batch):
        def body(params, opt_state, residual, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if compress:
                grads = jax.tree.map(
                    lambda g: compressed_allreduce_mean(
                        g.astype(jnp.float32), axis), grads)
                grads, residual = compress_with_feedback(grads, residual)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, axis), grads)
            params, opt_state = optimizer_update(params, grads, opt_state)
            loss = jax.lax.pmean(loss, axis)
            return params, opt_state, residual, loss

        rep = P()
        sharded = P(axis)
        return shard_map(
            body, mesh=mesh,
            in_specs=(rep, rep, rep,
                      jax.tree.map(lambda _: sharded, batch)),
            out_specs=(rep, rep, rep, rep),
            **_NO_CHECK,
        )(params, opt_state, residual, batch)

    return jax.jit(step)


def zeros_like_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
