"""First-class differential verification for the optimizer.

The optimizer's contract is *checkable*, not aspirational: any program,
any pipeline prefix, any executor —

* **state exactness** — memory, the full register file (every lane,
  masked ones included) and the Tag latch after the optimized program
  equal the stepwise oracle's on the unoptimized program, bit for bit;
* **trace semantics** — the optimized program's static trace never
  invents work: its memory events and its config events are
  sub-multisets of the original's, and it is never longer (CSE may
  *substitute* a register move for a load; scheduling only permutes);
* **structure** — instruction count and register pressure never
  increase, and lenient validation keeps passing (the pipeline guard in
  :mod:`repro.opt.pipeline` enforces this on every invocation too).

``tests/test_opt.py`` drives these checks over the pattern library and
hand-written pass unit cases; ``tests/test_conformance.py`` drives them
from the random-program fuzzer, so an optimizer bug surfaces as a
conformance failure rather than a silent miscompile.
"""
from __future__ import annotations

import collections
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from ..core import isa
from ..core.engine import compile_program
from ..core.interp import MVEInterpreter
from ..core.machine import MVEConfig
from .pipeline import optimize, pipeline_prefixes


def assert_states_equal(oracle_state, oracle_memory, result) -> None:
    """Bit-exact memory + register-file + Tag comparison (the same
    contract ``tests/test_conformance.py`` applies across executors)."""
    np.testing.assert_array_equal(np.asarray(oracle_memory),
                                  np.asarray(result.memory))
    assert set(oracle_state.regs) == set(result.regs), \
        "optimizer changed the set of defined registers"
    for r in oracle_state.regs:
        np.testing.assert_array_equal(
            np.asarray(oracle_state.regs[r]), np.asarray(result.regs[r]),
            err_msg=f"register v{r} diverged")
    np.testing.assert_array_equal(np.asarray(oracle_state.tag),
                                  np.asarray(result.tag),
                                  err_msg="Tag latch diverged")


def _canon_event(ev) -> Tuple:
    cb_bits = int(sum(1 << i for i, b in enumerate(ev.cb_mask) if b))
    return (ev.op.value, ev.dtype.suffix if ev.dtype else None,
            int(ev.elements), int(ev.segments), int(ev.scalar_count),
            int(ev.contiguous_run), int(ev.unique_elements),
            int(ev.lines), cb_bits)


def _submultiset(part: Iterable[Tuple], whole: Iterable[Tuple],
                 what: str) -> None:
    extra = collections.Counter(part) - collections.Counter(whole)
    assert not extra, \
        f"optimized trace invents {what} events not in the original: " \
        f"{sorted(extra)[:4]}"


def assert_trace_semantics(base_trace, opt_trace) -> None:
    """The optimized trace does strictly less work of every observable
    kind: no new memory traffic, no new config writes, never longer."""
    assert len(opt_trace) <= len(base_trace), \
        "optimized trace is longer than the original"
    base = [_canon_event(ev) for ev in base_trace]
    opt = [_canon_event(ev) for ev in opt_trace]
    mem_ops = {o.value for o in isa.MEMORY_OPS}
    cfg_ops = {o.value for o in isa.CONFIG_OPS}
    _submultiset((r for r in opt if r[0] in mem_ops),
                 (r for r in base if r[0] in mem_ops), "memory")
    _submultiset((r for r in opt if r[0] in cfg_ops),
                 (r for r in base if r[0] in cfg_ops), "config")


def verify_optimized(program, memories, level: Optional[int] = None,
                     passes: Optional[Sequence[str]] = None,
                     cfg: Optional[MVEConfig] = None,
                     modes: Tuple[str, ...] = ("vm", "fused"),
                     oracle=None) -> isa.Program:
    """Differentially check one pipeline (prefix) on one program.

    Runs the stepwise oracle on the *unoptimized* program per memory
    image, then the optimized program through each compiled executor
    mode, asserting bit-exact state and trace semantics.  ``oracle`` can
    pass precomputed ``[(memory, state), ...]`` results to amortize the
    stepwise runs across prefixes.  Returns the optimized program.
    """
    cfg = cfg or MVEConfig()
    if isinstance(memories, (np.ndarray,)) or not \
            isinstance(memories, (list, tuple)):
        memories = [memories]
    base = isa.Program(getattr(program, "program", program))
    opt_prog = optimize(base, level=level, passes=passes)
    assert len(opt_prog) <= len(base)
    if oracle is None:
        stepper = MVEInterpreter(cfg, compiled=False)
        oracle = [stepper.run_stepwise(base, m) for m in memories]
    base_cp = compile_program(base, cfg, mode="vm")
    for mode in modes:
        cp = compile_program(opt_prog, cfg, mode=mode)
        assert_trace_semantics(base_cp.static_trace, cp.static_trace)
        for (mem_i, st_i), m in zip(oracle, memories):
            _, st_e = cp.run(m)
            assert_states_equal(st_i, mem_i, st_e)
    return opt_prog


def verify_prefixes(program, memories, cfg: Optional[MVEConfig] = None,
                    modes: Tuple[str, ...] = ("vm",)) -> None:
    """Every pipeline prefix of one program, against one shared oracle."""
    cfg = cfg or MVEConfig()
    if isinstance(memories, (np.ndarray,)) or not \
            isinstance(memories, (list, tuple)):
        memories = [memories]
    base = isa.Program(getattr(program, "program", program))
    stepper = MVEInterpreter(cfg, compiled=False)
    oracle = [stepper.run_stepwise(base, m) for m in memories]
    for prefix in pipeline_prefixes():
        verify_optimized(base, memories, passes=prefix, cfg=cfg,
                         modes=modes, oracle=oracle)


def verify_across_targets(program, memory,
                          level: Optional[int] = None,
                          passes: Optional[Sequence[str]] = None,
                          target_names: Optional[Sequence[str]] = None
                          ) -> None:
    """The optimized program stays bit-exact with the stepwise oracle on
    the *unoptimized* program across every registered target."""
    from .. import targets                  # late: targets imports engine

    base = isa.Program(getattr(program, "program", program))
    opt_prog = optimize(base, level=level, passes=passes)
    oracle_mem, oracle_state = MVEInterpreter(
        MVEConfig(), compiled=False).run_stepwise(base, memory)
    for tname in (target_names or targets.list_targets()):
        art = targets.compile(opt_prog, target=tname)
        _, st_t = art.run(memory)
        assert_states_equal(oracle_state, oracle_mem, st_t)
