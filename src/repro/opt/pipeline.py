"""The pass pipeline: ordering, opt levels, and the invariant guard.

``optimize()`` is the one entry point the rest of the repo calls
(:func:`repro.core.engine.compile_program`, ``repro.targets.compile`` and
``frontend.Kernel.compile`` all route their ``opt_level=`` through it).
Results are LRU-cached per ``(program, passes)`` — programs are tuples of
frozen instructions, so they hash — which composes with the engine's own
compile cache: an optimized program is just another program.

Every pass runs inside a guard that *enforces* the optimizer's contract
instead of trusting it: if a pass output is longer, needs more registers,
or stops validating, the guard discards it and keeps the input.  The
differential harness (:mod:`repro.opt.verify`) checks the semantic half
of the contract; the guard checks the structural half on every single
invocation, so a buggy third-party pass degrades to a no-op instead of a
miscompile.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

from ..core import isa
from ..core.isa import Program
from . import passes as _p

#: Registered passes, in canonical pipeline order.  Add a pass by
#: inserting it here (docs/OPTIMIZER.md walks through the steps).
PASSES: Dict[str, Callable[[Sequence], Program]] = {
    "dead-config": _p.dead_config,
    "cse": _p.cse,
    "schedule": _p.schedule,
}

DEFAULT_PIPELINE: Tuple[str, ...] = tuple(PASSES)

#: ``opt_level`` -> pipeline prefix.  Level 0 is the identity; the
#: maximum level runs the full pipeline.
OPT_LEVELS: Dict[int, Tuple[str, ...]] = {
    i: DEFAULT_PIPELINE[:i] for i in range(len(DEFAULT_PIPELINE) + 1)
}
MAX_OPT_LEVEL = len(DEFAULT_PIPELINE)


def pipeline_prefixes() -> Tuple[Tuple[str, ...], ...]:
    """Every prefix of the canonical pipeline, shortest first — the unit
    the differential tests iterate over (``()`` included)."""
    return tuple(DEFAULT_PIPELINE[:i]
                 for i in range(len(DEFAULT_PIPELINE) + 1))


@dataclasses.dataclass(frozen=True)
class PassReport:
    """What one guarded pass application did."""

    name: str
    instructions_in: int
    instructions_out: int
    pressure_in: int
    pressure_out: int
    reverted: bool = False                 # guard rejected the output

    @property
    def removed(self) -> int:
        return self.instructions_in - self.instructions_out


def _max_pressure(program: Sequence) -> int:
    # Late import: repro.frontend imports repro.opt (builder dedup helpers
    # come from core.machine, but Kernel.compile calls optimize()).
    from ..frontend.regalloc import max_pressure
    return max_pressure(list(program))


def _guarded(name: str, fn: Callable, program: Program
             ) -> Tuple[Program, PassReport]:
    """Run one pass under the structural contract.

    The output is kept only if it (a) is no longer than the input,
    (b) does not raise under lenient :func:`repro.core.isa.validate`,
    and (c) does not increase register pressure.  Otherwise the input
    passes through unchanged and the report says so.
    """
    n_in = len(program)
    p_in = _max_pressure(program)
    out = Program(fn(program))
    ok = len(out) <= n_in
    p_out = p_in
    if ok:
        try:
            isa.validate(out)
            p_out = _max_pressure(out)
            ok = p_out <= p_in
        except isa.ProgramError:
            ok = False
    if not ok:
        return program, PassReport(name, n_in, n_in, p_in, p_in,
                                   reverted=True)
    return out, PassReport(name, n_in, len(out), p_in, p_out)


@dataclasses.dataclass(frozen=True)
class OptResult:
    """An optimized program plus the per-pass audit trail."""

    program: Program
    source: Program
    reports: Tuple[PassReport, ...]

    @property
    def removed(self) -> int:
        return len(self.source) - len(self.program)


def _resolve_passes(level: Optional[int],
                    passes: Optional[Sequence[str]]) -> Tuple[str, ...]:
    if passes is not None:
        names = tuple(passes)
        unknown = [n for n in names if n not in PASSES]
        if unknown:
            raise isa.ProgramError(
                f"unknown optimizer pass(es) {unknown}; registered: "
                f"{', '.join(PASSES)}")
        return names
    if level is None:
        return ()
    if level is True:                       # opt_level=True reads naturally
        return DEFAULT_PIPELINE
    lvl = max(0, min(int(level), MAX_OPT_LEVEL))
    return OPT_LEVELS[lvl]


@functools.lru_cache(maxsize=256)
def _optimize_cached(program: Program,
                     names: Tuple[str, ...]) -> OptResult:
    reports = []
    out = program
    for name in names:
        out, report = _guarded(name, PASSES[name], out)
        reports.append(report)
    return OptResult(program=out, source=program, reports=tuple(reports))


def optimize_result(program, level: Optional[int] = None,
                    passes: Optional[Sequence[str]] = None) -> OptResult:
    """Run a pipeline (an ``opt_level`` prefix, or an explicit pass list)
    and return the :class:`OptResult` with per-pass reports."""
    prog = Program(getattr(program, "program", program))
    return _optimize_cached(prog, _resolve_passes(level, passes))


def optimize(program, level: Optional[int] = None,
             passes: Optional[Sequence[str]] = None) -> Program:
    """The program after the requested pipeline; ``level=None``/``0`` is
    the identity.  See :func:`optimize_result` for the audit trail."""
    return optimize_result(program, level=level, passes=passes).program


def cache_clear() -> None:
    """Drop memoized optimization results (test hygiene)."""
    _optimize_cached.cache_clear()
