"""The optimizer passes: dead-config elimination, address-pattern CSE,
and list scheduling over :class:`~repro.core.isa.Program`.

Every pass is a pure function ``Program -> Program`` over the straight-
line MVE IR.  The soundness arguments live next to each pass; the
machine-checked version of those arguments is :mod:`repro.opt.verify`,
which differentially executes every pass (and every pipeline prefix)
against the stepwise oracle — see docs/OPTIMIZER.md for the pass catalog
and the verification contract.

Design constraints shared by all passes:

* **Config trajectory preservation** — the control-register state seen
  by every retained vector instruction is identical before and after a
  pass, so addressing, lane masks and strict validation are unaffected.
* **Register-file exactness** — passes never change which registers a
  program defines or the bits they hold at exit (masked lanes of a
  physical register keep whatever they last held — the conformance
  suite compares the *whole* register file, so value-numbering style
  rewrites must be bit-exact in every lane, not just the active ones).
* **Monotonicity** — a pass never increases instruction count or
  register pressure (enforced again, defensively, by the pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import isa
from ..core.isa import Instr, Op, Program
from ..core.machine import (ControlState, apply_config, config_cell,
                            read_config_cell)

#: Cells every vector instruction observes (lane mask, register-file
#: shape, dtype legality).  Stride cells are observed by memory ops only.
_DIM_CELLS = tuple(("diml", d) for d in range(4))
_STRIDE_CELLS = tuple(("ldstr", d) for d in range(4)) + \
    tuple(("ststr", d) for d in range(4))


def _observed_cells(instr: Instr) -> Tuple[Tuple, ...]:
    """Config cells whose value this (non-config) instruction depends on.

    Conservative: every vector op observes the dimension configuration,
    the width and the whole dimension mask; memory ops additionally
    observe the stride CRs.  ``vsetmask``/``vunsetmask`` are handled by
    the caller — they *observe* the dim cells too (strict validation
    checks the mask bit against the current top-dimension length).
    """
    if instr.op is Op.SCALAR:
        return ()
    cells = (("dimc",), ("width",)) + _DIM_CELLS + (("mask", None),)
    if instr.op in isa.MEMORY_OPS:
        cells = cells + _STRIDE_CELLS
    return cells


def _cells_overlap(cell: Tuple, observed: Tuple) -> bool:
    if cell[0] != observed[0]:
        return False
    if cell[0] == "mask" and observed[1] is None:
        return True                      # wildcard: all mask bits observed
    return cell == observed


# ---------------------------------------------------------------------------
# Pass 1: dead-config elimination.
# ---------------------------------------------------------------------------

def _drop_noop_configs(instrs: Sequence[Instr]) -> List[Instr]:
    """Remove config writes that re-establish the value already in effect
    (including the power-on defaults: ``vsetwidth(32)`` or ``vsetdimc(1)``
    at program start are architectural no-ops)."""
    ctrl = ControlState()
    out: List[Instr] = []
    for instr in instrs:
        if instr.op in isa.CONFIG_OPS:
            cell = config_cell(instr)
            before = read_config_cell(ctrl, cell)
            apply_config(ctrl, instr)
            if read_config_cell(ctrl, cell) == before:
                continue
        out.append(instr)
    return out


def _drop_dead_config_stores(instrs: Sequence[Instr]) -> List[Instr]:
    """Remove config writes that are overwritten before any instruction
    observes them.

    A write at the program tail (no later write to its cell) is kept:
    the final control state is part of the execution result.  Mask
    config ops observe the dimension cells (strict validation reads the
    top-dimension length at each ``vsetmask``/``vunsetmask``).
    """
    n = len(instrs)
    dead = set()
    for i, instr in enumerate(instrs):
        if instr.op not in isa.CONFIG_OPS:
            continue
        cell = config_cell(instr)
        for j in range(i + 1, n):
            nxt = instrs[j]
            if nxt.op in isa.CONFIG_OPS:
                if nxt.op in (Op.SET_MASK, Op.UNSET_MASK) and \
                        cell[0] in ("dimc", "diml"):
                    break                            # observer: strict check
                if config_cell(nxt) == cell:
                    dead.add(i)                      # overwritten, unobserved
                    break
                continue
            if any(_cells_overlap(cell, oc)
                   for oc in _observed_cells(nxt)):
                break                                # observed: live
        # fell through: tail write, keep (final ctrl state preserved)
    return [ins for i, ins in enumerate(instrs) if i not in dead]


def dead_config(program: Sequence[Instr]) -> Program:
    """Collapse ``vsetdimc``/``vsetdiml``/``vset*str``/mask/width sequences
    that re-establish state already in effect, and config writes that are
    overwritten before any instruction can see them.

    Runs the two rules to a fixpoint (each rule can expose work for the
    other), so the pass is idempotent by construction.
    """
    instrs = list(program)
    while True:
        nxt = _drop_dead_config_stores(_drop_noop_configs(instrs))
        if len(nxt) == len(instrs):
            return Program(nxt)
        instrs = nxt


# ---------------------------------------------------------------------------
# Pass 2: address-pattern CSE.
# ---------------------------------------------------------------------------

def _ctrl_digest(ctrl: ControlState) -> Tuple:
    """Full config-state digest: two accesses under equal digests resolve
    identical addresses, lane masks and register-file shapes."""
    return (ctrl.dim_count, tuple(ctrl.dim_lens), tuple(ctrl.ld_strides),
            tuple(ctrl.st_strides), ctrl.kernel_width,
            ctrl.dim_mask.tobytes())


def cse(program: Sequence[Instr]) -> Program:
    """Address-pattern common-subexpression elimination at the IR level.

    Re-executions of a load (``vsld``/``vrld``) or splat (``vsetdup``)
    whose full addressing context — base, stride modes, config-state
    digest, and memory version for loads — matches an available earlier
    instance are rewritten:

    * same destination register → dropped outright (architectural
      no-op: the register already holds exactly those bits);
    * different destination → replaced by ``vcpy vd, r``, which writes
      the *same* lanes a re-execution would (masked write-back), so the
      register file stays bit-exact while the trace loses a memory
      access.

    Any store conservatively invalidates every available load (the
    memory version is part of the load key); a clobber of the holding
    register invalidates its expression.  Predicated producers and
    consumers are excluded — their write-back depends on the Tag latch.
    """
    ctrl = ControlState()
    mem_version = 0
    avail: Dict[Tuple, int] = {}          # expression key -> holding reg
    held: Dict[int, Tuple] = {}           # reg -> key it currently holds

    def kill(reg: Optional[int]) -> None:
        key = held.pop(reg, None)
        if key is not None and avail.get(key) == reg:
            del avail[key]

    out: List[Instr] = []
    for instr in program:
        op = instr.op
        if op in isa.CONFIG_OPS:
            apply_config(ctrl, instr)
            out.append(instr)
            continue
        if op is Op.SCALAR:
            out.append(instr)
            continue
        if op in (Op.SST, Op.RST):
            mem_version += 1
            out.append(instr)
            continue
        if op in (Op.SLD, Op.RLD, Op.SET_DUP) and not instr.predicated:
            if op is Op.SET_DUP:
                key = ("dup", instr.dtype, instr.imm, _ctrl_digest(ctrl))
            else:
                key = (op, instr.dtype, instr.base, tuple(instr.modes or ()),
                       _ctrl_digest(ctrl), mem_version)
            reg = avail.get(key)
            if reg is not None:
                if reg == instr.vd:
                    continue                        # exact re-execution
                kill(instr.vd)
                out.append(isa.vcpy(instr.dtype, instr.vd, reg))
                continue
            kill(instr.vd)
            avail[key] = instr.vd
            held[instr.vd] = key
            out.append(instr)
            continue
        kill(isa.reg_defs(instr))
        out.append(instr)
    return Program(out)


# ---------------------------------------------------------------------------
# Pass 3: list scheduling (Saturn-style loads-ahead-of-compute).
# ---------------------------------------------------------------------------

def _static_interval(ctrl: ControlState, instr: Instr
                     ) -> Optional[Tuple[int, int]]:
    """Inclusive element-address envelope of a *static* access, or ``None``
    when the footprint is data-dependent (random-base accesses)."""
    if instr.op in (Op.RLD, Op.RST):
        return None
    store = instr.op is Op.SST
    dims = ctrl.active_dims()
    strides = ctrl.resolve_strides(tuple(instr.modes or ()), store)
    lo = instr.base + sum(min(0, (ln - 1) * s)
                          for ln, s in zip(dims, strides))
    hi = instr.base + sum(max(0, (ln - 1) * s)
                          for ln, s in zip(dims, strides))
    return (lo, hi)


def _may_alias(a: Optional[Tuple[int, int]],
               b: Optional[Tuple[int, int]]) -> bool:
    if a is None or b is None:
        return True
    return a[0] <= b[1] and b[0] <= a[1]


@dataclasses.dataclass
class _Node:
    index: int
    instr: Instr
    succs: List[int] = dataclasses.field(default_factory=list)
    n_preds: int = 0


def _region_graph(region: Sequence[Instr], ctrl: ControlState
                  ) -> List[_Node]:
    """Dependence graph of one config-free region.

    Edges: register RAW/WAR/WAW, Tag latch (compares write it, predicated
    instructions read it), and memory (conservative interval-based alias
    analysis under the region's — constant — control state).  ``scalar``
    pseudo-instructions carry no dependences: they have no architectural
    effect, only a cost-model one.
    """
    nodes = [_Node(i, ins) for i, ins in enumerate(region)]
    intervals = [
        _static_interval(ctrl, ins) if ins.op in isa.MEMORY_OPS else None
        for ins in region]

    def add_edge(i: int, j: int) -> None:
        if j not in nodes[i].succs:
            nodes[i].succs.append(j)
            nodes[j].n_preds += 1

    for j, nj in enumerate(nodes):
        ins_j = nj.instr
        if ins_j.op is Op.SCALAR:
            continue
        defs_j = isa.reg_defs(ins_j)
        uses_j = set(isa.reg_uses(ins_j))
        writes_tag_j = ins_j.op in isa.COMPARE_OPS
        reads_tag_j = ins_j.predicated
        is_store_j = ins_j.op in (Op.SST, Op.RST)
        # a random-base access reads its pointer array (RLD) or scatters
        # to data-dependent addresses (RST): treat as aliasing everything
        is_mem_j = ins_j.op in isa.MEMORY_OPS
        for i in range(j):
            ins_i = nodes[i].instr
            if ins_i.op is Op.SCALAR:
                continue
            defs_i = isa.reg_defs(ins_i)
            uses_i = set(isa.reg_uses(ins_i))
            if defs_i is not None and (defs_i in uses_j or
                                       defs_i == defs_j):
                add_edge(i, j)           # RAW / WAW
                continue
            if defs_j is not None and defs_j in uses_i:
                add_edge(i, j)           # WAR
                continue
            writes_tag_i = ins_i.op in isa.COMPARE_OPS
            reads_tag_i = ins_i.predicated
            if (writes_tag_i and (reads_tag_j or writes_tag_j)) or \
                    (reads_tag_i and writes_tag_j):
                add_edge(i, j)
                continue
            is_store_i = ins_i.op in (Op.SST, Op.RST)
            is_mem_i = ins_i.op in isa.MEMORY_OPS
            if (is_store_i and is_mem_j) or (is_mem_i and is_store_j):
                if _may_alias(intervals[i], intervals[j]):
                    add_edge(i, j)
    return nodes


#: Scheduling heuristics ``tune()`` sweeps.  Each maps a ready node to a
#: sort key (lower schedules earlier); original index breaks ties so
#: every heuristic is deterministic.
SCHEDULE_PRIORITIES = {
    # keep the input order (the identity schedule)
    "source": lambda ins: 1,
    # issue independent loads as early as possible (Saturn-style: the
    # memory streams start while the scalar core is still busy)
    "loads-first": lambda ins: 0 if ins.op in (Op.SLD, Op.RLD) else 1,
    # start every memory access (loads and ready stores) early
    "memory-first": lambda ins: 0 if ins.op in isa.MEMORY_OPS else 1,
    # sink cost-model scalar blocks below ready vector work
    "scalar-last": lambda ins: 2 if ins.op is Op.SCALAR else 1,
}


def schedule(program: Sequence[Instr],
             priority: str = "loads-first") -> Program:
    """List-schedule each config-free region under the dependence graph.

    Config instructions are scheduling barriers (they redefine the
    addressing context); within a region, ready instructions are issued
    by the chosen priority heuristic (``SCHEDULE_PRIORITIES``), original
    program order breaking ties.  The instruction *multiset* is
    untouched — only the order changes.
    """
    if priority not in SCHEDULE_PRIORITIES:
        raise ValueError(
            f"unknown schedule priority {priority!r}; available: "
            f"{', '.join(sorted(SCHEDULE_PRIORITIES))}")
    rank = SCHEDULE_PRIORITIES[priority]
    ctrl = ControlState()
    out: List[Instr] = []
    region: List[Instr] = []

    def flush() -> None:
        if not region:
            return
        nodes = _region_graph(region, ctrl)
        ready = [n.index for n in nodes if n.n_preds == 0]
        scheduled: List[Instr] = []
        while ready:
            ready.sort(key=lambda i: (rank(nodes[i].instr), i))
            i = ready.pop(0)
            scheduled.append(nodes[i].instr)
            for j in nodes[i].succs:
                nodes[j].n_preds -= 1
                if nodes[j].n_preds == 0:
                    ready.append(j)
        assert len(scheduled) == len(region), "scheduler dropped work"
        out.extend(scheduled)
        region.clear()

    for instr in program:
        if instr.op in isa.CONFIG_OPS:
            flush()
            out.append(instr)
            apply_config(ctrl, instr)
        else:
            region.append(instr)
    flush()
    return Program(out)
