"""``tune()``: pick the cheapest legal schedule for one target.

The list scheduler (:mod:`repro.opt.passes`) is heuristic; which
heuristic wins depends on the target's cost structure (the in-cache
timeline overlaps core issue time with CB busy time differently than the
Neon analytic model).  ``tune()`` makes the choice empirical: it sweeps
every registered schedule priority over the dead-config+CSE'd program,
prices each candidate through ``targets.compile(...).timeline`` — the
*target's* timing model over the static trace — and returns the
artifact of the cheapest one.

    result = repro.opt.tune(kernel, target="mve-bs")
    result.best                  # winning priority name
    result.artifact.run(...)     # compiled, bit-exact, cheapest schedule
    result.table                 # {priority: total_cycles} sweep record
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from ..core.isa import Program
from ..core.machine import MVEConfig
from . import passes as _p
from .pipeline import optimize


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one schedule sweep for one target."""

    target: str
    best: str                          # winning schedule priority
    program: Program                   # the winning optimized program
    artifact: object                   # CompiledArtifact of the winner
    table: Dict[str, float]            # priority -> modeled total cycles

    @property
    def cycles(self) -> float:
        return self.table[self.best]


def tune(kernel_or_program, target: str = "mve-bs",
         cfg: Optional[MVEConfig] = None, mode: Optional[str] = None,
         priorities: Optional[Tuple[str, ...]] = None,
         **overrides) -> TuneResult:
    """Sweep legal schedules for ``target`` and return the cheapest.

    Every candidate starts from the dead-config+CSE'd program (those
    passes are unconditional wins) and differs only in the scheduler's
    priority heuristic, so every candidate is a legal reordering of the
    same instruction multiset — the differential harness's guarantees
    apply to each one.  Pricing uses the target's static-trace timeline
    (no execution happens); ties resolve to the earlier priority in
    ``SCHEDULE_PRIORITIES`` order, so the result is deterministic.
    """
    from .. import targets                 # late: targets imports engine

    tgt = targets.get_target(target)
    base = optimize(kernel_or_program, passes=("dead-config", "cse"))
    names = tuple(priorities or _p.SCHEDULE_PRIORITIES)
    table: Dict[str, float] = {}
    best_name = None
    best_art = None
    best_prog = None
    for name in names:
        candidate = _p.schedule(base, priority=name)
        art = targets.compile(candidate, target=tgt, cfg=cfg, mode=mode,
                              **overrides)
        cycles = art.timeline().total_cycles
        table[name] = cycles
        if best_name is None or cycles < table[best_name]:
            best_name, best_art, best_prog = name, art, candidate
    return TuneResult(target=tgt.name, best=best_name, program=best_prog,
                      artifact=best_art, table=table)
