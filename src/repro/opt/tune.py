"""``tune()``: pick the cheapest legal schedule for one target.

The list scheduler (:mod:`repro.opt.passes`) is heuristic; which
heuristic wins depends on the target's cost structure (hazards, port
conflicts, and chaining reward different orderings than a single-number
analytic total).  ``tune()`` makes the choice empirical: it sweeps
every registered schedule priority over the dead-config+CSE'd program,
prices each candidate, and returns the artifact of the cheapest one.

By default candidates are priced through the *pipeline model* — the
timed twin of the requested target (:func:`repro.targets.timed_variant`,
docs/TIMING.md) — so the sweep optimizes against the machine the
scheduler is actually reordering for: RAW chains it can hide, memory
ports it can keep busy.  ``timing="analytic"`` restores the previous
single-number pricing; targets without a timed twin fall back to it.

    result = repro.opt.tune(kernel, target="mve-bs")
    result.best                  # winning priority name
    result.artifact.run(...)     # compiled, bit-exact, cheapest schedule
    result.table                 # {priority: total_cycles} sweep record
    result.timing                # "pipeline" or "analytic"
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from ..core.isa import Program
from ..core.machine import MVEConfig
from . import passes as _p
from .pipeline import optimize


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one schedule sweep for one target."""

    target: str
    best: str                          # winning schedule priority
    program: Program                   # the winning optimized program
    artifact: object                   # CompiledArtifact of the winner
    table: Dict[str, float]            # priority -> modeled total cycles
    timing: str = "analytic"           # cost model the sweep priced with

    @property
    def cycles(self) -> float:
        return self.table[self.best]


def tune(kernel_or_program, target: str = "mve-bs",
         cfg: Optional[MVEConfig] = None, mode: Optional[str] = None,
         priorities: Optional[Tuple[str, ...]] = None,
         timing: str = "pipeline",
         **overrides) -> TuneResult:
    """Sweep legal schedules for ``target`` and return the cheapest.

    Every candidate starts from the dead-config+CSE'd program (those
    passes are unconditional wins) and differs only in the scheduler's
    priority heuristic, so every candidate is a legal reordering of the
    same instruction multiset — the differential harness's guarantees
    apply to each one.  Pricing uses the static trace (no execution
    happens) under ``timing``: ``"pipeline"`` (default) prices through
    the target's timed twin's in-order pipeline model, ``"analytic"``
    through the target's own timeline; ties resolve to the earlier
    priority in ``SCHEDULE_PRIORITIES`` order, so the result is
    deterministic.  The returned artifact is always compiled for the
    *requested* target, whichever model priced the sweep.
    """
    from .. import targets                 # late: targets imports engine

    if timing not in ("pipeline", "analytic"):
        raise ValueError(f"timing must be 'pipeline' or 'analytic', "
                         f"got {timing!r}")
    tgt = targets.get_target(target)
    pricer = None
    used = "analytic"
    if timing == "pipeline":
        pricer = targets.timed_variant(tgt)
        if pricer is not None:
            used = "pipeline"
    base = optimize(kernel_or_program, passes=("dead-config", "cse"))
    names = tuple(priorities or _p.SCHEDULE_PRIORITIES)
    table: Dict[str, float] = {}
    best_name = None
    best_art = None
    best_prog = None
    for name in names:
        candidate = _p.schedule(base, priority=name)
        art = targets.compile(candidate, target=tgt, cfg=cfg, mode=mode,
                              **overrides)
        if pricer is None:
            cycles = art.timeline().total_cycles
        else:
            # Same compilation, re-priced through the pipeline model
            # (the twin shares the base target's machine config).
            cycles = pricer.timeline(
                art.program, art.cfg, art.cp.static_trace).total_cycles
        table[name] = cycles
        if best_name is None or cycles < table[best_name]:
            best_name, best_art, best_prog = name, art, candidate
    return TuneResult(target=tgt.name, best=best_name, program=best_prog,
                      artifact=best_art, table=table, timing=used)
