"""``repro.opt``: the differentially-tested optimizer pass pipeline.

Three passes over the straight-line MVE IR (:class:`repro.core.isa.Program`),
each a pure ``Program -> Program`` function:

``dead-config``
    Collapse ``vsetdimc``/``vsetdiml``/``vset*str``/mask/width writes
    that re-establish control state already in effect (power-on defaults
    included) or are overwritten before anything observes them.
``cse``
    Address-pattern common-subexpression elimination: a load or splat
    whose full addressing context matches an available earlier instance
    is dropped (same destination) or becomes a register move — traces
    and instruction counts shrink at the IR level, not just in the VM's
    deduplicated pattern tables.
``schedule``
    A list scheduler that reorders independent loads ahead of compute
    under a dependence graph (Saturn-style, arXiv:2412.00997), with
    config instructions as barriers.

Entry points:

    repro.opt.optimize(program, level=3)        # pipeline prefix
    repro.opt.optimize(program, passes=("cse",))
    repro.opt.tune(kernel, target="rvv-1d")     # cheapest schedule/target
    repro.opt.verify_prefixes(program, memory)  # differential harness

or, threaded through the existing compile surfaces:

    kernel.compile(opt_level=3)
    repro.targets.compile(kernel, target="mve-bs", opt_level=3)
    repro.core.compile_program(program, cfg, opt_level=3)

The verification contract — bit-exact memory/registers/Tag against the
stepwise oracle, sub-multiset trace semantics, monotone instruction
count and register pressure, on every pipeline prefix and executor —
is documented in docs/OPTIMIZER.md and enforced by
:mod:`repro.opt.verify`, ``tests/test_opt.py``, and the conformance
fuzzer (``tests/test_conformance.py``).
"""
from .passes import (SCHEDULE_PRIORITIES, cse, dead_config,  # noqa: F401
                     schedule)
from .pipeline import (DEFAULT_PIPELINE, MAX_OPT_LEVEL,  # noqa: F401
                       OPT_LEVELS, PASSES, OptResult, PassReport,
                       cache_clear, optimize, optimize_result,
                       pipeline_prefixes)
from .tune import TuneResult, tune  # noqa: F401
from .verify import (assert_states_equal,  # noqa: F401
                     assert_trace_semantics, verify_across_targets,
                     verify_optimized, verify_prefixes)
