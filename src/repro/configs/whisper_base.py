"""Whisper-base [arXiv:2212.04356] — encoder-decoder; conv audio frontend
is a STUB (input_specs provides precomputed frame embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    activation="gelu",
    encoder_layers=6, num_frames=1500,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        activation="gelu", encoder_layers=2, num_frames=32,
        attn_chunk=32, ce_chunk=32,
    )
