"""Granite-34B-Code [arXiv:2405.04324; hf] — llama-arch, MQA (kv=1)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    activation="silu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16,
        activation="silu", attn_chunk=32, ce_chunk=32,
    )
