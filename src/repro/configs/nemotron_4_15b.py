"""Nemotron-4-15B [arXiv:2402.16819] — dense GQA with squared-ReLU MLP."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000, head_dim=128,
    activation="squared_relu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        activation="squared_relu", attn_chunk=32, ce_chunk=32,
    )
