"""Llama-3.2-11B-Vision [hf:meta-llama] — dense GQA decoder with
cross-attention image layers every 5th layer; vision frontend is a STUB
(input_specs provides precomputed patch embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    activation="silu", rope_theta=5e5,
    cross_attn_every=5, num_image_tokens=1024,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-smoke", family="vlm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        cross_attn_every=2, num_image_tokens=16,
        attn_chunk=32, ce_chunk=32,
    )
