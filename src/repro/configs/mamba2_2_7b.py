"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD (state-space
duality), state=128, headdim=64, expand=2."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
    ssm_ngroups=1, ssm_chunk=256,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_conv=4,
        ssm_ngroups=1, ssm_chunk=32, ce_chunk=32,
    )
