"""Architecture + shape-cell configuration schema.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact published dims) and ``reduced()`` (a tiny same-family
config for CPU smoke tests).  The launcher resolves ``--arch <id>`` through
:func:`repro.configs.get_config`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "silu"     # silu | squared_relu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False     # llama4-style early-fusion shared
    moe_dense_residual: bool = False    # arctic-style dense+MoE in parallel
    capacity_factor: float = 1.25
    moe_group_size: int = 2048
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    # --- hybrid (zamba2) ---
    attn_every: int = 0                 # shared attn block period
    num_shared_blocks: int = 2          # alternating shared weight sets
    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0           # cross-attn layer period
    num_image_tokens: int = 1024        # stub frontend output length
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    num_frames: int = 1500              # stub conv-frontend output length
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    remat: str = "full"                 # full | dots | none
    ce_chunk: int = 1024                # cross-entropy sequence chunking
    attn_chunk: int = 512               # q-chunk for chunked attention
    use_pallas: bool = False
    # Unroll layer scans: used by the dry-run analysis compiles so XLA's
    # cost model (which counts a while body once) sees every layer.
    scan_unroll: bool = False
    # Gradient accumulation: microbatches per optimizer step.  Activation
    # transients (SP all-gathers, saved carries, CE chunks) scale with the
    # microbatch, so this is the production memory knob for big train cells.
    grad_accum: int = 1
    # beyond-paper serving/training knobs (see EXPERIMENTS.md §Perf)
    kv_cache_dtype: str = "bfloat16"    # bfloat16 | float8
    grad_accum_dtype: str = "float32"   # float32 | bfloat16

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so embeddings shard on a 16-way model axis."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.activation == "squared_relu":
            mlp = 2 * d * ff
        else:
            mlp = 3 * d * ff                  # gated (SwiGLU)
        total = 2 * v * d                     # embed + head (untied)
        if self.family == "ssm":
            per_layer = self._ssm_params()
            total += self.num_layers * per_layer
        elif self.family == "hybrid":
            per_layer = self._ssm_params()
            total += self.num_layers * per_layer
            shared = attn + mlp
            total += self.num_shared_blocks * shared
        elif self.family == "moe":
            moe = self.num_experts * (3 * d * ff)
            if self.moe_shared_expert:
                moe += 3 * d * ff
            if self.moe_dense_residual:
                moe += 3 * d * ff
            total += self.num_layers * (attn + moe + d * self.num_experts)
        elif self.family == "encdec":
            # embed + untied head (total already = 2*v*d from above);
            # decoder layers carry self- AND cross-attention
            total += self.encoder_layers * (attn + mlp)
            total += self.num_layers * (2 * attn + mlp)
        elif self.family == "vlm":
            total += self.num_layers * (attn + mlp)
            n_cross = self.num_layers // max(self.cross_attn_every, 1)
            total += n_cross * attn
        else:
            total += self.num_layers * (attn + mlp)
        return int(total)

    def _ssm_params(self) -> int:
        d = self.d_model
        in_proj = d * (2 * self.d_inner + 2 * self.ssm_ngroups *
                       self.ssm_state + self.ssm_heads)
        conv = self.conv_dim * self.ssm_conv
        out = self.d_inner * d
        return in_proj + conv + out + 2 * self.ssm_heads

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        full_moe = self.num_experts * (3 * d * ff)
        active_moe = self.experts_per_token * (3 * d * ff)
        return int(self.param_count() - self.num_layers *
                   (full_moe - active_moe))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing: SSM/hybrid only.

    (All archs here are decoder-capable, so decode_32k always applies; see
    DESIGN.md §Arch-applicability for the skip rationale.)
    """
    if cell.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention architecture: a 512K KV-cache "
                       "decode is quadratic-history; skipped per spec")
    return True, ""
