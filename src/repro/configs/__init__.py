"""Architecture registry: ``--arch <id>`` resolution.

The ten assigned architectures (+ the paper's own MVE geometry config live
in repro.core.machine.MVEConfig).
"""
from __future__ import annotations

from . import (arctic_480b, granite_34b, llama4_scout_17b,
               llama_3_2_vision_11b, mamba2_2_7b, nemotron_4_15b,
               qwen2_0_5b, qwen2_72b, whisper_base, zamba2_2_7b)
from .base import SHAPES, ModelConfig, ShapeCell, cell_supported  # noqa

_MODULES = {
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "mamba2-2.7b": mamba2_2_7b,
    "qwen2-72b": qwen2_72b,
    "qwen2-0.5b": qwen2_0_5b,
    "nemotron-4-15b": nemotron_4_15b,
    "granite-34b": granite_34b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "arctic-480b": arctic_480b,
    "zamba2-2.7b": zamba2_2_7b,
    "whisper-base": whisper_base,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.reduced() if reduced else mod.CONFIG
