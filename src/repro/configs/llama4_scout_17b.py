"""Llama-4-Scout-17B-16E [hf:meta-llama] — MoE 16 experts top-1 with a
shared (early-fusion) expert."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    activation="silu", rope_theta=5e5,
    num_experts=16, experts_per_token=1, moe_shared_expert=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, head_dim=16,
        num_experts=4, experts_per_token=1, moe_shared_expert=True,
        moe_group_size=64, attn_chunk=32, ce_chunk=32,
    )
