"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone with two
alternating *shared* attention+MLP blocks applied every 6 layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    activation="gelu",
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
    ssm_ngroups=1, ssm_chunk=256,
    attn_every=6, num_shared_blocks=2,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        activation="gelu",
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_conv=4,
        ssm_chunk=32, attn_every=2, num_shared_blocks=2,
        attn_chunk=32, ce_chunk=32,
    )
