"""Qwen2-0.5B [arXiv:2407.10671; hf] — dense GQA (kv=2) with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, head_dim=64,
    activation="silu", qkv_bias=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="dense",
        num_layers=2, d_model=56, num_heads=7, num_kv_heads=1,
        d_ff=96, vocab_size=512, head_dim=8,
        activation="silu", qkv_bias=True, attn_chunk=32, ce_chunk=32,
    )
