"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base] —
128-expert top-2 MoE with a parallel dense residual MLP."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    activation="silu",
    num_experts=128, experts_per_token=2, moe_dense_residual=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="arctic-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, head_dim=16,
        num_experts=8, experts_per_token=2, moe_dense_residual=True,
        moe_group_size=64, attn_chunk=32, ce_chunk=32,
    )
