"""Qwen2-72B [arXiv:2407.10671; hf] — dense GQA with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    activation="silu", qkv_bias=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        activation="silu", qkv_bias=True, attn_chunk=32, ce_chunk=32,
    )
