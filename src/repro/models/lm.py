"""Model assembly for every assigned architecture family.

One :class:`LM` object per config exposes:

  * ``abstract_params()`` / ``init_params(key)``  (+ logical sharding axes)
  * ``loss(params, batch)``                — training objective
  * ``prefill(params, batch)``             — returns (last-token logits, cache)
  * ``decode_step(params, cache, tokens, cache_index)``

Families: dense (GQA), moe (GShard EP), ssm (Mamba2/SSD), hybrid (zamba2
shared blocks), vlm (interleaved cross-attention), encdec (whisper).
Layers are stacked and scanned (small HLO, checkpointed per layer).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeCell
from ..parallel.axes import constrain
from .attention import chunked_attention, decode_attention, update_cache
from .common import (ParamDef, abstract_tree, activation_fn, axes_tree,
                     chunked_cross_entropy, materialize_tree, rmsnorm, rope,
                     sinusoidal_positions)
from .moe import moe_ffn
from .ssm import mamba_block


def _tree_index(tree, i):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        tree)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def constrain_like(params, axes, skip_leading: int = 1):
    """Re-assert sharding of per-layer parameter slices *inside* a scan
    body.  Without this, XLA hoists the FSDP all-gather of the stacked
    weights out of the layer loop and materializes the full unsharded
    parameter stack (observed: 72B train peak 37 GB/device -> ~10 GB with
    the constraint).  ``skip_leading`` drops the scanned 'layers' axis."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_a = jax.tree.flatten(axes, is_leaf=_is_axes_leaf)[0]
    out = [constrain(x, *a[skip_leading:])
           for x, a in zip(flat_p, flat_a)]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig, L: int, gated: bool = False,
               kv_in: Optional[int] = None) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq = cfg.num_heads * hd
    nkv = cfg.num_kv_heads * hd
    kv_in = kv_in or d
    p = {
        "norm": ParamDef((L, d), ("layers", None), init="ones"),
        "wq": ParamDef((L, d, nq), ("layers", "embed", "heads")),
        "wk": ParamDef((L, kv_in, nkv), ("layers", "embed", "kv")),
        "wv": ParamDef((L, kv_in, nkv), ("layers", "embed", "kv")),
        "wo": ParamDef((L, nq, d), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDef((L, nq), ("layers", "heads"), init="zeros")
        p["bk"] = ParamDef((L, nkv), ("layers", "kv"), init="zeros")
        p["bv"] = ParamDef((L, nkv), ("layers", "kv"), init="zeros")
    if gated:
        p["gate"] = ParamDef((L,), ("layers",), init="zeros")
    return p


def _mlp_defs(cfg: ModelConfig, L: int) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    p = {
        "norm": ParamDef((L, d), ("layers", None), init="ones"),
        "wi": ParamDef((L, d, ff), ("layers", "embed", "mlp")),
        "wo": ParamDef((L, ff, d), ("layers", "mlp", "embed")),
    }
    if cfg.activation != "squared_relu":
        p["wg"] = ParamDef((L, d, ff), ("layers", "embed", "mlp"))
    return p


def _dense_mlp_defs(cfg: ModelConfig, L: int) -> Dict:
    """Un-stacked-expert dense MLP used as shared expert / dense residual."""
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamDef((L, d, ff), ("layers", "embed", "mlp")),
        "wg": ParamDef((L, d, ff), ("layers", "embed", "mlp")),
        "wo": ParamDef((L, ff, d), ("layers", "mlp", "embed")),
    }


def _moe_defs(cfg: ModelConfig, L: int) -> Dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "norm": ParamDef((L, d), ("layers", None), init="ones"),
        "router": ParamDef((L, d, e), ("layers", "embed", None)),
        "wi": ParamDef((L, e, d, ff), ("layers", "expert", "embed", None)),
        "wg": ParamDef((L, e, d, ff), ("layers", "expert", "embed", None)),
        "wo": ParamDef((L, e, ff, d), ("layers", "expert", None, "embed")),
    }
    if cfg.moe_shared_expert:
        p["shared"] = _dense_mlp_defs(cfg, L)
    if cfg.moe_dense_residual:
        p["dense"] = _dense_mlp_defs(cfg, L)
    return p


def _ssm_defs(cfg: ModelConfig, L: int) -> Dict:
    d = cfg.d_model
    din = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    h = cfg.ssm_heads
    return {
        "in_norm": ParamDef((L, d), ("layers", None), init="ones"),
        "w_zx": ParamDef((L, d, 2 * din), ("layers", "embed", "ssm_inner")),
        "w_bc": ParamDef((L, d, 2 * gn), ("layers", "embed", None)),
        "w_dt": ParamDef((L, d, h), ("layers", "embed", None)),
        "dt_bias": ParamDef((L, h), ("layers", None), init="zeros"),
        "conv_w": ParamDef((L, cfg.ssm_conv, cfg.conv_dim),
                           ("layers", None, "conv_dim")),
        "conv_b": ParamDef((L, cfg.conv_dim), ("layers", "conv_dim"),
                           init="zeros"),
        "a_log": ParamDef((L, h), ("layers", None), init="ones"),
        "d_skip": ParamDef((L, h), ("layers", None), init="ones"),
        "gate_norm": ParamDef((L, din), ("layers", "ssm_inner"),
                              init="ones"),
        "w_out": ParamDef((L, din, d), ("layers", "ssm_inner", "embed")),
    }


def build_param_defs(cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.padded_vocab
    L = cfg.num_layers
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed")),
        "final_norm": ParamDef((d,), (None,), init="ones"),
        "lm_head": ParamDef((d, v), ("embed", "vocab")),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        defs["blocks"] = {"attn": _attn_defs(cfg, L),
                          "mlp": _mlp_defs(cfg, L)}
        if fam == "vlm":
            lc = L // cfg.cross_attn_every
            defs["cross"] = _attn_defs(cfg, lc, gated=True)
    elif fam == "moe":
        defs["blocks"] = {"attn": _attn_defs(cfg, L),
                          "moe": _moe_defs(cfg, L)}
    elif fam == "ssm":
        defs["blocks"] = _ssm_defs(cfg, L)
    elif fam == "hybrid":
        defs["blocks"] = _ssm_defs(cfg, L)
        nb = cfg.num_shared_blocks
        defs["shared"] = {"attn": _attn_defs(cfg, nb),
                          "mlp": _mlp_defs(cfg, nb)}
    elif fam == "encdec":
        le = cfg.encoder_layers
        defs["enc_blocks"] = {"attn": _attn_defs(cfg, le),
                              "mlp": _mlp_defs(cfg, le)}
        defs["enc_norm"] = ParamDef((d,), (None,), init="ones")
        defs["blocks"] = {"attn": _attn_defs(cfg, L),
                          "cross": _attn_defs(cfg, L),
                          "mlp": _mlp_defs(cfg, L)}
    else:
        raise ValueError(f"unknown family {fam}")
    return defs


# ---------------------------------------------------------------------------
# Layer applications
# ---------------------------------------------------------------------------

def _proj_qkv(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
              kv_input: Optional[jnp.ndarray] = None):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    kv_src = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", kv_src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", kv_src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, kv_src.shape[1], cfg.num_kv_heads, hd)
    v = v.reshape(b, kv_src.shape[1], cfg.num_kv_heads, hd)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, None, None)
    return q, k, v


def _self_attention(cfg: ModelConfig, p: Dict, h: jnp.ndarray,
                    positions, segment_ids, mode: str,
                    cache_kv=None, cache_index=None, causal: bool = True):
    """Returns (h_out, (k_cache,v_cache)|kv-to-collect|None)."""
    x = rmsnorm(h, p["norm"], cfg.norm_eps)
    q, k, v = _proj_qkv(cfg, p, x)
    if cfg.family != "encdec" and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    side = None
    if mode == "decode":
        ck, cv = cache_kv
        ck, cv = update_cache(ck, cv, k, v, cache_index)
        ck = constrain(ck, "batch", "kv_seq", None, None)
        cv = constrain(cv, "batch", "kv_seq", None, None)
        out = decode_attention(q, ck, cv, cache_index)
        side = (ck, cv)
    else:
        out = chunked_attention(q, k, v, causal=causal,
                                segment_ids=segment_ids,
                                chunk=cfg.attn_chunk,
                                use_pallas=cfg.use_pallas)
        if mode == "prefill":
            side = (k, v)
    b, s = h.shape[:2]
    out = out.reshape(b, s, -1)
    h = h + jnp.einsum("bsh,hd->bsd", out, p["wo"])
    h = constrain(h, "batch", "seq", None)
    return h, side


def _cross_attention(cfg: ModelConfig, p: Dict, h: jnp.ndarray,
                     kv_input=None, cached_kv=None, gated: bool = False):
    """Cross-attn against encoder output / image embeds (non-causal)."""
    x = rmsnorm(h, p["norm"], cfg.norm_eps)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if cached_kv is None:
        q, k, v = _proj_qkv(cfg, p, x, kv_input=kv_input)
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
        if "bq" in p:
            q = q + p["bq"]
        q = q.reshape(b, s, cfg.num_heads, hd)
        k, v = cached_kv
    out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk,
                            use_pallas=cfg.use_pallas)
    out = out.reshape(b, s, -1)
    delta = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if gated:
        delta = delta * jnp.tanh(p["gate"]).astype(delta.dtype)
    return h + delta


def _mlp(cfg: ModelConfig, p: Dict, h: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm(h, p["norm"], cfg.norm_eps)
    act = activation_fn(cfg.activation)
    u = act(jnp.einsum("bsd,df->bsf", x, p["wi"]))
    if "wg" in p:
        u = u * jnp.einsum("bsd,df->bsf", x, p["wg"])
    u = constrain(u, "batch", None, "mlp")
    h = h + jnp.einsum("bsf,fd->bsd", u, p["wo"])
    return constrain(h, "batch", "seq", None)


def _cross_kv_from(cfg: ModelConfig, p_stack: Dict, src: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute stacked cross K/V caches: (L?, B, T, K, hd)."""
    hd = cfg.resolved_head_dim
    k = jnp.einsum("btd,ldh->lbth", src, p_stack["wk"])
    v = jnp.einsum("btd,ldh->lbth", src, p_stack["wv"])
    if "bk" in p_stack:
        k = k + p_stack["bk"][:, None, None, :]
        v = v + p_stack["bv"][:, None, None, :]
    lc, b, t, _ = k.shape
    k = k.reshape(lc, b, t, cfg.num_kv_heads, hd)
    v = v.reshape(lc, b, t, cfg.num_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# The model object
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LM:
    cfg: ModelConfig

    def __post_init__(self):
        self.defs = build_param_defs(self.cfg)
        self._axes = axes_tree(self.defs)

    def _unroll(self):
        return True if self.cfg.scan_unroll else 1

    # ---- parameters ----
    def abstract_params(self):
        return abstract_tree(self.defs)

    def param_axes(self):
        return axes_tree(self.defs)

    def init_params(self, key: jax.Array):
        return materialize_tree(self.defs, key)

    # ---- embedding ----
    def _embed(self, params, tokens, positions=None):
        h = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.family == "encdec" and positions is not None:
            table = sinusoidal_positions(8192, self.cfg.d_model)
            h = h + jnp.take(table, jnp.clip(positions, 0, 8191),
                             axis=0).astype(h.dtype)
        return constrain(h, "batch", "seq", None)

    # ---- backbones ----
    def _transformer_stack(self, params, h, positions, segment_ids, mode,
                           cache=None, cache_index=None, image_embeds=None):
        cfg = self.cfg
        L = cfg.num_layers
        blocks = params["blocks"]
        cross = params.get("cross")
        remat = (cfg.remat != "none") and mode == "train"

        def body(carry, xs):
            h, aux = carry
            if mode == "decode":
                p, idx, ck, cv = xs
            else:
                p, idx = xs
                ck = cv = None
            p = constrain_like(p, self._axes["blocks"])
            if cfg.cross_attn_every:
                every = cfg.cross_attn_every

                def do_cross(hh):
                    ci = idx // every
                    cp = _tree_index(cross, ci)
                    cp = constrain_like(cp, self._axes["cross"])
                    if mode == "decode":
                        ckv = (_tree_index(cache["cross_k"], ci),
                               _tree_index(cache["cross_v"], ci))
                        return _cross_attention(cfg, cp, hh,
                                                cached_kv=ckv, gated=True)
                    return _cross_attention(cfg, cp, hh,
                                            kv_input=image_embeds,
                                            gated=True)

                h = jax.lax.cond(idx % every == 0, do_cross,
                                 lambda hh: hh, h)
            h, side = _self_attention(
                cfg, p["attn"], h, positions, segment_ids, mode,
                cache_kv=(ck, cv) if mode == "decode" else None,
                cache_index=cache_index)
            if "moe" in p:
                x = rmsnorm(h, p["moe"]["norm"], cfg.norm_eps)
                out, a = moe_ffn(p["moe"], x, cfg)
                h = constrain(h + out, "batch", "seq", None)
                aux = aux + a
            else:
                h = _mlp(cfg, p["mlp"], h)
            ys = side if mode in ("decode", "prefill") else 0
            return (h, aux), ys

        if remat:
            body = jax.checkpoint(body)
        idxs = jnp.arange(L)
        if mode == "decode":
            xs = (blocks, idxs, cache["k"], cache["v"])
        else:
            xs = (blocks, idxs)
        (h, aux), ys = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                    xs, unroll=self._unroll())
        new_cache = None
        if mode == "decode":
            new_cache = dict(cache)
            new_cache["k"], new_cache["v"] = ys
        elif mode == "prefill":
            new_cache = {"k": ys[0], "v": ys[1]}
        return h, aux, new_cache

    def _ssm_stack(self, params, h, mode, cache=None):
        cfg = self.cfg

        def body(carry, xs):
            h = carry
            if mode == "decode":
                p, sst, cst = xs
                p = constrain_like(p, self._axes["blocks"])
                h, ns = mamba_block(p, h, cfg,
                                    state={"ssm": sst, "conv": cst})
            else:
                p = constrain_like(xs, self._axes["blocks"])
                h, ns = mamba_block(p, h, cfg)
            h = constrain(h, "batch", "seq", None)
            ys = ((ns["ssm"], ns["conv"])
                  if mode in ("decode", "prefill") else 0)
            return h, ys

        if cfg.remat != "none" and mode == "train":
            body = jax.checkpoint(body)
        xs = ((params["blocks"], cache["ssm"], cache["conv"])
              if mode == "decode" else params["blocks"])
        h, ys = jax.lax.scan(body, h, xs, unroll=self._unroll())
        new_cache = None
        if mode in ("decode", "prefill"):
            new_cache = {"ssm": ys[0], "conv": ys[1]}
        return h, jnp.zeros((), jnp.float32), new_cache

    def _hybrid_stack(self, params, h, positions, segment_ids, mode,
                      cache=None, cache_index=None):
        cfg = self.cfg
        every = cfg.attn_every
        groups = cfg.num_layers // every
        remat = cfg.remat != "none" and mode == "train"

        def mamba_body(carry, xs):
            hh = carry
            if mode == "decode":
                p, sst, cst = xs
                p = constrain_like(p, self._axes["blocks"])
                hh, ns = mamba_block(p, hh, cfg,
                                     state={"ssm": sst, "conv": cst})
            else:
                p = constrain_like(xs, self._axes["blocks"])
                hh, ns = mamba_block(p, hh, cfg)
            hh = constrain(hh, "batch", "seq", None)
            ys = ((ns["ssm"], ns["conv"])
                  if mode in ("decode", "prefill") else 0)
            return hh, ys

        if remat:
            mamba_body = jax.checkpoint(mamba_body)

        ssm_caches, conv_caches = [], []
        shared_k, shared_v = [], []
        new_cache = dict(cache) if cache is not None else None
        for gi in range(groups):
            sp = jax.tree.map(lambda a: a[gi % cfg.num_shared_blocks],
                              params["shared"])
            sp = constrain_like(sp, self._axes["shared"])
            if mode == "decode":
                ckv = (cache["shared_k"][gi], cache["shared_v"][gi])
            else:
                ckv = None
            h, side = _self_attention(
                cfg, sp["attn"], h, positions, segment_ids, mode,
                cache_kv=ckv, cache_index=cache_index)
            h = _mlp(cfg, sp["mlp"], h)
            if side is not None:
                shared_k.append(side[0])
                shared_v.append(side[1])
            sl = slice(gi * every, (gi + 1) * every)
            p_grp = jax.tree.map(lambda a: a[sl], params["blocks"])
            if mode == "decode":
                xs = (p_grp, cache["ssm"][sl], cache["conv"][sl])
            else:
                xs = p_grp
            h, ys = jax.lax.scan(mamba_body, h, xs,
                                 unroll=self._unroll())
            if mode in ("decode", "prefill"):
                ssm_caches.append(ys[0])
                conv_caches.append(ys[1])
        if mode in ("decode", "prefill"):
            new_cache = {
                "ssm": jnp.concatenate(ssm_caches, axis=0),
                "conv": jnp.concatenate(conv_caches, axis=0),
                "shared_k": jnp.stack(shared_k),
                "shared_v": jnp.stack(shared_v),
            }
        return h, jnp.zeros((), jnp.float32), new_cache

    def _encode(self, params, frames):
        cfg = self.cfg
        table = sinusoidal_positions(cfg.num_frames, cfg.d_model)
        h = frames + table[None, :frames.shape[1]].astype(frames.dtype)
        h = constrain(h, "batch", "seq", None)

        def body(carry, p):
            hh, _ = carry
            p = constrain_like(p, self._axes["enc_blocks"])
            hh, _side = _self_attention(cfg, p["attn"], hh, None, None,
                                        "train", causal=False)
            hh = _mlp(cfg, p["mlp"], hh)
            return (hh, 0.0), 0

        (h, _), _ = jax.lax.scan(body, (h, 0.0), params["enc_blocks"],
                                 unroll=self._unroll())
        return rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    def _encdec_stack(self, params, h, positions, mode, enc_out=None,
                      cache=None, cache_index=None):
        cfg = self.cfg

        def body(carry, xs):
            hh, aux = carry
            if mode == "decode":
                p, ck, cv, xk, xv = xs
            else:
                p = xs
                ck = cv = xk = xv = None
            p = constrain_like(p, self._axes["blocks"])
            hh, side = _self_attention(
                cfg, p["attn"], hh, positions, None, mode,
                cache_kv=(ck, cv) if mode == "decode" else None,
                cache_index=cache_index)
            if mode == "decode":
                hh = _cross_attention(cfg, p["cross"], hh,
                                      cached_kv=(xk, xv))
            else:
                hh = _cross_attention(cfg, p["cross"], hh,
                                      kv_input=enc_out)
            hh = _mlp(cfg, p["mlp"], hh)
            ys = side if mode in ("decode", "prefill") else 0
            return (hh, aux), ys

        if cfg.remat != "none" and mode == "train":
            body = jax.checkpoint(body)
        if mode == "decode":
            xs = (params["blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"])
        else:
            xs = params["blocks"]
        (h, aux), ys = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                    xs, unroll=self._unroll())
        new_cache = None
        if mode == "decode":
            new_cache = dict(cache)
            new_cache["k"], new_cache["v"] = ys[0], ys[1]
        elif mode == "prefill":
            new_cache = {"k": ys[0], "v": ys[1]}
        return h, aux, new_cache

    # ---- top-level passes ----
    def _backbone(self, params, tokens, positions, segment_ids, mode,
                  cache=None, cache_index=None, image_embeds=None,
                  frames=None):
        cfg = self.cfg
        h = self._embed(params, tokens, positions)
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            h, aux, new_cache = self._transformer_stack(
                params, h, positions, segment_ids, mode, cache=cache,
                cache_index=cache_index, image_embeds=image_embeds)
            if fam == "vlm" and mode == "prefill":
                ck, cv = _cross_kv_from(cfg, params["cross"], image_embeds)
                new_cache["cross_k"] = ck
                new_cache["cross_v"] = cv
        elif fam == "ssm":
            h, aux, new_cache = self._ssm_stack(params, h, mode, cache)
        elif fam == "hybrid":
            h, aux, new_cache = self._hybrid_stack(
                params, h, positions, segment_ids, mode, cache,
                cache_index)
        elif fam == "encdec":
            if mode == "decode":
                h, aux, new_cache = self._encdec_stack(
                    params, h, positions, mode, cache=cache,
                    cache_index=cache_index)
            else:
                enc_out = self._encode(params, frames)
                h, aux, new_cache = self._encdec_stack(
                    params, h, positions, mode, enc_out=enc_out)
                if mode == "prefill":
                    xk, xv = _cross_kv_from(cfg, params["blocks"]["cross"],
                                            enc_out)
                    new_cache["cross_k"] = xk
                    new_cache["cross_v"] = xv
        else:
            raise ValueError(fam)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        h = constrain(h, "batch", "seq", None)
        return h, aux, new_cache

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        h, aux, _ = self._backbone(
            params, batch["tokens"], batch["positions"],
            batch.get("segment_ids"), "train",
            image_embeds=batch.get("image_embeds"),
            frames=batch.get("frames"))
        ce, count = chunked_cross_entropy(
            h, params["lm_head"], batch["targets"], batch["loss_mask"],
            cfg.vocab_size, cfg.ce_chunk)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux, "tokens": count}

    def prefill(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        h, _aux, cache = self._backbone(
            params, batch["tokens"], batch["positions"],
            batch.get("segment_ids"), "prefill",
            image_embeds=batch.get("image_embeds"),
            frames=batch.get("frames"))
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"])
        return logits, cache

    def decode_step(self, params, cache, tokens, cache_index
                    ) -> Tuple[jnp.ndarray, Dict]:
        """``cache_index``: scalar, or (B,) for continuous batching where
        every request slot sits at its own sequence position."""
        b = tokens.shape[0]
        ci = jnp.asarray(cache_index)
        positions = jnp.broadcast_to(
            ci.reshape(-1, 1) if ci.ndim else ci, (b, 1)
        ).astype(jnp.int32)
        h, _aux, new_cache = self._backbone(
            params, tokens, positions, None, "decode",
            cache=cache, cache_index=cache_index)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"])
        logits = constrain(logits, "batch", "act_vocab")
        return logits, new_cache

    # ---- cache declaration (for dry-run input specs) ----
    def cache_defs(self, batch: int, seq: int) -> Dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim if cfg.num_heads else 0
        L = cfg.num_layers
        kvh = cfg.num_kv_heads

        def kv(n_layers, t):
            return {
                "k": ParamDef((n_layers, batch, t, kvh, hd),
                              ("layers", "batch", "kv_seq", None, None),
                              dtype=cfg.kv_cache_dtype),
                "v": ParamDef((n_layers, batch, t, kvh, hd),
                              ("layers", "batch", "kv_seq", None, None),
                              dtype=cfg.kv_cache_dtype),
            }

        def ssm_states(n_layers):
            return {
                "ssm": ParamDef(
                    (n_layers, batch, cfg.ssm_ngroups,
                     cfg.ssm_heads // cfg.ssm_ngroups,
                     cfg.ssm_headdim, cfg.ssm_state),
                    ("layers", "batch", None, "act_heads", None, None),
                    dtype="float32"),
                "conv": ParamDef(
                    (n_layers, batch, cfg.ssm_conv - 1, cfg.conv_dim),
                    ("layers", "batch", None, "conv_dim")),
            }

        fam = cfg.family
        if fam in ("dense", "moe"):
            return kv(L, seq)
        if fam == "vlm":
            c = kv(L, seq)
            lc = L // cfg.cross_attn_every
            cross = kv(lc, cfg.num_image_tokens)
            c["cross_k"], c["cross_v"] = cross["k"], cross["v"]
            return c
        if fam == "ssm":
            return ssm_states(L)
        if fam == "hybrid":
            c = ssm_states(L)
            groups = L // cfg.attn_every
            shared = kv(groups, seq)
            c["shared_k"], c["shared_v"] = shared["k"], shared["v"]
            return c
        if fam == "encdec":
            c = kv(L, seq)
            cross = kv(L, cfg.num_frames)
            c["cross_k"], c["cross_v"] = cross["k"], cross["v"]
            return c
        raise ValueError(fam)
