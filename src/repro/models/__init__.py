"""Model substrate: composable JAX definitions for all assigned archs."""
from .lm import LM, build_param_defs  # noqa: F401
from . import attention, common, moe, specs, ssm  # noqa: F401
