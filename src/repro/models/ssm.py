"""Mamba2 / SSD (state-space duality) sequence mixing.

Chunked training algorithm per arXiv:2405.21060 (minimal-SSD form): the
sequence is split into chunks; intra-chunk terms use the quadratic
"attention-like" dual with a decay matrix, inter-chunk terms pass a
(heads, headdim, state) recurrence through a `lax.scan`.  Decode is the
O(1)-per-token linear recurrence — this is why the `long_500k` cell is
runnable for the SSM/hybrid architectures only.

Oracle for tests: :func:`ssd_naive` (step-by-step recurrence).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import rmsnorm


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., L) -> (..., L, L); [i, j] = sum_{k=j+1..i} x_k, -inf for i<j."""
    cs = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    diff = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, chunk: int,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b/c: (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,G,H/G,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,)
    dtf = dt.astype(jnp.float32)
    da = (dtf * a).reshape(bsz, nc, chunk, g, hg)           # log decay
    xdt = (x * dt[..., None]).reshape(bsz, nc, chunk, g, hg, p)
    bc_ = b.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cc_ = c.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    xf = xdt.astype(jnp.float32)

    da_cum = jnp.cumsum(da, axis=2)                         # (B,C,L,G,H)

    # intra-chunk (diagonal blocks): decay matrix L then dual attention
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 4, 2)))    # (B,C,G,H,L,S)
    y_diag = jnp.einsum("bclgn,bcsgn,bcghls,bcsghp->bclghp",
                        cc_, bc_, lmat, xf)

    # per-chunk input -> end-of-chunk state
    da_last = da_cum[:, :, -1:]                             # (B,C,1,G,H)
    decay_states = jnp.exp(da_last - da_cum)                # (B,C,L,G,H)
    states = jnp.einsum("bclgn,bclgh,bclghp->bcghpn",
                        bc_, decay_states, xf)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_last[:, :, 0])                 # (B,C,G,H)
    init = (initial_state.astype(jnp.float32) if initial_state is not None
            else jnp.zeros((bsz, g, hg, p, n), jnp.float32))

    def scan_fn(prev, inp):
        st, dec = inp
        new = prev * dec[..., None, None] + st
        return new, prev                    # emit state ENTERING the chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4, 5),
         chunk_decay.transpose(1, 0, 2, 3)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)   # (B,C,G,H,P,N)

    # contribution of the incoming state to each position
    state_decay = jnp.exp(da_cum)                           # (B,C,L,G,H)
    y_off = jnp.einsum("bclgn,bcghpn,bclgh->bclghp",
                       cc_, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, sp, h, p)[:, :s]
    return y.astype(x.dtype), final_state


def ssd_naive(x, dt, a_log, b, c,
              initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Step-by-step recurrence oracle (fp32)."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    state = (initial_state.astype(jnp.float32) if initial_state is not None
             else jnp.zeros((bsz, g, hg, p, n), jnp.float32))
    ys = []
    for t in range(s):
        xt = (x[:, t] * dt[:, t, :, None]).astype(jnp.float32)
        xt = xt.reshape(bsz, g, hg, p)
        da = jnp.exp(dt[:, t].astype(jnp.float32) * a).reshape(bsz, g, hg)
        state = state * da[..., None, None] + jnp.einsum(
            "bgn,bghp->bghpn", b[:, t].astype(jnp.float32), xt)
        yt = jnp.einsum("bgn,bghpn->bghp", c[:, t].astype(jnp.float32),
                        state)
        ys.append(yt.reshape(bsz, h, p))
    return jnp.stack(ys, axis=1).astype(x.dtype), state


def ssd_decode_step(x, dt, a_log, b, c, state
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrence.  x: (B,H,P); dt: (B,H); b/c: (B,G,N);
    state: (B,G,H/G,P,N)."""
    bsz, h, p = x.shape
    g, n = b.shape[1], b.shape[2]
    hg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32) * a).reshape(bsz, g, hg)
    xdt = (x * dt[..., None]).astype(jnp.float32).reshape(bsz, g, hg, p)
    state = state * da[..., None, None] + jnp.einsum(
        "bgn,bghp->bghpn", b.astype(jnp.float32), xdt)
    y = jnp.einsum("bgn,bghpn->bghp", c.astype(jnp.float32), state)
    return y.reshape(bsz, h, p).astype(x.dtype), state


# ---------------------------------------------------------------------------
# Full Mamba2 block (projections + causal conv + SSD + gated norm).
# ---------------------------------------------------------------------------

def _causal_conv(u: jnp.ndarray, w: jnp.ndarray,
                 bias: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, kernel size K: (B,S,C) x (K,C) -> (B,S,C)."""
    ksize = w.shape[0]
    out = u * w[-1]
    for i in range(1, ksize):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :u.shape[1]]
        out = out + shifted * w[ksize - 1 - i]
    return out + bias


def _conv_step(u_new: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray,
               bias: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode: u_new (B,C); conv_state (B,K-1,C)."""
    window = jnp.concatenate([conv_state, u_new[:, None, :]], axis=1)
    out = jnp.einsum("bkc,kc->bc", window, w) + bias
    return out, window[:, 1:]


def mamba_block(params: Dict, x: jnp.ndarray, cfg,
                state: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """Pre-norm Mamba2 block with residual.  x: (B,S,D) (train/prefill) or
    (B,1,D) with ``state`` (decode)."""
    d_in = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    h = cfg.ssm_heads
    p = cfg.ssm_headdim
    g = cfg.ssm_ngroups
    n = cfg.ssm_state
    bsz, s, _ = x.shape

    hidden = rmsnorm(x, params["in_norm"], cfg.norm_eps)
    zx = hidden @ params["w_zx"]                      # (B,S,2*din)
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = hidden @ params["w_bc"]                      # (B,S,2gn)
    dt_raw = hidden @ params["w_dt"]                  # (B,S,H)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))

    conv_in = jnp.concatenate([xin, bc], axis=-1)     # (B,S,conv_dim)
    new_state: Dict = {}
    if state is None:
        conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    else:
        conv_out, conv_state = _conv_step(
            conv_in[:, 0], state["conv"], params["conv_w"],
            params["conv_b"])
        conv_out = conv_out[:, None, :]
        new_state["conv"] = conv_state
    conv_out = jax.nn.silu(conv_out)
    xc, bmat, cmat = jnp.split(conv_out, [d_in, d_in + gn], axis=-1)

    if state is None:
        y, final = ssd_chunked(
            xc.reshape(bsz, s, h, p), dt, params["a_log"],
            bmat.reshape(bsz, s, g, n), cmat.reshape(bsz, s, g, n),
            cfg.ssm_chunk)
        new_state["ssm"] = final
        # conv state = last (K-1) raw conv inputs, left-padded if short
        k1 = cfg.ssm_conv - 1
        if s >= k1:
            new_state["conv"] = conv_in[:, -k1:, :]
        else:
            new_state["conv"] = jnp.pad(
                conv_in, ((0, 0), (k1 - s, 0), (0, 0)))
    else:
        yd, ssm_state = ssd_decode_step(
            xc[:, 0].reshape(bsz, h, p), dt[:, 0],
            params["a_log"], bmat[:, 0].reshape(bsz, g, n),
            cmat[:, 0].reshape(bsz, g, n), state["ssm"])
        y = yd[:, None]
        new_state["ssm"] = ssm_state

    y = y + params["d_skip"].astype(y.dtype)[:, None] * \
        (xc.reshape(bsz, s, h, p) if state is None
         else xc.reshape(bsz, 1, h, p))
    y = y.reshape(bsz, s, d_in)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["gate_norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    return x + out, new_state
