"""input_specs(): ShapeDtypeStruct stand-ins + logical axes for every
(architecture x shape-cell), used by the dry-run and by jit shardings.

No device allocation happens here — the same pattern shannon/kernels uses.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell
from .common import ParamDef, abstract_tree, axes_tree
from .lm import LM


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell
                      ) -> Tuple[Dict, Dict]:
    """(ShapeDtypeStructs, logical axes) for one training batch."""
    b, s = cell.global_batch, cell.seq_len
    specs = {
        "tokens": _sds((b, s), jnp.int32),
        "targets": _sds((b, s), jnp.int32),
        "loss_mask": _sds((b, s), jnp.float32),
        "positions": _sds((b, s), jnp.int32),
        "segment_ids": _sds((b, s), jnp.int32),
    }
    axes = {k: ("batch", None) for k in specs}
    if cfg.family == "vlm":
        specs["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                     jnp.bfloat16)
        axes["image_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        specs["frames"] = _sds((b, cfg.num_frames, cfg.d_model),
                               jnp.bfloat16)
        axes["frames"] = ("batch", None, None)
    return specs, axes


def prefill_batch_specs(cfg: ModelConfig, cell: ShapeCell
                        ) -> Tuple[Dict, Dict]:
    b, s = cell.global_batch, cell.seq_len
    specs = {
        "tokens": _sds((b, s), jnp.int32),
        "positions": _sds((b, s), jnp.int32),
    }
    axes = {k: ("batch", None) for k in specs}
    if cfg.family == "vlm":
        specs["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                     jnp.bfloat16)
        axes["image_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        specs["frames"] = _sds((b, cfg.num_frames, cfg.d_model),
                               jnp.bfloat16)
        axes["frames"] = ("batch", None, None)
    return specs, axes


def decode_specs(cfg: ModelConfig, cell: ShapeCell
                 ) -> Tuple[Dict, Dict, Dict, Dict]:
    """Returns (cache specs, cache axes, token specs, token axes)."""
    model = LM(cfg)
    cache_defs = model.cache_defs(cell.global_batch, cell.seq_len)
    cache_specs = abstract_tree(cache_defs)
    cache_axes = axes_tree(cache_defs)
    tok = {"tokens": _sds((cell.global_batch, 1), jnp.int32)}
    tok_axes = {"tokens": ("batch", None)}
    return cache_specs, cache_axes, tok, tok_axes


def input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Unified entry: dict describing everything the step function takes."""
    if cell.kind == "train":
        return train_batch_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_batch_specs(cfg, cell)
    if cell.kind == "decode":
        return decode_specs(cfg, cell)
    raise ValueError(cell.kind)
