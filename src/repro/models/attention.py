"""GQA attention: chunked-causal (train/prefill), cached decode, cross.

The chunked path scans query chunks so peak logits memory is
``(B, heads, chunk, S)`` — the jnp mirror of the Pallas flash kernel
(:mod:`repro.kernels.flash_attention`), which replaces it on TPU when
``cfg.use_pallas``.  GQA is computed grouped, never materializing repeated
KV heads.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..parallel.axes import constrain

NEG_INF = -1e30


def _grouped(q: jnp.ndarray, kv_heads: int) -> jnp.ndarray:
    """(B,S,Hq,D) -> (B,S,K,G,D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, kv_heads, hq // kv_heads, d)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True,
                      segment_ids: Optional[jnp.ndarray] = None,
                      kv_segment_ids: Optional[jnp.ndarray] = None,
                      chunk: int = 512,
                      use_pallas: bool = False) -> jnp.ndarray:
    """q: (B,Sq,Hq,D); k/v: (B,Sk,K,D) -> (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    scale = 1.0 / np.sqrt(d)

    if use_pallas and segment_ids is None and d in (64, 128):
        qt = q.transpose(0, 2, 1, 3)
        g = hq // kh
        kt = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
        vt = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
        out = kops.flash_attention(qt, kt, vt, causal=causal)
        return out.transpose(0, 2, 1, 3)

    # GQA: repeat kv heads so the merged head axis shards like Megatron
    # TP (64/16 etc.); for head counts not divisible by the model axis
    # the 'act_heads' rule falls back and the q-chunk 'seq' rule takes
    # the mesh axis instead (sequence-parallel attention).  Repeat order
    # matches the (kv, group) factoring used by decode_attention.
    g = hq // kh
    kr = jnp.repeat(k, g, axis=2) if g > 1 else k          # (B,Sk,Hq,D)
    vr = jnp.repeat(v, g, axis=2) if g > 1 else v
    kr = constrain(kr, "batch", None, "act_heads", None)
    vr = constrain(vr, "batch", None, "act_heads", None)

    chunk = min(chunk, sq)
    pad = -sq % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    if pad and segment_ids is not None:
        segment_ids = jnp.pad(segment_ids, ((0, 0), (0, pad)))
    nq = qp.shape[1] // chunk
    qs = qp.reshape(b, nq, chunk, hq, d).swapaxes(0, 1)    # (nq,B,c,H,D)
    seg_q = (segment_ids.reshape(b, nq, chunk).swapaxes(0, 1)
             if segment_ids is not None else None)
    kv_seg = kv_segment_ids if kv_segment_ids is not None else segment_ids
    kpos = jnp.arange(sk)
    offset = sk - sq

    def body(i, qc, sq_c):
        # bf16 operands + fp32 accumulation (preferred_element_type), so
        # the backward cotangents stay in the model dtype — input-side
        # .astype(f32) casts were materializing 2 GB f32 activation
        # cotangents outside the layer loop on the 72B cell.
        qc = constrain(qc, "batch", None, "act_heads", None)
        logits = jnp.einsum("bchd,bshd->bhcs", qc, kr)
        logits = logits.astype(jnp.float32) * scale
        logits = constrain(logits, "batch", "act_heads", "seq", None)
        valid = jnp.ones((b, 1, chunk, sk), bool)
        if causal:
            qpos = i * chunk + jnp.arange(chunk) + offset
            valid = valid & (qpos[:, None] >= kpos[None, :])[None, None]
        if sq_c is not None and kv_seg is not None:
            valid = valid & (sq_c[:, None, :, None] ==
                             kv_seg[:, None, None, :])
        logits = jnp.where(valid, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        probs = constrain(probs, "batch", "act_heads", "seq", None)
        out = jnp.einsum("bhcs,bshd->bchd", probs.astype(vr.dtype), vr)
        out = constrain(out, "batch", None, "act_heads", None)
        return out.astype(q.dtype)

    def scan_body(i, xs):
        if seg_q is not None:
            qc, sq_c = xs
        else:
            qc, sq_c = xs, None
        return i + 1, body(i, qc, sq_c)

    # checkpoint each chunk: the backward recomputes the (chunk, Sk)
    # probability block instead of saving it — flash-attention residual
    # behavior at the remat level.
    scan_body = jax.checkpoint(scan_body)
    xs = (qs, seg_q) if seg_q is not None else qs
    _, outs = jax.lax.scan(scan_body, 0, xs)
    out = outs.swapaxes(0, 1).reshape(b, sq + pad, hq, d)
    return out[:, :sq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray,
                     cache_index: jnp.ndarray) -> jnp.ndarray:
    """One-token attention over a (B,S,K,D) cache filled up to and
    including ``cache_index``."""
    b, one, hq, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / np.sqrt(d)
    if k_cache.dtype == jnp.float8_e4m3fn:     # quantized KV cache
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
    qg = _grouped(q, kh)                                   # (B,1,K,G,D)
    logits = jnp.einsum("bokgd,bskd->bkgos", qg, k_cache)
    logits = logits.astype(jnp.float32) * scale
    # kv-sequence sharded attention (flash-decode): each device scores its
    # cache slice; XLA turns the softmax into a partial-max/sum reduce.
    logits = constrain(logits, "batch", None, None, None, "kv_seq")
    ci = jnp.broadcast_to(jnp.asarray(cache_index), (b,))
    valid = (jnp.arange(s)[None, :] <= ci[:, None]
             )[:, None, None, None, :]
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = constrain(probs, "batch", None, None, None, "kv_seq")
    out = jnp.einsum("bkgos,bskd->bokgd", probs.astype(v_cache.dtype),
                     v_cache)
    return out.reshape(b, one, hq, d).astype(q.dtype)


def update_cache(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 k_new: jnp.ndarray, v_new: jnp.ndarray,
                 cache_index: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write the new token's K/V at ``cache_index`` (functional update).

    A scalar index writes one seq slice; a (B,) index writes each batch
    row at its own position (continuous-batching decode)."""
    ci = jnp.asarray(cache_index)
    if ci.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), ci, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), ci, axis=1)
    else:
        rows = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[rows, ci].set(
            k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, ci].set(
            v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache
