"""Mixture-of-Experts block: GShard-style grouped top-k dispatch with
capacity, expert-parallel over the 'model' mesh axis.

Tokens are split into groups of ``cfg.moe_group_size``; per group a
(g, E, c) dispatch/combine pair routes tokens to experts via einsum so the
expert matmuls stay dense and MXU-shaped.  Expert weights carry the
'expert' logical axis -> 'model' mesh axis (EP); XLA inserts the
all-to-all-equivalent collectives from the sharding constraints.

Supports: top-1 (llama4-scout) and top-2 (arctic), a llama4-style shared
expert, an arctic-style parallel dense residual, and a load-balance aux
loss (Switch/GShard form).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.axes import constrain
from .common import activation_fn


def moe_ffn(params: Dict, x: jnp.ndarray, cfg
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    e = cfg.num_experts
    k = cfg.experts_per_token
    act = activation_fn(cfg.activation)
    t = b * s
    g = min(cfg.moe_group_size, t)
    pad = -t % g
    xt = x.reshape(t, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = xt.shape[0] // g
    xg = xt.reshape(ng, g, d)
    xg = constrain(xg, "batch", None, None)

    logits = jnp.einsum("Ggd,de->Gge", xg, params["router"]
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G,g,E)

    capacity = int(np.ceil(k * g / e * cfg.capacity_factor))
    capacity = max(capacity, 4)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (G,g,k)
    # renormalize the k gates (standard for top-2 routing)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    counts = jnp.zeros((ng, e), jnp.int32)
    dispatch = jnp.zeros((ng, g, e, capacity), xg.dtype)
    combine = jnp.zeros((ng, g, e, capacity), jnp.float32)
    for j in range(k):
        mask_j = jax.nn.one_hot(gate_idx[..., j], e,
                                dtype=jnp.int32)            # (G,g,E)
        pos_j = counts[:, None, :] + jnp.cumsum(mask_j, axis=1) - mask_j
        keep = (pos_j < capacity) & (mask_j > 0)
        counts = counts + mask_j.sum(axis=1)
        oh = jax.nn.one_hot(jnp.where(keep, pos_j, capacity),
                            capacity, dtype=xg.dtype)       # (G,g,E,c)
        oh = oh * keep[..., None].astype(xg.dtype)
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * \
            gate_vals[..., j, None, None] * mask_j[..., None]

    # aux load-balance loss: E * sum_e mean(frac_tokens_e) * mean(prob_e)
    frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32),
        axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_prob)

    xd = jnp.einsum("GgEc,Ggd->GEcd", dispatch, xg)
    xd = constrain(xd, None, "act_expert", None, None)
    wi, wg, wo = params["wi"], params["wg"], params["wo"]
    h = act(jnp.einsum("GEcd,Edf->GEcf", xd, wi))
    h = h * jnp.einsum("GEcd,Edf->GEcf", xd, wg)
    y = jnp.einsum("GEcf,Efd->GEcd", h, wo)
    y = constrain(y, None, "act_expert", None, None)
    out = jnp.einsum("GgEc,GEcd->Ggd", combine.astype(y.dtype), y)

    out = out.reshape(-1, d)[:t].reshape(b, s, d)

    if cfg.moe_shared_expert or cfg.moe_dense_residual:
        key = "shared" if cfg.moe_shared_expert else "dense"
        p = params[key]
        hh = act(jnp.einsum("bsd,df->bsf", x, p["wi"]))
        hh = hh * jnp.einsum("bsd,df->bsf", x, p["wg"])
        out = out + jnp.einsum("bsf,fd->bsd", hh, p["wo"])
    return out, aux
