"""Shared model components: parameter declaration trees, norms, RoPE,
activations, chunked cross-entropy.

Parameters are declared as :class:`ParamDef` pytrees carrying *logical*
sharding axes; ``abstract_tree``/``materialize_tree`` turn a declaration
into ShapeDtypeStructs (dry-run) or initialized arrays (smoke/real runs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.axes import constrain

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16, "float8": jnp.float8_e4m3fn}


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small
    dtype: str = "bfloat16"
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_def)


def abstract_tree(tree):
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, DTYPES[d.dtype]), tree)


def axes_tree(tree):
    return tree_map_defs(lambda d: d.axes, tree)


def materialize_tree(tree, key: jax.Array):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        dt = DTYPES[d.dtype]
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        elif d.init == "small":
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * d.scale * 0.1).astype(dt))
        else:
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * d.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
            eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * \
        scale.astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float) -> jnp.ndarray:
    """Rotary embedding, split-halves convention.  x: (B,S,H,D).

    Angles are computed in f32 (position precision), but the rotation
    itself runs in the model dtype: upcasting x here materializes
    (B,S,H*D) f32 activations + cotangents — at 72B-train scale that is
    2 GB per buffer outside the layer loop.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    sin = jnp.sin(angles).astype(x.dtype)[:, :, None, :]
    cos = jnp.cos(angles).astype(x.dtype)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d)
    table = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(table, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V) logits).
# ---------------------------------------------------------------------------

def chunked_cross_entropy(hidden: jnp.ndarray, lm_head: jnp.ndarray,
                          targets: jnp.ndarray, loss_mask: jnp.ndarray,
                          vocab_size: int, chunk: int
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over masked tokens; scans sequence chunks of the LM head
    matmul so peak logits memory is (B, chunk, V)."""
    b, s, d = hidden.shape
    v = lm_head.shape[-1]
    chunk = min(chunk, s)
    pad = -s % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        loss_mask = jnp.pad(loss_mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    ts = targets.reshape(b, nc, chunk).swapaxes(0, 1)
    ms = loss_mask.reshape(b, nc, chunk).swapaxes(0, 1)
    vocab_mask = (jnp.arange(v) < vocab_size)

    def body(carry, xs):
        h, t, m = xs
        h = constrain(h, "batch", "seq", None)
        # fp32 MXU accumulation, but round the *saved* logits (and hence
        # the h/lm_head cotangents) to the model dtype: keeping this
        # boundary in f32 materializes full-seq f32 dL/dh buffers
        # (7 x 2 GB on the 72B cell).
        logits = jnp.einsum("bcd,dv->bcv", h, lm_head)
        logits = constrain(logits, "batch", None, "act_vocab")
        logits = jnp.where(vocab_mask, logits.astype(jnp.float32), -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None],
                                   axis=-1).squeeze(-1)
        ce = (lse - gold) * m
        loss_sum, count = carry
        return (loss_sum + ce.sum(), count + m.sum()), None

    # checkpoint: the backward recomputes each chunk's logits instead of
    # saving (B, chunk, V) fp32 blocks across all chunks.
    body = jax.checkpoint(body)
    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ts, ms.astype(jnp.float32)))
    return loss_sum / jnp.maximum(count, 1.0), count
