"""Named tensor operands and the flat-memory planner.

The MVE machine model addresses one flat element memory; every
hand-written program in this repo used to carve it up with magic base
offsets (``c_base = n_rows * k + k * m`` and friends).  The frontend
replaces that with *named operands*: a kernel declares the tensors it
reads and writes (``b.input("x", (n,), DType.F)``), and the
:class:`MemoryPlan` packs them into the flat buffer back to back in
declaration order.  Programs address memory exclusively through operand
handles — ``a.at(i, j).load(...)`` — so base addresses never appear in
user code, and results are read back by name
(:meth:`MemoryPlan.unpack`).

Packing is deterministic (declaration order), which keeps frontend-built
programs byte-compatible with the legacy hand-packed layouts: declaring
operands in the legacy base-address order reproduces the exact memory
image, which the equivalence suite (``tests/test_frontend.py``) relies
on.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from ..core.isa import DType

#: Stride-mode mnemonics (the paper's 2-bit encodings, Section III-C).
BCAST = 0      # stride 0: replicate along this dimension
SEQ = 1        # stride 1: sequential
DERIVED = 2    # S_i = S_{i-1} * L_{i-1}: dense row-major continuation
CR = 3         # stride taken from the per-dimension stride control register

_KINDS = ("input", "output", "inout", "scratch")


class OperandError(ValueError):
    """Bad operand declaration or binding (wrong name/shape/dtype)."""


@dataclasses.dataclass(frozen=True)
class Operand:
    """One named tensor in the kernel's flat memory image.

    ``base`` is assigned at declaration time (operands pack in
    declaration order), so pointer tables for random-base accesses can
    be computed with :meth:`addr` while the kernel is still being built.
    """

    name: str
    shape: Tuple[int, ...]
    dtype: DType
    kind: str
    base: int
    init: Optional[np.ndarray] = None
    _builder: object = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    # -- addressing --------------------------------------------------------
    def _flat(self, idx: Tuple) -> int:
        """Row-major flat element offset of a (possibly partial) index."""
        if len(idx) == 1 and not isinstance(idx[0], tuple):
            # single index: flat offset into the ravelled operand
            return idx[0]
        if len(idx) > len(self.shape):
            raise OperandError(
                f"operand {self.name!r} has {len(self.shape)} dims, "
                f"got index {idx}")
        full = tuple(idx) + (0,) * (len(self.shape) - len(idx))
        off = 0
        for i, n in zip(full, self.shape):
            off = off * n + i
        return off

    def at(self, *idx) -> "OperandRef":
        """An addressable reference: ``a.at(i, j)`` is element ``a[i, j]``
        (row-major; trailing indices default to 0, a single index is a
        flat offset into the ravelled tensor)."""
        return OperandRef(self, self._flat(idx) if idx else 0)

    def addr(self, idx=0):
        """Absolute element address(es) in the flat memory image.

        Accepts an int flat offset or a numpy array of offsets — the
        latter is how pointer tables for random-base accesses (Eq. 1)
        are built without ever spelling out a base address."""
        return self.base + np.asarray(idx) if isinstance(
            idx, np.ndarray) else self.base + int(idx)

    # -- sugar: load/store at offset 0 -------------------------------------
    def load(self, *modes, dtype: Optional[DType] = None):
        return self.at().load(*modes, dtype=dtype)

    def store(self, value, *modes, dtype: Optional[DType] = None) -> None:
        self.at().store(value, *modes, dtype=dtype)

    def rload(self, *modes, dtype: Optional[DType] = None):
        return self.at().rload(*modes, dtype=dtype)

    def rstore(self, value, *modes, dtype: Optional[DType] = None) -> None:
        self.at().rstore(value, *modes, dtype=dtype)


@dataclasses.dataclass(frozen=True)
class OperandRef:
    """An operand at an element offset — the unit of addressing.

    ``load``/``store`` emit strided accesses whose base is the referenced
    element; ``rload``/``rstore`` treat the referenced slice as the
    pointer array of a random-base access (Eq. 1).  The per-dimension
    stride modes are the frontend mnemonics :data:`SEQ`, :data:`BCAST`,
    :data:`DERIVED`, :data:`CR` (or raw 2-bit mode ints).
    """

    operand: Operand
    offset: int

    @property
    def address(self) -> int:
        return self.operand.base + self.offset

    def _b(self):
        b = self.operand._builder
        if b is None:
            raise OperandError(
                f"operand {self.operand.name!r} is not bound to a builder")
        return b

    def load(self, *modes, dtype: Optional[DType] = None):
        return self._b()._load(self, modes,
                               dtype or self.operand.dtype, random=False)

    def rload(self, *modes, dtype: Optional[DType] = None):
        return self._b()._load(self, modes,
                               dtype or self.operand.dtype, random=True)

    def store(self, value, *modes, dtype: Optional[DType] = None) -> None:
        self._b()._store(self, value, modes, dtype, random=False)

    def rstore(self, value, *modes, dtype: Optional[DType] = None) -> None:
        self._b()._store(self, value, modes, dtype, random=True)


class MemoryPlan:
    """The packed flat-memory layout of a kernel's named operands.

    ``pack`` builds a memory image from named arrays (falling back to
    each operand's declared ``init``, or zeros); ``unpack`` slices a
    result image back into named, shaped views.  Round-trips by name:
    ``plan.unpack(plan.pack(d))[k] == d[k]`` for every operand ``k``.
    """

    def __init__(self, operands: Iterable[Operand]):
        self.operands: "OrderedDict[str, Operand]" = OrderedDict(
            (op.name, op) for op in operands)
        self.size = sum(op.size for op in self.operands.values())

    def __contains__(self, name: str) -> bool:
        return name in self.operands

    def base(self, name: str) -> int:
        return self.operands[name].base

    def region(self, name: str) -> slice:
        op = self.operands[name]
        return slice(op.base, op.base + op.size)

    def pack(self, values: Optional[Dict[str, np.ndarray]] = None
             ) -> np.ndarray:
        """Build the flat float64 memory image the executors consume."""
        if values is not None and not isinstance(values, dict):
            raise OperandError(
                f"pack() wants a dict of named operand arrays, got "
                f"{type(values).__name__} — a flat memory image does "
                "not need packing")
        values = dict(values) if values is not None else {}
        mem = np.zeros(self.size, dtype=np.float64)
        for name, op in self.operands.items():
            val = values.pop(name, op.init)
            if val is None:
                continue
            arr = np.asarray(val)
            if arr.size != op.size:
                raise OperandError(
                    f"operand {name!r}: expected shape {op.shape} "
                    f"({op.size} elements), got {arr.shape}")
            mem[op.base:op.base + op.size] = arr.ravel()
        if values:
            raise OperandError(
                f"unknown operand(s) {sorted(values)}; kernel declares "
                f"{list(self.operands)}")
        return mem

    def unpack(self, memory) -> Dict[str, np.ndarray]:
        """Named, shaped copies of every non-scratch operand region."""
        mem = np.asarray(memory)
        out: Dict[str, np.ndarray] = {}
        for name, op in self.operands.items():
            if op.kind == "scratch":
                continue
            out[name] = mem[..., op.base:op.base + op.size].reshape(
                mem.shape[:-1] + op.shape).copy()
        return out

    def __repr__(self) -> str:
        rows = ", ".join(
            f"{op.name}@{op.base}:{op.kind}{list(op.shape)}"
            for op in self.operands.values())
        return f"MemoryPlan({self.size} elements: {rows})"
