"""MVE kernel frontend: trace kernels, never touch registers or offsets.

    import repro.frontend as mve
    from repro.frontend import SEQ, BCAST, DERIVED, CR
    from repro.core.isa import DType

    @mve.kernel
    def daxpy(b, n=8192, alpha=1.5):
        x = b.input("x", (n,), DType.F)
        y = b.inout("y", (n,), DType.F)
        b.width(32)
        with b.dims(n):
            b.scalar(4)
            vy = y.load(SEQ)
            vy += alpha * x.load(SEQ)
            y.store(vy, SEQ)

Layers (design note: docs/FRONTEND.md):

  builder  — tracing ``KernelBuilder`` / ``@mve.kernel`` API
  regalloc — liveness-based linear-scan virtual->physical allocation
  operands — named tensor operands + flat-memory planner

Built kernels lower to the unchanged :class:`repro.core.isa.Program` IR:
every executor and the serving stack accept them directly.
"""
from .builder import (BuildError, Kernel, KernelBuilder,  # noqa: F401
                      VectorHandle, kernel)
from .operands import (BCAST, CR, DERIVED, SEQ,  # noqa: F401
                       MemoryPlan, Operand, OperandError, OperandRef)
from .regalloc import (DEFAULT_MAX_REGS, RegisterPressureError,  # noqa: F401
                       allocate, live_intervals, max_pressure)
