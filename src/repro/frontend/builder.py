"""``KernelBuilder`` / ``@mve.kernel``: the tracing kernel frontend.

The paper's pitch for MVE is that it "abstracts cache geometry and data
layout" behind an intrinsics interface (Section V); this module is that
interface for the repo.  A kernel function receives a builder, declares
named tensor operands, opens dimension scopes, and computes with
operator-overloaded vector handles:

    import repro.frontend as mve
    from repro.frontend import SEQ
    from repro.core.isa import DType

    @mve.kernel
    def daxpy(b, n=8192, alpha=1.5):
        x = b.input("x", (n,), DType.F)
        y = b.inout("y", (n,), DType.F)
        b.width(32)
        with b.dims(n):
            b.scalar(4)
            vx = x.load(SEQ)
            vy = y.load(SEQ)
            vy += alpha * vx          # vsetdup + vmul + vadd
            y.store(vy, SEQ)

    k = daxpy(n=4096)                 # -> Kernel
    out, state = k.run({"x": xs, "y": ys})
    out["y"]                          # results read back by name

What the user never sees:

* register numbers — every value is a fresh *virtual* register; a
  liveness-based linear-scan allocator (:mod:`repro.frontend.regalloc`)
  maps them onto the physical file and errors only when no valid
  assignment exists.  Staying under ``vm.N_REGS`` keeps kernels on the
  signature-shared VM executor path;
* base addresses — operands are named tensors packed by the memory
  planner (:mod:`repro.frontend.operands`); addressing goes through
  ``a.at(i, j)``;
* config-op sequencing — ``b.dims(...)`` emits ``vsetdimc`` /
  ``vsetdiml`` (+ stride CRs) in canonical order, ``b.masked_off(...)``
  brackets a scope with ``vunsetmask``/``vsetmask``.

Tracing is eager: Python control flow unrolls, so the emitted program is
straight-line — exactly what the compile walk of
:mod:`repro.core.engine` resolves statically.  ``build()`` allocates
registers, then validates the program strictly
(:func:`repro.core.isa.validate`): out-of-range dims, width/dtype
mismatches and out-of-image addressing fail at build time with one-line
diagnostics instead of deep inside the walk compiler.

Design note: docs/FRONTEND.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import isa
from ..core.isa import DType, Instr, Op
from ..core.machine import (ControlState, apply_config, config_cell,
                            read_config_cell)
from . import regalloc
from .operands import MemoryPlan, Operand, OperandError, OperandRef


class BuildError(ValueError):
    """Misuse of the builder API detected while tracing."""


class VectorHandle:
    """A traced vector value living in a virtual register.

    Arithmetic operators emit instructions; Python scalars on either
    side are broadcast via ``vsetdup`` into a fresh register first.
    Augmented assignment (``+=`` and friends) updates *in place* —
    masked lanes keep the destination's previous contents, which is how
    accumulators and read-modify-write idioms are expressed.
    """

    __slots__ = ("_b", "vreg", "dtype")

    def __init__(self, b: "KernelBuilder", vreg: int, dtype: DType):
        self._b = b
        self.vreg = vreg
        self.dtype = dtype

    def __repr__(self) -> str:
        return f"VectorHandle(v{self.vreg}, {self.dtype.name})"

    # -- binary arithmetic -------------------------------------------------
    def __add__(self, o):
        return self._b._binary(Op.ADD, self, o)

    def __radd__(self, o):
        # commutative: keep the handle as vs1, like hand-written code
        return self._b._binary(Op.ADD, self, o)

    def __sub__(self, o):
        return self._b._binary(Op.SUB, self, o)

    def __rsub__(self, o):
        return self._b._binary(Op.SUB, self, o, swap=True)

    def __mul__(self, o):
        return self._b._binary(Op.MUL, self, o)

    def __rmul__(self, o):
        return self._b._binary(Op.MUL, self, o)

    def __xor__(self, o):
        return self._b._binary(Op.XOR, self, o)

    def __and__(self, o):
        return self._b._binary(Op.AND, self, o)

    def __or__(self, o):
        return self._b._binary(Op.OR, self, o)

    def __iadd__(self, o):
        return self._b._binary(Op.ADD, self, o, in_place=True)

    def __isub__(self, o):
        return self._b._binary(Op.SUB, self, o, in_place=True)

    def __imul__(self, o):
        return self._b._binary(Op.MUL, self, o, in_place=True)

    def __ixor__(self, o):
        return self._b._binary(Op.XOR, self, o, in_place=True)

    def __iand__(self, o):
        return self._b._binary(Op.AND, self, o, in_place=True)

    def __ior__(self, o):
        return self._b._binary(Op.OR, self, o, in_place=True)

    def min(self, o):
        return self._b._binary(Op.MIN, self, o)

    def max(self, o):
        return self._b._binary(Op.MAX, self, o)

    # -- shifts / rotates (immediate amounts; integers only) ---------------
    def __lshift__(self, amount: int):
        return self._b._shift(self, amount)

    def __rshift__(self, amount: int):
        return self._b._shift(self, -int(amount))

    def __ilshift__(self, amount: int):
        return self._b._shift(self, amount, in_place=True)

    def __irshift__(self, amount: int):
        return self._b._shift(self, -int(amount), in_place=True)

    def rot(self, amount: int):
        return self._b._emit_unary(Op.ROTI, self, imm=int(amount))

    def shift_by(self, amount: "VectorHandle"):
        """Variable left shift (``vshr``): per-lane amounts."""
        return self._b._binary(Op.SHR, self, amount)

    # -- moves -------------------------------------------------------------
    def copy(self):
        return self._b._emit_unary(Op.CPY, self)

    def astype(self, dtype: DType):
        """Type conversion (``vcvt``): float<->int with saturation to the
        destination's range, exactly like the executors."""
        return self._b._emit_unary(Op.CVT, self, dtype=dtype)

    # -- comparisons: write the per-lane Tag predicate latch ---------------
    def gt(self, o):
        self._b._compare(Op.GT, self, o)

    def gte(self, o):
        self._b._compare(Op.GTE, self, o)

    def lt(self, o):
        self._b._compare(Op.LT, self, o)

    def lte(self, o):
        self._b._compare(Op.LTE, self, o)

    def eq(self, o):
        self._b._compare(Op.EQ, self, o)

    def neq(self, o):
        self._b._compare(Op.NEQ, self, o)


@dataclasses.dataclass(eq=False)      # identity semantics: hashable, so
class Kernel:                         # the engine can track attachments
    """A built kernel: validated program + memory plan + metadata.

    ``program`` targets the existing :class:`repro.core.isa.Program` IR
    unchanged, so every executor (step interpreter, fused engine,
    program-as-data VM) and the serving stack run kernels without any
    semantic changes — ``compile_program``, ``MVEScheduler.submit`` and
    ``MVEProgramServer.submit`` all accept a ``Kernel`` directly.
    """

    name: str
    program: isa.Program
    plan: MemoryPlan
    n_vregs: int
    n_regs: int            # distinct physical registers after allocation
    max_live: int          # peak simultaneous liveness

    # -- memory binding ----------------------------------------------------
    def pack(self, operands: Optional[Dict[str, np.ndarray]] = None
             ) -> np.ndarray:
        """Flat memory image from named arrays (declared inits fill the
        rest)."""
        return self.plan.pack(operands)

    def unpack(self, memory) -> Dict[str, np.ndarray]:
        """Named, shaped results from a (possibly batched) memory image."""
        return self.plan.unpack(memory)

    def pack_batch(self, operand_batches: Dict[str, np.ndarray]
                   ) -> np.ndarray:
        """Stack per-operand leading batch axes into a batch of memory
        images (missing operands broadcast their declared init)."""
        batch = max(np.asarray(v).shape[0]
                    for v in operand_batches.values())
        return np.stack([
            self.pack({k: np.asarray(v)[i]
                       for k, v in operand_batches.items()})
            for i in range(batch)])

    def equivalent(self, other: "Kernel") -> bool:
        """Same memory-image semantics: identical operand layout (names,
        shapes, kinds, bases) and identical declared init data — i.e.
        ``pack``/``unpack`` behave the same on both."""
        a, b = self.plan.operands, other.plan.operands
        if list(a) != list(b):
            return False
        for name in a:
            oa, ob = a[name], b[name]
            if (oa.shape, oa.kind, oa.base, oa.dtype) != \
                    (ob.shape, ob.kind, ob.base, ob.dtype):
                return False
            if (oa.init is None) != (ob.init is None):
                return False
            if oa.init is not None and not np.array_equal(oa.init, ob.init):
                return False
        return True

    # -- execution ---------------------------------------------------------
    def compile(self, cfg=None, mode: Optional[str] = None,
                target: Optional[object] = None,
                opt_level: Optional[int] = None):
        """The cached :class:`~repro.core.engine.CompiledProgram` — or,
        with ``target=`` (a registered name like ``"rvv-1d"`` or a
        :class:`~repro.targets.Target`), the uniform
        :class:`~repro.targets.CompiledArtifact` exposing
        ``run``/``run_batch``/``timeline``/``energy``/
        ``instruction_mix`` under that target's cost models
        (docs/TARGETS.md).  The kernel runs unchanged on every target.

        ``opt_level`` runs the traced program through the
        :mod:`repro.opt` pass pipeline first (``None`` = as traced);
        results stay bit-exact — the optimizer's differentially-tested
        contract (docs/OPTIMIZER.md)."""
        if target is not None:
            from ..targets import compile as compile_for_target
            return compile_for_target(self, target=target, cfg=cfg,
                                      mode=mode, opt_level=opt_level)
        from ..core.engine import compile_program
        return compile_program(self, cfg, mode=mode, opt_level=opt_level)

    def run(self, operands: Optional[Dict[str, np.ndarray]] = None,
            cfg=None, mode: Optional[str] = None,
            target: Optional[object] = None):
        """Execute once; returns ``(outputs, state)`` with outputs read
        back by name (every non-scratch operand).  ``target=`` executes
        through :mod:`repro.targets` (identical results on every
        target; ``state`` then prices under that target via
        ``kernel.compile(target=...).timeline(state)``)."""
        mem_after, state = self.compile(cfg, mode, target).run(
            self.pack(operands))
        return self.unpack(mem_after), state

    def run_batch(self, operand_batches: Dict[str, np.ndarray],
                  cfg=None, mode: Optional[str] = None,
                  target: Optional[object] = None):
        """Vmapped execution over a leading batch axis per operand
        (missing operands broadcast their declared init)."""
        mems = self.pack_batch(operand_batches)
        mem_after, _, _ = self.compile(cfg, mode, target).run_batch(mems)
        return self.unpack(np.asarray(mem_after))

    def dump(self) -> str:
        return self.program.dump()


class _Scope:
    """Returned by :meth:`KernelBuilder.dims` — config ops are emitted at
    the call, ``with`` adds structure only (and restores nothing: MVE
    config registers are architectural state, not a stack)."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class KernelBuilder:
    """Tracing builder: declare operands, configure dimensions, compute.

    See the module docstring for the programming model.  Every emitted
    instruction uses virtual registers; :meth:`build` runs the register
    allocator and strict validation and returns a :class:`Kernel`.
    """

    def __init__(self, name: str = "kernel",
                 max_regs: int = regalloc.DEFAULT_MAX_REGS):
        self.name = name
        self.max_regs = max_regs
        self._instrs: List[Instr] = []
        self._operands: "List[Operand]" = []
        self._names: Dict[str, Operand] = {}
        self._cursor = 0
        self._next_vreg = 0
        self._dim_lens: Tuple[int, ...] = (1,)
        self._pinned: List[int] = []
        self._built = False
        # Duplicate-config suppression: mirror of the control registers
        # the traced program has established so far, plus the set of
        # cells it has explicitly written (first writes always emit,
        # even when they match the power-on defaults — making the traced
        # configuration explicit is the frontend's job; removing
        # power-on no-ops is the optimizer's).
        self._ctrl = ControlState()
        self._cfg_written: set = set()

    # -- operand declaration ----------------------------------------------
    def _declare(self, kind: str, name: str, shape, dtype: DType,
                 init) -> Operand:
        if self._built:
            raise BuildError("builder already built")
        if name in self._names:
            raise OperandError(f"operand {name!r} declared twice")
        if init is not None:
            init = np.asarray(init)
            shape = tuple(shape) if shape is not None else init.shape
            if init.size != int(np.prod(shape)):
                raise OperandError(
                    f"operand {name!r}: init has {init.size} elements, "
                    f"shape {shape} wants {int(np.prod(shape))}")
        elif shape is None:
            raise OperandError(f"operand {name!r} needs a shape or init")
        else:
            shape = tuple(shape)
        op = Operand(name=name, shape=shape, dtype=dtype, kind=kind,
                     base=self._cursor, init=init, _builder=self)
        self._operands.append(op)
        self._names[name] = op
        self._cursor += op.size
        return op

    def input(self, name: str, shape=None, dtype: DType = DType.F,
              init=None) -> Operand:
        """Declare a named input tensor (bound at pack/run time)."""
        return self._declare("input", name, shape, dtype, init)

    def output(self, name: str, shape=None, dtype: DType = DType.F,
               init=None) -> Operand:
        """Declare a named output tensor (zero-initialised)."""
        return self._declare("output", name, shape, dtype, init)

    def inout(self, name: str, shape=None, dtype: DType = DType.F,
              init=None) -> Operand:
        """Declare a tensor that is both read and written."""
        return self._declare("inout", name, shape, dtype, init)

    def scratch(self, name: str, shape=None, dtype: DType = DType.F,
                init=None) -> Operand:
        """Declare working memory that is not read back by name."""
        return self._declare("scratch", name, shape, dtype, init)

    def operand(self, name: str) -> Operand:
        """A previously declared operand, by name."""
        try:
            return self._names[name]
        except KeyError:
            raise OperandError(
                f"no operand {name!r}; declared: {list(self._names)}"
            ) from None

    # -- machine configuration --------------------------------------------
    def width(self, bits: int) -> None:
        """Configure the live register width (``vsetwidth``): the
        register file holds ``256 // bits`` physical registers."""
        self._emit_config(isa.vsetwidth(bits))

    def dims(self, *lengths: int,
             ld_strides: Optional[Dict[int, int]] = None,
             st_strides: Optional[Dict[int, int]] = None) -> _Scope:
        """Open a dimension scope: ``dims(x, y, z)`` configures a 3-D
        logical register geometry (x fastest) by emitting ``vsetdimc`` +
        one ``vsetdiml`` per dimension, followed by any load/store
        stride control registers (for :data:`~repro.frontend.CR`-mode
        accesses).  Usable bare or as ``with b.dims(...):`` — the
        ``with`` form adds readable structure; configuration is
        architectural state and persists until the next reconfiguration.
        """
        if not (1 <= len(lengths) <= isa.MAX_DIMS):
            raise BuildError(
                f"1..{isa.MAX_DIMS} dimensions, got {len(lengths)}")
        self._emit_config(isa.vsetdimc(len(lengths)))
        for d, ln in enumerate(lengths):
            self._emit_config(isa.vsetdiml(d, int(ln)))
        for d, s in sorted((ld_strides or {}).items()):
            self._emit_config(isa.vsetldstr(d, int(s)))
        for d, s in sorted((st_strides or {}).items()):
            self._emit_config(isa.vsetststr(d, int(s)))
        self._dim_lens = tuple(int(ln) for ln in lengths)
        return _Scope()

    def dim_length(self, dim: int, length: int) -> None:
        """Adjust one dimension's length in place (tail iterations)."""
        self._emit_config(isa.vsetdiml(dim, int(length)))
        lens = list(self._dim_lens)
        if dim < len(lens):
            lens[dim] = int(length)
            self._dim_lens = tuple(lens)

    @contextlib.contextmanager
    def masked_off(self, *mask_bits: int):
        """Scope with the given highest-dimension elements masked off
        (``vunsetmask`` on entry, ``vsetmask`` on exit) — the Section-IV
        reduction idiom."""
        for i in mask_bits:
            self._emit_config(isa.vunsetmask(int(i)))
        try:
            yield
        finally:
            for i in reversed(mask_bits):
                self._emit_config(isa.vsetmask(int(i)))

    def scalar(self, count: int) -> None:
        """Account ``count`` interleaved scalar-core instructions (cost
        model only — no architectural effect)."""
        self._emit(isa.scalar(int(count)))

    # -- values ------------------------------------------------------------
    def const(self, dtype: DType, value) -> VectorHandle:
        """Broadcast an immediate into a fresh register (``vsetdup``)."""
        h = self._fresh(dtype)
        self._emit(Instr(Op.SET_DUP, dtype=dtype, vd=h.vreg, imm=value))
        return h

    def keep(self, *handles: VectorHandle) -> None:
        """Pin values in their registers for the rest of the kernel.

        The allocator frees a register after its value's last read;
        ``keep`` extends the lifetime to the end of the program — for
        values a later kernel revision will read, or to mirror hand
        code that deliberately holds an input resident."""
        for h in handles:
            self._pinned.append(h.vreg)

    def add(self, a, b, predicated: bool = False,
            in_place: bool = False):
        return self._binary(Op.ADD, a, b, predicated=predicated,
                            in_place=in_place)

    def sub(self, a, b, predicated: bool = False,
            in_place: bool = False):
        return self._binary(Op.SUB, a, b, predicated=predicated,
                            in_place=in_place)

    def mul(self, a, b, predicated: bool = False,
            in_place: bool = False):
        """Predicated + in-place is the conditional-update idiom: lanes
        whose Tag is clear keep the destination's previous value, which
        only means something when the destination *is* an existing
        register — the range-reduction loops in :mod:`repro.nn.ops`
        (``s *= 0.5 where s >= 2``) are the motivating use."""
        return self._binary(Op.MUL, a, b, predicated=predicated,
                            in_place=in_place)

    def _compare(self, op: Op, a: VectorHandle, b) -> None:
        """Emit a comparison: writes the per-lane Tag predicate latch
        (no destination register)."""
        bh = self._coerce(b, a.dtype)
        self._emit(Instr(op, dtype=a.dtype, vs1=a.vreg, vs2=bh.vreg))

    # -- internal emission --------------------------------------------------
    def _emit(self, instr: Instr) -> None:
        if self._built:
            raise BuildError("builder already built")
        self._instrs.append(instr)

    def _emit_config(self, instr: Instr) -> None:
        """Emit a config instruction unless it re-establishes state this
        trace has already explicitly written (re-entering a dimension
        scope inside a Python loop re-traces its ``vsetdim*``/stride
        writes — identical state does not need re-emitting).  The state
        *trajectory* at every retained instruction is unchanged, so
        addressing and strict validation are unaffected; regression test
        in ``tests/test_frontend.py``."""
        cell = config_cell(instr)
        before = read_config_cell(self._ctrl, cell)
        apply_config(self._ctrl, instr)
        if cell in self._cfg_written and \
                read_config_cell(self._ctrl, cell) == before:
            return
        self._cfg_written.add(cell)
        self._emit(instr)

    def _fresh(self, dtype: DType) -> VectorHandle:
        h = VectorHandle(self, self._next_vreg, dtype)
        self._next_vreg += 1
        return h

    def _coerce(self, value, dtype: DType) -> VectorHandle:
        if isinstance(value, VectorHandle):
            return value
        if isinstance(value, (int, float, np.integer, np.floating)):
            if isinstance(value, (float, np.floating)) \
                    and not dtype.is_float and value != int(value):
                raise BuildError(
                    f"non-integral scalar {value} into {dtype.name} lanes")
            return self.const(
                dtype, float(value) if dtype.is_float else int(value))
        raise BuildError(f"cannot use {type(value).__name__} as a vector "
                         "operand")

    def _binary(self, op: Op, a: VectorHandle, b, swap: bool = False,
                in_place: bool = False,
                predicated: bool = False) -> VectorHandle:
        bh = self._coerce(b, a.dtype)
        lhs, rhs = (bh, a) if swap else (a, bh)
        if in_place:
            vd = a.vreg
            out = a
        else:
            out = self._fresh(a.dtype)
            vd = out.vreg
        self._emit(Instr(op, dtype=a.dtype, vd=vd, vs1=lhs.vreg,
                         vs2=rhs.vreg, predicated=predicated))
        return out

    def _shift(self, a: VectorHandle, amount: int,
               in_place: bool = False) -> VectorHandle:
        out = a if in_place else self._fresh(a.dtype)
        self._emit(Instr(Op.SHI, dtype=a.dtype, vd=out.vreg, vs1=a.vreg,
                         imm=int(amount)))
        return out

    def _emit_unary(self, op: Op, a: VectorHandle,
                    dtype: Optional[DType] = None,
                    imm: Optional[int] = None) -> VectorHandle:
        out = self._fresh(dtype or a.dtype)
        self._emit(Instr(op, dtype=dtype or a.dtype, vd=out.vreg,
                         vs1=a.vreg, imm=imm))
        return out

    def _load(self, ref: OperandRef, modes: Tuple, dtype: DType,
              random: bool) -> VectorHandle:
        h = self._fresh(dtype)
        self._emit(Instr(Op.RLD if random else Op.SLD, dtype=dtype,
                         vd=h.vreg, base=ref.address,
                         modes=tuple(int(m) for m in modes)))
        return h

    def _store(self, ref: OperandRef, value: VectorHandle, modes: Tuple,
               dtype: Optional[DType], random: bool) -> None:
        if not isinstance(value, VectorHandle):
            raise BuildError("store source must be a VectorHandle")
        self._emit(Instr(Op.RST if random else Op.SST,
                         dtype=dtype or value.dtype, vs1=value.vreg,
                         base=ref.address,
                         modes=tuple(int(m) for m in modes)))

    # -- finalisation -------------------------------------------------------
    def build(self) -> Kernel:
        """Allocate registers, validate strictly, freeze the Kernel."""
        if self._built:
            raise BuildError("builder already built")
        self._built = True
        alloc = regalloc.allocate(self._instrs, self.max_regs,
                                  pinned=self._pinned)
        program = isa.Program(alloc.program)
        program.validate(memory_size=self._cursor, strict=True)
        return Kernel(name=self.name, program=program,
                      plan=MemoryPlan(self._operands),
                      n_vregs=self._next_vreg, n_regs=alloc.n_used,
                      max_live=alloc.max_live)


def kernel(fn=None, *, name: Optional[str] = None,
           max_regs: int = regalloc.DEFAULT_MAX_REGS):
    """Decorator: a function ``f(b, ...)`` becomes a kernel factory —
    calling it traces ``f`` through a fresh :class:`KernelBuilder` and
    returns the built :class:`Kernel`.

        @mve.kernel
        def daxpy(b, n=8192, alpha=1.5): ...

        k = daxpy(n=4096)
    """
    def deco(f):
        @functools.wraps(f)
        def factory(*args, **kwargs) -> Kernel:
            b = KernelBuilder(name or f.__name__, max_regs=max_regs)
            f(b, *args, **kwargs)
            return b.build()
        factory.__mve_kernel__ = True
        return factory
    return deco(fn) if fn is not None else deco
