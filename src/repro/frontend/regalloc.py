"""Liveness-based register allocation for traced kernels.

The builder emits instructions over an unbounded supply of *virtual*
registers (every produced value gets a fresh one; in-place updates reuse
their destination's).  This module maps them onto the machine's physical
register file with a linear-scan allocator over live intervals.

Traced programs are straight line (Python loops unroll at trace time),
so every virtual register has exactly one live interval
``[first_def, last_use]`` and linear scan is *optimal* for them: an
allocation exists iff the maximum number of simultaneously live virtual
registers never exceeds the physical register count — which is exactly
what the allocator guarantees (``tests/test_frontend.py`` fuzzes this
property).

Two policy details matter for matching hand-written register usage (the
equivalence suite checks frontend-built patterns against the legacy
hand-coded programs instruction by instruction):

* lowest-index-first — a freed physical register is reused as soon as a
  new value needs one, like hand code does;
* no same-instruction reuse — a register whose last use is instruction
  ``i`` is not reassigned to a value defined *by* instruction ``i``
  (source and destination of one instruction stay distinct, as on the
  real bit-serial datapath where the destination PR is written while the
  sources are read).

Masked-lane caveat: physical register reuse means a value's lanes
*outside* the dimension configuration active at its definition hold
whatever the register last contained — matching the hardware, where PRs
are raw SRAM.  Read a handle only under (a subset of) the dims it was
produced under; docs/FRONTEND.md discusses this.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from ..core import isa
from ..core.isa import Instr, Op

#: Default physical register budget.  Matches the program-as-data VM's
#: dense register file (``repro.core.vm.N_REGS``) — staying at or under
#: it is what keeps frontend-built programs on the signature-shared VM
#: path instead of falling back to per-program fused compiles — and the
#: 256-wordline register file at the common 32-bit kernel width
#: (Section III-B: 256 / 32 = 8 live PRs).
DEFAULT_MAX_REGS = 8


class RegisterPressureError(RuntimeError):
    """No valid physical assignment exists at some program point."""

    def __init__(self, index: int, instr: Instr, live: Sequence[int],
                 max_regs: int):
        self.index = index
        self.live = tuple(live)
        self.max_regs = max_regs
        super().__init__(
            f"register pressure: {len(live) + 1} values live at "
            f"instruction {index} but the machine has {max_regs} "
            f"physical registers\n  at [{index:3d}] "
            f"{isa.disassemble(instr)}\n  live virtual registers: "
            f"{sorted(live)} — split the kernel or shorten value "
            f"lifetimes (store intermediates)")


def _defs_reg(instr: Instr) -> Optional[int]:
    """The virtual register this instruction writes, if any."""
    return isa.reg_defs(instr)


def _uses_regs(instr: Instr) -> List[int]:
    """The virtual registers this instruction reads."""
    return list(isa.reg_uses(instr))


@dataclasses.dataclass
class Allocation:
    """Result of :func:`allocate`."""

    program: List[Instr]          # instructions with physical registers
    mapping: Dict[int, int]       # virtual -> physical
    n_used: int                   # distinct physical registers used
    max_live: int                 # peak simultaneous liveness


def live_intervals(instrs: Sequence[Instr],
                   pinned: Sequence[int] = ()):
    """``vreg -> (first_def, last_event)`` over a straight-line program.

    A write to an already-live register (in-place update, or a partial
    write under a dimension mask) extends its interval like a use — the
    old contents are merged, so the register must stay allocated.
    ``pinned`` registers (:meth:`KernelBuilder.keep`) stay live to the
    end of the program.
    """
    first: Dict[int, int] = {}
    last: Dict[int, int] = {}
    for i, instr in enumerate(instrs):
        for r in _uses_regs(instrs[i]):
            first.setdefault(r, i)
            last[r] = i
        d = _defs_reg(instr)
        if d is not None:
            first.setdefault(d, i)
            last[d] = max(last.get(d, i), i)
    for r in pinned:
        if r in first and instrs:
            last[r] = len(instrs) - 1
    return {r: (first[r], last[r]) for r in first}


def max_pressure(instrs: Sequence[Instr]) -> int:
    """Peak simultaneous liveness — the minimum register file that can
    host the program (linear scan achieves it on straight-line code)."""
    iv = live_intervals(instrs)
    if not iv:
        return 0
    n = max(e for _, e in iv.values()) + 1
    live = [0] * (n + 1)
    for s, e in iv.values():
        live[s] += 1
        live[e + 1] -= 1
    peak = cur = 0
    for d in live:
        cur += d
        peak = max(peak, cur)
    return peak


def allocate(instrs: Sequence[Instr],
             max_regs: int = DEFAULT_MAX_REGS,
             pinned: Sequence[int] = ()) -> Allocation:
    """Linear-scan allocate virtual registers onto ``max_regs`` physical
    ones; raises :class:`RegisterPressureError` only when no valid
    assignment exists (peak liveness exceeds ``max_regs``)."""
    intervals = live_intervals(instrs, pinned)
    mapping: Dict[int, int] = {}
    free = list(range(max_regs))          # min-heap by construction
    expiry: List[tuple] = []              # (last_event, vreg) active list
    out: List[Instr] = []
    n_used = 0
    max_live = 0

    for i, instr in enumerate(instrs):
        # Expire strictly-before-i intervals: a register read for the
        # last time by instruction i-1 is reusable at i, but sources of
        # instruction i itself are not reusable as its destination.
        still = []
        for last_event, vreg in expiry:
            if last_event < i:
                free.append(mapping[vreg])
            else:
                still.append((last_event, vreg))
        expiry = still
        free.sort()

        for r in _uses_regs(instr):
            if r not in mapping:
                raise isa.ProgramError(
                    f"virtual register v{r} read before it is written",
                    i, instr)
        d = _defs_reg(instr)
        if d is not None and d not in mapping:
            if not free:
                raise RegisterPressureError(
                    i, instr, [v for _, v in expiry], max_regs)
            mapping[d] = free.pop(0)
            expiry.append((intervals[d][1], d))
            n_used = max(n_used, mapping[d] + 1)
        max_live = max(max_live, len(expiry))

        if instr.op in isa.VECTOR_OPS:
            out.append(dataclasses.replace(
                instr,
                vd=mapping.get(instr.vd) if instr.vd is not None else None,
                vs1=mapping.get(instr.vs1)
                if instr.vs1 is not None else None,
                vs2=mapping.get(instr.vs2)
                if instr.vs2 is not None else None))
        else:
            out.append(instr)

    return Allocation(program=out, mapping=mapping, n_used=n_used,
                      max_live=max_live)
