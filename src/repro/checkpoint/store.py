"""Fault-tolerant checkpoint store.

Features a production trainer needs on a 1000-node cluster, implemented
host-side (single-controller semantics; each leaf is fetched to host and
written as .npy with a JSON manifest):

  * atomic commits (write to tmp dir, fsync, rename) — a preempted writer
    never corrupts the latest checkpoint;
  * async saves on a background thread so the train loop keeps stepping;
  * resharding restore: a checkpoint written on one mesh can be loaded
    onto any other mesh/topology (elastic scaling) — leaves are stored
    unsharded and re-device_put with the new sharding;
  * retention policy + emergency ("preemption") saves;
  * step/data-position metadata for exact training resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree,
                    metadata: Optional[Dict] = None) -> str:
    """Atomic synchronous save; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace(_SEP, "__") + ".npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # numpy can't serialize ml_dtypes natively: store raw bytes
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1
                           else np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": logical_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template,
                    step: Optional[int] = None,
                    shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedSharding (same structure) —
    leaves are device_put with them, which is how a checkpoint written on
    a 256-chip mesh restores onto 512 chips (or 1 CPU).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    flat = _flatten_with_paths(template)
    shard_flat = ([s for _, s in _flatten_with_paths(shardings)]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (key, tmpl), shard in zip(flat, shard_flat):
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]))
        logical = entry["dtype"]
        if str(arr.dtype) != logical:          # byte-viewed ml_dtypes
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, logical)))
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {np.shape(tmpl)}")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def reshard_tree(tree, shardings):
    """Re-device_put a live tree with new shardings (elastic re-mesh)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        tree, shardings)


class CheckpointManager:
    """Async saves + retention + emergency save hook."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree, metadata=None) -> None:
        self.wait()
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_emergency(self, step: int, tree, metadata=None) -> str:
        """Synchronous, used from preemption signal handlers."""
        self.wait()
        meta = dict(metadata or {})
        meta["emergency"] = True
        return save_checkpoint(self.directory, step, tree, meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
