"""Checkpointing substrate."""
from .store import (CheckpointManager, load_checkpoint,  # noqa: F401
                    reshard_tree, save_checkpoint)
