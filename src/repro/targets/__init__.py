"""``repro.targets``: one pluggable Target API for every ISA x
compute-scheme x cost-model combination.

The paper's headline results are *comparisons across targets*: the same
kernel driven through MVE vs. a 1D RVV-style vector ISA vs. Arm Neon,
over the BS/BP/BH/AC in-SRAM compute schemes of Section II-B (Figures
10/11/13: 2.9x performance, 8.8x energy vs. a commercial mobile SIMD
core).  This package is that comparison matrix as an API:

    import repro.targets as targets

    art = targets.compile(kernel, target="rvv-1d")   # or any Target
    out, state = art.run({"x": xs, "y": ys})
    art.timeline(state).total_cycles                 # 1D-ISA cycles
    art.energy(state).total_pj                       # component model
    art.instruction_mix().vector                     # Figure 11 currency

Registered targets (``list_targets()``): ``mve-bs`` (default),
``mve-bp``, ``mve-bh``, ``mve-ac``, ``rvv-1d``, ``neon`` — each with a
pipeline-model twin (``mve-bs-timed``, ..., ``neon-timed``) that prices
the same trace through the cycle-accurate in-order model of
:mod:`repro.timing` (per-cause ``timeline().stalls``, a verified
analytic envelope; docs/TIMING.md) — plus ``mve-bicameral``, the
split-cache demo of :mod:`repro.targets.bicameral`, and anything
third-party code adds via ``register_target()``.  Every target executes through the same
functional engine, so a frontend ``@mve.kernel`` runs *unchanged* on
all of them and results are bit-exact across targets (the RVV path is
the same access, sliced — asserted in ``tests/test_targets.py`` /
``tests/test_conformance.py``).  What differs per target is pricing:
instruction issue, cycles, and energy.

Design note: docs/TARGETS.md.
"""
from .base import (CompiledArtifact, InstructionMix,  # noqa: F401
                   Target, compile, get_target, list_targets,
                   register_target)
from .builtin import (DEFAULT_TARGET, MVE_AC, MVE_BH,  # noqa: F401
                      MVE_BP, MVE_BS, NEON, RVV_1D, InCacheTarget,
                      NeonTarget, RVV1DTarget)
from .timed import (MVE_AC_TIMED, MVE_BH_TIMED,  # noqa: F401
                    MVE_BP_TIMED, MVE_BS_TIMED, NEON_TIMED,
                    RVV_1D_TIMED, TimedTarget, timed_variant)
from .bicameral import MVE_BICAMERAL, BicameralTarget  # noqa: F401


def smoke(pattern: str = "daxpy", verbose: bool = False) -> dict:
    """Compile + run one kernel on every registered target and assert
    cross-target bit-exactness — the CI targets smoke step.

    Returns ``{target_name: modeled_total_cycles}`` (also printed with
    ``verbose=True``); raises on any cross-target result mismatch.
    """
    import numpy as np

    from ..core.patterns import PATTERNS

    run = PATTERNS[pattern]()
    reference = None
    cycles = {}
    for name in list_targets():
        art = compile(run.program, target=name)
        mem_after, state = art.run(run.memory)
        mem_after = np.asarray(mem_after)
        run.check(mem_after, state)
        if reference is None:
            reference = mem_after
        else:
            np.testing.assert_array_equal(
                mem_after, reference,
                err_msg=f"target {name!r} diverged from "
                        f"{list_targets()[0]!r} on {pattern!r}")
        cycles[name] = art.timeline(state).total_cycles
        if verbose:
            print(f"targets-smoke/{pattern}/{name}: "
                  f"{cycles[name]:.0f} cycles, bit-exact")
    return cycles
