"""``*-timed`` targets: the pipeline model behind the uniform Target API.

Each timed target wraps a registered analytic target and changes only
:meth:`~repro.targets.base.Target.timeline`: instead of the analytic
controller/CB model it replays the *same* performance trace through the
in-order pipeline model of :mod:`repro.timing` under a named uarch
config, returning a :class:`~repro.timing.TimedTimeline` with per-cause
``stalls`` and the verified ``[lower_bound, upper_bound]`` envelope.
Execution, energy, and instruction mix delegate to the base target —
the timing layer never touches functional semantics (asserted against
the stepwise oracle by ``tests/test_conformance.py``).

  =============  ==========  ============  ==========================
  name           wraps       uarch config  dependence extraction
  =============  ==========  ============  ==========================
  mve-bs-timed   mve-bs      mve-bs        architectural registers
  mve-bp-timed   mve-bp      mve-bp        architectural registers
  mve-bh-timed   mve-bh      mve-bh        architectural registers
  mve-ac-timed   mve-ac      mve-ac        architectural registers
  rvv-1d-timed   rvv-1d      rvv-1d        synthesized (lowered 1D
                                           stream is not 1:1)
  neon-timed     neon        mobile-core   architectural registers
  =============  ==========  ============  ==========================

``repro.opt.tune()`` prices its schedule sweeps through the timed twin
of the requested target by default (:func:`timed_variant`), so the
scheduler optimizes against hazards and port conflicts instead of
analytic totals (docs/OPTIMIZER.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .. import timing
from ..core.cost import EnergyReport, Timeline
from ..core.machine import MVEConfig
from .base import InstructionMix, Target, get_target, register_target


@dataclasses.dataclass(frozen=True)
class TimedTarget(Target):
    """A registered target re-priced through the pipeline model.

    ``base_name`` is the wrapped analytic target; ``uarch`` names a
    :data:`repro.timing.UARCH_CONFIGS` entry (or is a config dict);
    ``cost_model`` selects per-event durations: ``"incache"`` reuses
    the scheme's analytic op costs, ``"simd"`` the packed-SIMD costs.
    """

    name: str
    base_name: str
    uarch: str = "mve-bs"
    cost_model: str = "incache"
    description: str = ""
    isa_name: str = "mve"

    @property
    def base(self) -> Target:
        return get_target(self.base_name)

    # -- execution: delegate everything functional --------------------------
    def machine_config(self, cfg: Optional[MVEConfig] = None,
                       **overrides) -> MVEConfig:
        return self.base.machine_config(cfg, **overrides)

    def freq_ghz(self, cfg: MVEConfig) -> float:
        return self.base.freq_ghz(cfg)

    def performance_trace(self, program, cfg, mve_trace):
        return self.base.performance_trace(program, cfg, mve_trace)

    def energy(self, program, cfg, mve_trace) -> EnergyReport:
        return self.base.energy(program, cfg, mve_trace)

    def instruction_mix(self, program, cfg) -> InstructionMix:
        return self.base.instruction_mix(program, cfg)

    # -- pricing: the pipeline model ----------------------------------------
    def timed_ops(self, program, cfg, mve_trace):
        """The pipeline model's input for one compilation —
        ``(ops, lane_capacity)`` (exposed for the conformance harness,
        which recomputes the envelope from the same ops)."""
        trace = self.performance_trace(program, cfg, mve_trace)
        return timing.build_timed_ops(
            program, trace, cfg, tp=self.base.timing, uarch=self.uarch,
            cost_model=self.cost_model)

    def timeline(self, program, cfg, mve_trace) -> Timeline:
        ops, lane_capacity = self.timed_ops(program, cfg, mve_trace)
        return timing.simulate_pipeline(ops, self.uarch,
                                        lane_capacity=lane_capacity)


# ---------------------------------------------------------------------------
# Registration: one timed twin per built-in target.
# ---------------------------------------------------------------------------

_TWINS: Dict[str, str] = {}


def _register_twin(base_name: str, uarch: str,
                   cost_model: str = "incache") -> TimedTarget:
    base = get_target(base_name)
    t = TimedTarget(
        name=f"{base_name}-timed", base_name=base_name, uarch=uarch,
        cost_model=cost_model, isa_name=base.isa_name,
        description=f"{base.description} — pipeline model ({uarch})")
    register_target(t)
    _TWINS[base_name] = t.name
    return t


MVE_BS_TIMED = _register_twin("mve-bs", "mve-bs")
MVE_BP_TIMED = _register_twin("mve-bp", "mve-bp")
MVE_BH_TIMED = _register_twin("mve-bh", "mve-bh")
MVE_AC_TIMED = _register_twin("mve-ac", "mve-ac")
RVV_1D_TIMED = _register_twin("rvv-1d", "rvv-1d")
NEON_TIMED = _register_twin("neon", "mobile-core", cost_model="simd")


def timed_variant(name) -> Optional[Target]:
    """The pipeline-model twin of a registered target name (identity
    for targets that already are timed; ``None`` when no twin exists —
    e.g. an unregistered custom target)."""
    tgt = name if isinstance(name, Target) else None
    tname = tgt.name if tgt is not None else name
    if isinstance(tgt, TimedTarget):
        return tgt
    if tname in _TWINS:
        return get_target(_TWINS[tname])
    try:
        resolved = get_target(tname)
    except Exception:
        return None
    return resolved if isinstance(resolved, TimedTarget) else None
