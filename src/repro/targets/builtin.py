"""Built-in targets: the paper's full comparison matrix.

  =========  =====  ==============  =======================================
  name       ISA    engine          what it models
  =========  =====  ==============  =======================================
  mve-bs     MVE    bit-serial      Neural Cache — the paper's default
  mve-bp     MVE    bit-parallel    VRAM: n-bit data horizontal
  mve-bh     MVE    bit-hybrid      EVE: p-bit segments, serial carry
  mve-ac     MVE    associative     CAPE: truth-table search/update
  rvv-1d     RVV    bit-serial      the same engine driven by a 1D ISA
                                    (Section III-C segment decomposition)
  neon       Neon   packed SIMD     2x128-bit ASIMD pipes on a mobile core
  =========  =====  ==============  =======================================

Each row also has a ``-timed`` twin (:mod:`repro.targets.timed`,
registered on package import) that re-prices the same performance trace
through the cycle-accurate in-order pipeline model of
:mod:`repro.timing` — scoreboard hazards, chaining, memory ports —
instead of the analytic timeline.

All six execute through the shared functional engine — bit-exact results
— and differ only in how the program is *issued and priced* (Figures
10/11/13).  The in-cache targets reuse the controller/CB timeline model
under their scheme's latencies; ``rvv-1d`` first lowers every
multi-dimensional access into partial 1D segments
(:func:`repro.core.rvv.compile_to_rvv`); ``neon`` prices the workload
the MVE trace records through the analytic
:class:`~repro.core.cost.NeonModel`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from ..core import cost, isa, rvv
from ..core.cost import (EnergyParams, EnergyReport, NeonModel, Timeline,
                         TimingParams, TraceEvent)
from ..core.machine import MVEConfig
from .base import InstructionMix, Target, register_target

#: The default target: the paper's MVE-on-bit-serial configuration.
DEFAULT_TARGET = "mve-bs"


def _replace_cfg(cfg: MVEConfig, overrides: dict) -> MVEConfig:
    if not overrides:
        return cfg
    return dataclasses.replace(cfg, **overrides)


@dataclasses.dataclass(frozen=True)
class InCacheTarget(Target):
    """MVE driving the in-cache engine under one compute scheme.

    The program IS the target's native ISA, so the performance trace is
    the engine trace itself; the scheme (``bs``/``bp``/``bh``/``ac``)
    changes per-op latencies and effective lane counts through
    :func:`repro.core.cost.compute_cycles` /
    :meth:`~repro.core.machine.MVEConfig.effective_lanes`.
    """

    name: str
    scheme: str = "bs"
    description: str = ""
    isa_name: str = "mve"
    timing: TimingParams = TimingParams()
    energy_params: EnergyParams = cost.DEFAULT_ENERGY
    #: extra MVEConfig fields pinned by this target, e.g.
    #: ``(("bh_segment_bits", 8),)`` — applied before per-call overrides.
    config_overrides: Tuple[Tuple[str, object], ...] = ()

    def machine_config(self, cfg=None, **overrides) -> MVEConfig:
        merged = dict(self.config_overrides)
        merged["scheme"] = self.scheme
        merged.update(overrides)
        return _replace_cfg(cfg or MVEConfig(), merged)

    def performance_trace(self, program, cfg, mve_trace):
        return mve_trace

    def energy_model(self, cfg) -> Tuple[EnergyParams, str]:
        """The ``(params, provenance)`` this target prices energy with.

        Default behaviour derives the constants from the silicon model
        for the *actual* machine geometry (:mod:`repro.silicon.params`)
        — byte-identical to :data:`~repro.core.cost.DEFAULT_ENERGY` at
        the Table IV default by the calibration contract.  A target
        constructed with explicit ``energy_params`` opts out and keeps
        its fixed constants (provenance ``"default"``).
        """
        if self.energy_params is not cost.DEFAULT_ENERGY:
            return self.energy_params, "default"
        from ..silicon.params import derived_energy
        return derived_energy(cfg, self.scheme)

    def energy(self, program, cfg, mve_trace) -> EnergyReport:
        tl = self.timeline(program, cfg, mve_trace)
        ep, source = self.energy_model(cfg)
        return cost.mve_energy(tl, cfg, cost.data_bytes(mve_trace),
                               ep, params_source=source)

    def instruction_mix(self, program, cfg) -> InstructionMix:
        return InstructionMix.from_rvv_stats(rvv.mve_stats(program))


@dataclasses.dataclass(frozen=True)
class RVV1DTarget(InCacheTarget):
    """A 1D long-vector (RVV-style) ISA driving the same in-cache engine.

    Execution is unchanged — the 1D decomposition performs *the same
    access, sliced* — but the performance trace is the Section III-C
    lowering: ``ceil(active_lanes / inner-1D-segment)`` partial accesses,
    each paying a predicate config, the access, a pack move, and scalar
    address generation; dimension-level masks become materialize+load
    sequences.  Defaults to the bit-serial engine (the Figure 10/11
    configuration); instantiate with another ``scheme`` for the Figure 13
    sweep rows.
    """

    name: str = "rvv-1d"
    isa_name: str = "rvv"

    def performance_trace(self, program, cfg, mve_trace):
        trace, _ = rvv.compile_to_rvv(program, cfg)
        return trace

    def instruction_mix(self, program, cfg) -> InstructionMix:
        _, stats = rvv.compile_to_rvv(program, cfg)
        return InstructionMix.from_rvv_stats(stats)


def _neon_work(trace: List[TraceEvent]) -> Tuple[float, int, float]:
    """(element ops, dominant bit width, unique memory bytes) of a trace.

    The MVE trace is the workload record: every non-memory vector event
    contributes its active elements as element-operations; memory traffic
    is the unique-byte count (replication is free on Neon too — it reads
    the value once into a register).  The dominant width is the
    element-op-weighted mode, so a kernel computing in int8 with an f32
    epilogue prices as int8.
    """
    elem_ops = 0.0
    by_bits: dict = {}
    for ev in trace:
        if ev.op is isa.Op.SCALAR or ev.op in isa.CONFIG_OPS:
            continue
        if ev.dtype is None or ev.op in isa.MEMORY_OPS:
            continue
        elem_ops += ev.elements
        by_bits[ev.dtype.bits] = by_bits.get(ev.dtype.bits, 0) + ev.elements
    bits = max(by_bits, key=by_bits.get) if by_bits else 32
    return elem_ops, bits, cost.data_bytes(trace)


@dataclasses.dataclass(frozen=True)
class NeonTarget(Target):
    """Packed-SIMD mobile baseline (2x128-bit ASIMD pipes, Figure 7).

    Execution still goes through the functional engine (Neon computes
    the same arithmetic — bit-exactness holds trivially); timing and
    energy come from the analytic :class:`~repro.core.cost.NeonModel`
    over the workload the MVE trace records (:func:`_neon_work`).
    Patterns carrying a hand-derived analytic workload descriptor
    (``PatternRun.neon``) can be priced more precisely via
    ``benchmarks/paper_claims.fig7_neon``; this target is the generic
    path that works for *any* kernel.
    """

    name: str = "neon"
    description: str = "Arm Neon packed SIMD (Cortex-A76-class, 2x128b)"
    isa_name: str = "neon"
    model: NeonModel = NeonModel()
    energy_params: EnergyParams = cost.DEFAULT_ENERGY

    def machine_config(self, cfg=None, **overrides) -> MVEConfig:
        # Functional execution substrate only — Neon has no in-SRAM
        # scheme; geometry overrides still apply (they bound the lanes
        # the functional engine packs).
        return _replace_cfg(cfg or MVEConfig(), overrides)

    def freq_ghz(self, cfg) -> float:
        return self.model.freq_ghz

    def performance_trace(self, program, cfg, mve_trace):
        # Neon issues no in-cache instructions; the MVE trace is the
        # workload descriptor its analytic model prices.
        return mve_trace

    def timeline(self, program, cfg, mve_trace) -> Timeline:
        elem_ops, bits, mem_bytes = _neon_work(mve_trace)
        m = self.model
        lanes = max(1, m.simd_bits // bits)
        cycles = m.kernel_cycles(1.0, elem_ops, bits, mem_bytes)
        compute = elem_ops / (lanes * m.pipes)
        data = mem_bytes / m.l1_bytes_per_cycle
        simd_ops = int(math.ceil(elem_ops / lanes))
        tl = Timeline(total_cycles=cycles, compute_cycles=compute,
                      data_cycles=data,
                      scalar_cycles=simd_ops * 0.5 / 4.0,
                      vector_instructions=simd_ops,
                      scalar_instructions=int(math.ceil(simd_ops * 0.5)))
        tl.lane_slots = cycles * lanes * m.pipes
        tl.busy_lane_cycles = compute * lanes * m.pipes
        tl.cb_slots = cycles * m.pipes
        tl.busy_cb_cycles = compute * m.pipes
        tl.idle_cycles = max(0.0, cycles - compute - data)
        return tl

    def energy(self, program, cfg, mve_trace) -> EnergyReport:
        elem_ops, bits, mem_bytes = _neon_work(mve_trace)
        simd_ops = elem_ops / max(1, self.model.simd_bits // bits)
        return cost.neon_energy(simd_ops, mem_bytes, self.energy_params)

    def instruction_mix(self, program, cfg) -> InstructionMix:
        trace = _trace_cache_walk(program, cfg, self.name)
        elem_ops, bits, mem_bytes = _neon_work(trace)
        lanes = max(1, self.model.simd_bits // bits)
        simd_ops = int(math.ceil(elem_ops / lanes))
        mem_ops = int(math.ceil(mem_bytes / (self.model.simd_bits // 8)))
        return InstructionMix(vector=simd_ops + mem_ops, memory=mem_ops,
                              scalar=int(math.ceil(simd_ops * 0.5)))


def _trace_cache_walk(program, cfg, cache_tag: str) -> List[TraceEvent]:
    """Static engine trace of a program (compile-walk only, cached via
    the engine LRU under the calling target's tag) — the workload record
    instruction_mix needs when no execution state is at hand."""
    from ..core.engine import compile_program
    return compile_program(program, cfg, cache_tag=cache_tag).static_trace


# ---------------------------------------------------------------------------
# Registration: the paper's six-way comparison matrix.
# ---------------------------------------------------------------------------

MVE_BS = register_target(InCacheTarget(
    "mve-bs", scheme="bs",
    description="MVE on the bit-serial engine (Neural Cache; default)"))
MVE_BP = register_target(InCacheTarget(
    "mve-bp", scheme="bp",
    description="MVE on the bit-parallel engine (VRAM: n-bit horizontal)"))
MVE_BH = register_target(InCacheTarget(
    "mve-bh", scheme="bh",
    description="MVE on the bit-hybrid engine (EVE: p-bit segments)"))
MVE_AC = register_target(InCacheTarget(
    "mve-ac", scheme="ac",
    description="MVE on the associative engine (CAPE: truth-table rows)"))
RVV_1D = register_target(RVV1DTarget(
    description="1D long-vector (RVV-style) ISA on the bit-serial engine"))
NEON = register_target(NeonTarget())
