"""The :class:`Target` protocol, registry, and :class:`CompiledArtifact`.

A *target* is a named bundle of

  * a **machine configuration** — the engine geometry + in-SRAM compute
    scheme the kernel is priced for;
  * a **program lowering** — how the MVE program's accesses map onto the
    target's ISA (identity for MVE itself; the Section III-C segment
    decomposition of :mod:`repro.core.rvv` for a 1D long-vector ISA;
    an analytic workload extraction for packed SIMD);
  * a **timing model** — cycles via the controller/CB timeline
    (:func:`repro.core.cost.simulate`) or an analytic throughput model;
  * an **energy model** — the shared :class:`~repro.core.cost.EnergyParams`
    component model.

Every target *executes* through the same functional engine
(:func:`repro.core.engine.compile_program`), so results are bit-exact
across targets by contract — the paper's cross-ISA comparisons (Figures
10/11/13) run the *same* kernel and differ only in how instructions are
issued and priced.  The RVV path is literally the same access, sliced
into partial 1D segments; ``tests/test_targets.py`` and
``tests/test_conformance.py`` assert the bit-exactness invariant on
every registered target.

Third-party schemes plug in by subclassing :class:`Target` (or any of
the concrete adapters in :mod:`repro.targets.builtin`) and calling
:func:`register_target` — see docs/TARGETS.md for a worked example.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Optional, Tuple

from ..core import cost
from ..core.cost import EnergyReport, Timeline, TimingParams, TraceEvent
from ..core.engine import CompiledProgram, compile_program
from ..core.isa import ProgramError
from ..core.machine import MVEConfig
from ..core.rvv import RVVStats


@dataclasses.dataclass(frozen=True)
class InstructionMix:
    """Dynamic instruction counts of one program as the target issues it
    (the currency of Figure 11: MVE needs 2.3x fewer vector and 2x fewer
    scalar instructions than the 1D baseline)."""

    vector: int = 0        # vector instructions (incl. memory + moves)
    memory: int = 0        # vector loads/stores
    move: int = 0          # pack/unpack moves
    mask: int = 0          # mask materialization / predicate config
    scalar: int = 0        # scalar-core instructions (addressing, masks)
    config: int = 0        # control-register writes

    @property
    def total(self) -> int:
        return self.vector + self.scalar + self.config

    @classmethod
    def from_rvv_stats(cls, stats: RVVStats) -> "InstructionMix":
        return cls(vector=stats.vector_instructions,
                   memory=stats.memory_instructions,
                   move=stats.move_instructions,
                   mask=stats.mask_instructions,
                   scalar=stats.scalar_instructions,
                   config=stats.config_instructions)


class Target(abc.ABC):
    """One ISA x compute-scheme x cost-model combination.

    Concrete targets are frozen dataclasses (hashable, comparable) with
    at least ``name`` and ``description`` fields; the registry maps
    names to instances.  The protocol splits cleanly into *execution*
    (shared — :meth:`machine_config` feeds the functional engine) and
    *pricing* (per-target — :meth:`performance_trace`, :meth:`timeline`,
    :meth:`energy`, :meth:`instruction_mix`).
    """

    # concrete dataclasses provide these as fields
    name: str
    description: str
    isa_name: str
    #: Timing constants the default :meth:`timeline` simulates with;
    #: dataclass subclasses typically redeclare this as a field.
    timing: TimingParams = TimingParams()

    # -- execution ---------------------------------------------------------
    @abc.abstractmethod
    def machine_config(self, cfg: Optional[MVEConfig] = None,
                       **overrides) -> MVEConfig:
        """The machine configuration this target executes and is priced
        under, derived from ``cfg`` (default geometry when ``None``) with
        per-call ``overrides`` applied last."""

    def freq_ghz(self, cfg: MVEConfig) -> float:
        """Clock used to convert the target's cycles to wall time."""
        return cfg.freq_ghz

    # -- pricing -----------------------------------------------------------
    @abc.abstractmethod
    def performance_trace(self, program, cfg: MVEConfig,
                          mve_trace: List[TraceEvent]) -> List[TraceEvent]:
        """The trace the *target's* ISA would issue for this program.

        ``mve_trace`` is the executed (or static) MVE engine trace — the
        ground-truth record of what the kernel touched; targets that
        re-issue the work differently (1D slicing, packed SIMD) derive
        their own stream from the program and/or that record."""

    def timeline(self, program, cfg: MVEConfig,
                 mve_trace: List[TraceEvent]) -> Timeline:
        """Cycles, by default via the controller/CB timeline model over
        :meth:`performance_trace`."""
        return cost.simulate(self.performance_trace(program, cfg, mve_trace),
                             cfg, self.timing)

    @abc.abstractmethod
    def energy(self, program, cfg: MVEConfig,
               mve_trace: List[TraceEvent]) -> EnergyReport:
        """Per-component energy of one execution (pJ)."""

    @abc.abstractmethod
    def instruction_mix(self, program, cfg: MVEConfig) -> InstructionMix:
        """Dynamic instruction counts as this target issues the program."""


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: "Dict[str, Target]" = {}


def register_target(target: Target, overwrite: bool = False) -> Target:
    """Register a target under ``target.name``.

    Third-party compute schemes call this once at import time; the name
    then works everywhere a target is accepted (``repro.targets.compile``,
    ``Kernel.compile(target=...)``, ``MVEScheduler.submit(target=...)``,
    ``benchmarks/run.py --only targets``).
    """
    if not isinstance(target, Target):
        raise TypeError(f"register_target wants a Target, got "
                        f"{type(target).__name__}")
    if target.name in _REGISTRY and not overwrite:
        raise ProgramError(
            f"target {target.name!r} is already registered "
            f"(pass overwrite=True to replace it)")
    _REGISTRY[target.name] = target
    return target


def get_target(name) -> Target:
    """Resolve a registered target by name; :class:`Target` instances
    pass through.  Unknown names raise a :class:`ProgramError` that
    names every registered target."""
    if isinstance(name, Target):
        return name
    target = _REGISTRY.get(name)
    if target is None:
        raise ProgramError(
            f"unknown target {name!r}; registered targets: "
            f"{', '.join(sorted(_REGISTRY))}")
    return target


def list_targets() -> Tuple[str, ...]:
    """Registered target names, registration order preserved."""
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# The uniform compiled artifact.
# ---------------------------------------------------------------------------

class CompiledArtifact:
    """What ``repro.targets.compile`` returns: one compiled program bound
    to one target, exposing the uniform surface

        run / run_batch / trace / timeline / energy / instruction_mix

    Execution (`run`, `run_batch`) dispatches to the shared
    :class:`~repro.core.engine.CompiledProgram` — results are bit-exact
    across targets.  Pricing (`timeline`, `energy`, ...) goes through the
    target's models.  The pricing methods take an optional ``source``:

      * ``None`` — price the compile-time static trace (exact unless the
        program uses random-base accesses, whose cache-line counts are
        data-dependent);
      * an execution state (anything with a ``.trace``) — price that
        run's exact trace;
      * a memory image (or dict of named operands for kernel artifacts)
        — execute it and price the exact trace.
    """

    def __init__(self, target: Target, cfg: MVEConfig, cp: CompiledProgram):
        self.target = target
        self.cfg = cfg
        self.cp = cp

    # -- delegation --------------------------------------------------------
    @property
    def program(self):
        return self.cp.program

    @property
    def kernel(self):
        """The frontend kernel this artifact was compiled from (None for
        raw programs)."""
        return self.cp.kernel

    @property
    def mode(self) -> str:
        return self.cp.mode

    def run(self, memory=None):
        """Execute once; ``(memory_after, state)`` exactly like
        :meth:`CompiledProgram.run`.  Kernel artifacts accept a dict of
        named operand arrays or nothing (declared inits apply) and read
        results back via ``state.operands``."""
        if memory is None:
            if self.kernel is None:
                raise TypeError(
                    "run() without a memory image needs a frontend "
                    "kernel artifact (declared inits form the image)")
            memory = self.kernel.pack()
        return self.cp.run(memory)

    def run_batch(self, memories):
        """Vmapped execution over a leading batch axis (see
        :meth:`CompiledProgram.run_batch`)."""
        return self.cp.run_batch(memories)

    def warmup(self, memory_size, batch=None) -> "CompiledArtifact":
        self.cp.warmup(memory_size, batch)
        return self

    # -- pricing -----------------------------------------------------------
    def _mve_trace(self, source=None) -> List[TraceEvent]:
        if source is None:
            return self.cp.static_trace
        trace = getattr(source, "trace", None)
        # Execution states expose ``trace`` as data; arrays expose a
        # ``trace()`` *method* (matrix trace) — those are memory images.
        if trace is not None and not callable(trace):
            return trace
        return self.run(source)[1].trace

    def trace(self, source=None) -> List[TraceEvent]:
        """The instruction stream this target's ISA issues (see class
        docstring for ``source``)."""
        return self.target.performance_trace(
            self.program, self.cfg, self._mve_trace(source))

    def timeline(self, source=None) -> Timeline:
        """Cycles under this target's timing model."""
        return self.target.timeline(
            self.program, self.cfg, self._mve_trace(source))

    def energy(self, source=None) -> EnergyReport:
        """Per-component energy (pJ) under this target's energy model."""
        return self.target.energy(
            self.program, self.cfg, self._mve_trace(source))

    def instruction_mix(self) -> InstructionMix:
        """Dynamic instruction counts as this target issues the program."""
        return self.target.instruction_mix(self.program, self.cfg)

    def us(self, source=None) -> float:
        """Modeled wall time (microseconds) at the target's clock."""
        return self.timeline(source).us(self.target.freq_ghz(self.cfg))

    def __repr__(self) -> str:
        return (f"CompiledArtifact(target={self.target.name!r}, "
                f"mode={self.mode!r}, "
                f"instructions={len(self.program)})")


def compile(kernel_or_program, target="mve-bs",
            cfg: Optional[MVEConfig] = None, mode: Optional[str] = None,
            opt_level: Optional[int] = None,
            **overrides) -> CompiledArtifact:
    """THE entry point: compile a frontend kernel or raw MVE program for
    one target.

        art = repro.targets.compile(kernel, target="rvv-1d")
        out, state = art.run({"x": xs, "y": ys})
        art.timeline(state).total_cycles     # 1D-ISA cycles
        art.energy(state).total_pj

    ``target`` is a registered name (``repro.targets.list_targets()``)
    or a :class:`Target` instance; ``cfg`` overrides the base machine
    geometry and ``**overrides`` patch individual
    :class:`~repro.core.machine.MVEConfig` fields (``num_arrays=8``,
    ``bh_segment_bits=8``, ...).  Compilations are cached per target
    (``cache_tag``), so the same program compiled for two targets holds
    two independent LRU entries (``cache_info().per_target``).

    ``opt_level`` routes the program through the :mod:`repro.opt` pass
    pipeline first (``None`` = as written); the artifact's ``program``,
    ``trace`` and ``timeline`` then describe the optimized text, priced
    under this target's models — which is what ``repro.opt.tune()``
    sweeps schedules with (docs/OPTIMIZER.md).
    """
    tgt = get_target(target)
    tcfg = tgt.machine_config(cfg, **overrides)
    cp = compile_program(kernel_or_program, tcfg, mode=mode,
                         cache_tag=tgt.name, opt_level=opt_level)
    return CompiledArtifact(tgt, tcfg, cp)
