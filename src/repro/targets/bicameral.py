"""Bicameral split-cache demo target (arXiv:2407.15440).

The Bicameral cache splits one physical SRAM macro into an *attentive*
partition (compute-enabled subarrays with the full MVE peripheral
apparatus) and a plain *storage* partition that keeps ordinary cache
capacity.  Mapped onto this repo: the compute partition is exactly the
paper's Table IV geometry (32 arrays — execution, timing and energy are
**bit-exact** with ``mve-bs``), while the macro additionally carries 32
storage-only subarrays that pay cell area but no compute peripherals.

What changes is the *area accounting*: the in-cache additions are
amortized over a twice-as-large L2, so the ``overhead_vs_cache_pct``
metric of :class:`repro.silicon.area.AreaReport` drops relative to a
compute-only macro — the argument the Bicameral paper makes for
retrofitting compute into a big cache instead of shrinking it.

Registered at package import like the built-ins, so it shows up in
``repro.targets.list_targets()``, the conformance fuzz loop and the
``targets`` bench section; also the worked ``register_target()`` example
of docs/TARGETS.md.
"""
from __future__ import annotations

import dataclasses

from ..silicon.area import AreaReport, area_report
from .base import register_target
from .builtin import InCacheTarget


@dataclasses.dataclass(frozen=True)
class BicameralTarget(InCacheTarget):
    """``mve-bs`` compute partition + storage-only subarrays."""

    name: str = "mve-bicameral"
    scheme: str = "bs"
    description: str = ("Bicameral split cache: bit-serial compute "
                        "partition + equal storage partition "
                        "(arXiv:2407.15440)")
    #: Storage-only subarrays sharing the macro with the compute ones.
    storage_arrays: int = 32

    def area_report(self, tech_nm: float = 7.0) -> AreaReport:
        """Area accounting with the storage partition in the macro."""
        return area_report(self.machine_config(), tech_nm=tech_nm,
                           storage_arrays=self.storage_arrays)


MVE_BICAMERAL = register_target(BicameralTarget())
