"""Pallas TPU kernel: multi-dimensional strided scatter (MVE ``vsst``).

The store-side counterpart of :mod:`repro.kernels.mdgather`: lane values
are written back to Algorithm-1 addresses.  Collisions (stride-0 output
dims) follow the interpreter's last-lane-wins semantics; the oracle is
:func:`repro.kernels.ref.mdscatter_ref`.

The destination tile is VMEM-resident per grid step (input_output_alias
keeps it in place); lanes are streamed in (8,128) tiles like the gather.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANE_TILE = (8, 128)


def _scatter_kernel(dims: Tuple[int, ...], strides: Tuple[int, ...],
                    base: int, total: int, n_tiles: int,
                    values_ref, dst_in_ref, dst_ref):
    """Single grid step: walk every lane tile in order (sequential, so
    later lanes win on address collisions)."""
    dst_ref[...] = dst_in_ref[...]
    rows, cols = LANE_TILE

    def tile_body(tile, _):
        lane0 = tile * rows * cols
        lane = (lane0
                + jax.lax.broadcasted_iota(jnp.int32, LANE_TILE, 0) * cols
                + jax.lax.broadcasted_iota(jnp.int32, LANE_TILE, 1))
        addr = jnp.full(LANE_TILE, base, dtype=jnp.int32)
        rem = lane
        for length, stride in zip(dims, strides):
            idx = rem % length
            rem = rem // length
            addr = addr + idx * stride
        active = (lane < total).reshape(-1)
        # inactive lanes write into the trash slot the wrapper appended —
        # masking them with a read-modify-write would race the active
        # lanes' updates inside the same vector scatter
        trash = dst_ref.shape[0] - 1
        flat_addr = jnp.where(active, addr.reshape(-1), trash)
        vals = values_ref[pl.ds(tile * rows, rows), :].reshape(-1)
        dst_ref[flat_addr] = vals
        return 0

    jax.lax.fori_loop(0, n_tiles, tile_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("dims", "strides", "base", "interpret"))
def mdscatter(dst: jnp.ndarray, values: jnp.ndarray,
              dims: Tuple[int, ...], strides: Tuple[int, ...],
              base: int = 0, interpret: bool = True) -> jnp.ndarray:
    """Scatter ``prod(dims)`` lane values into flat ``dst``."""
    total = int(np.prod(dims))
    rows, cols = LANE_TILE
    tile_elems = rows * cols
    n_tiles = -(-total // tile_elems)
    pad = n_tiles * tile_elems - values.shape[0]
    vals = jnp.pad(values, (0, max(pad, 0)))[: n_tiles * tile_elems]
    vals = vals.reshape(n_tiles * rows, cols).astype(dst.dtype)

    dst_pad = jnp.pad(dst, (0, 1))               # trash slot for masked lanes
    kernel = functools.partial(_scatter_kernel, tuple(dims),
                               tuple(strides), base, total, n_tiles)
    out = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec(vals.shape, lambda: (0, 0)),
                  pl.BlockSpec(dst_pad.shape, lambda: (0,))],
        out_specs=pl.BlockSpec(dst_pad.shape, lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct(dst_pad.shape, dst.dtype),
        interpret=interpret,
    )(vals, dst_pad)
    return out[:-1]
