"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; the Pallas kernels must match them bit-for-bit
(integer kernels) or to numerical tolerance (float kernels).  Tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle in interpret mode.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Multi-dimensional strided gather/scatter (the MVE vsld/vsst data path).
# ---------------------------------------------------------------------------

def mdv_lane_addresses(dims: Sequence[int], strides: Sequence[int],
                       base: int, lanes: int) -> jnp.ndarray:
    """Per-lane flat source addresses per Algorithm 1 (x fastest)."""
    lane = jnp.arange(lanes, dtype=jnp.int32)
    addr = jnp.full((lanes,), base, dtype=jnp.int32)
    rem = lane
    for d, (length, stride) in enumerate(zip(dims, strides)):
        idx = rem % length
        rem = rem // length
        addr = addr + idx * stride
    return addr


def mdgather_ref(src: jnp.ndarray, dims: Sequence[int],
                 strides: Sequence[int], base: int = 0) -> jnp.ndarray:
    """Gather ``prod(dims)`` lanes from flat ``src``; Algorithm 1."""
    lanes = int(np.prod(dims))
    addr = mdv_lane_addresses(dims, strides, base, lanes)
    return src[addr]


def mdscatter_ref(dst: jnp.ndarray, values: jnp.ndarray,
                  dims: Sequence[int], strides: Sequence[int],
                  base: int = 0) -> jnp.ndarray:
    lanes = int(np.prod(dims))
    addr = mdv_lane_addresses(dims, strides, base, lanes)
    return dst.at[addr].set(values[:lanes])


# ---------------------------------------------------------------------------
# Bit-plane (bit-serial adapted) quantized matmul.
# ---------------------------------------------------------------------------

def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 exact matmul."""
    return jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def bitplane_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Same result computed plane-by-plane — the oracle mirrors the
    bit-serial decomposition so tests validate the *algorithm*, not just
    the final kernel: w = -128*b7 + sum_{b<7} 2^b * b_b (two's complement).
    """
    xi = x.astype(jnp.int32)
    wu = w.astype(jnp.int32) & 0xFF
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for b in range(8):
        plane = (wu >> b) & 1
        partial = jnp.dot(xi, plane, preferred_element_type=jnp.int32)
        acc = acc + (partial << b) * (-1 if b == 7 else 1)
    return acc


def quantize_rowwise_ref(x: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization (used by serving + gradient
    compression)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Model-block oracles for the repro.nn kernel zoo (docs/MODELS.md).
# ---------------------------------------------------------------------------

def tree_sum_ref(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Pairwise (log-tree) summation along one power-of-two axis.

    The MVE reduction idiom halves the dimension per step (Section IV),
    so cross-dimension sums on the lane grid happen in *this* order, not
    left-to-right.  Oracles that promise bit-exactness against a lane
    reduction must mirror it — fp32 addition is not associative.
    """
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"tree_sum_ref needs a power of two, got {n}"
    while n > 1:
        half = n // 2
        x = x[..., :half] + x[..., half:n]
        n = half
    return x[..., 0]


def ssm_scan_ref(h: jnp.ndarray, a: jnp.ndarray, bvec: jnp.ndarray,
                 x: jnp.ndarray, c: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One diagonal-SSM decode step (Mamba2/SSD-style state update).

    ``h, a: (P, N)``; ``bvec, c: (N,)``; ``x: (P,)``.  Returns
    ``(h_new, y)`` with ``h_new = a * h + bvec * x`` and
    ``y[p] = sum_n c[n] * h_new[p, n]`` — the sum in pairwise-tree
    order, and every multiply/add in the exact sequence the MVE block
    kernel emits, so fp32 results match bit for bit.
    """
    h = h.astype(jnp.float32)
    t = bvec.astype(jnp.float32)[None, :] * x.astype(jnp.float32)[:, None]
    h_new = a.astype(jnp.float32) * h
    h_new = h_new + t
    y = tree_sum_ref(c.astype(jnp.float32)[None, :] * h_new, axis=-1)
    return h_new, y


def moe_gather_ref(w: jnp.ndarray, experts: jnp.ndarray,
                   gates: jnp.ndarray) -> jnp.ndarray:
    """Top-k expert gather: ``y[t] = sum_j gates[t, j] * w[experts[t, j]]``.

    ``w: (E, D)`` expert rows, ``experts: (T, topk)`` int indices,
    ``gates: (T, topk)`` fp32.  Accumulated j = 0..topk-1 in order
    (matching the MVE random-base gather kernel), so fp32 is bit-exact.
    """
    t, topk = experts.shape
    y = jnp.zeros((t, w.shape[1]), jnp.float32)
    for j in range(topk):
        rows = w.astype(jnp.float32)[experts[:, j]]
        y = y + gates.astype(jnp.float32)[:, j][:, None] * rows
    return y


# ---------------------------------------------------------------------------
# Flash attention (forward) — online softmax over kv blocks.
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    """Naive reference: (B, H, Sq, D) x (B, H, Sk, D) -> (B, H, Sq, D).

    fp32 softmax; this is the oracle for both the Pallas kernel and the
    chunked-attention path used inside the models.
    """
    *_, sq, d = q.shape
    sk = k.shape[-2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("...qd,...kd->...qk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("...qk,...kd->...qd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
