"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; the Pallas kernels must match them bit-for-bit
(integer kernels) or to numerical tolerance (float kernels).  Tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle in interpret mode.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Multi-dimensional strided gather/scatter (the MVE vsld/vsst data path).
# ---------------------------------------------------------------------------

def mdv_lane_addresses(dims: Sequence[int], strides: Sequence[int],
                       base: int, lanes: int) -> jnp.ndarray:
    """Per-lane flat source addresses per Algorithm 1 (x fastest)."""
    lane = jnp.arange(lanes, dtype=jnp.int32)
    addr = jnp.full((lanes,), base, dtype=jnp.int32)
    rem = lane
    for d, (length, stride) in enumerate(zip(dims, strides)):
        idx = rem % length
        rem = rem // length
        addr = addr + idx * stride
    return addr


def mdgather_ref(src: jnp.ndarray, dims: Sequence[int],
                 strides: Sequence[int], base: int = 0) -> jnp.ndarray:
    """Gather ``prod(dims)`` lanes from flat ``src``; Algorithm 1."""
    lanes = int(np.prod(dims))
    addr = mdv_lane_addresses(dims, strides, base, lanes)
    return src[addr]


def mdscatter_ref(dst: jnp.ndarray, values: jnp.ndarray,
                  dims: Sequence[int], strides: Sequence[int],
                  base: int = 0) -> jnp.ndarray:
    lanes = int(np.prod(dims))
    addr = mdv_lane_addresses(dims, strides, base, lanes)
    return dst.at[addr].set(values[:lanes])


# ---------------------------------------------------------------------------
# Bit-plane (bit-serial adapted) quantized matmul.
# ---------------------------------------------------------------------------

def int8_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 exact matmul."""
    return jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def bitplane_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Same result computed plane-by-plane — the oracle mirrors the
    bit-serial decomposition so tests validate the *algorithm*, not just
    the final kernel: w = -128*b7 + sum_{b<7} 2^b * b_b (two's complement).
    """
    xi = x.astype(jnp.int32)
    wu = w.astype(jnp.int32) & 0xFF
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for b in range(8):
        plane = (wu >> b) & 1
        partial = jnp.dot(xi, plane, preferred_element_type=jnp.int32)
        acc = acc + (partial << b) * (-1 if b == 7 else 1)
    return acc


def quantize_rowwise_ref(x: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization (used by serving + gradient
    compression)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Flash attention (forward) — online softmax over kv blocks.
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    """Naive reference: (B, H, Sq, D) x (B, H, Sk, D) -> (B, H, Sq, D).

    fp32 softmax; this is the oracle for both the Pallas kernel and the
    chunked-attention path used inside the models.
    """
    *_, sq, d = q.shape
    sk = k.shape[-2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("...qd,...kd->...qk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("...qk,...kd->...qd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
