"""Pallas TPU kernel: flash attention forward (online softmax).

The perf-critical compute hot-spot of every transformer cell in the
framework.  Block sizes are MXU/VPU aligned (q-block 128, kv-block 128,
head_dim expected 64/128).  The kv stream for one (batch*head) is VMEM
resident per grid step; the q dimension is gridded, and causal masking
skips fully-masked kv blocks via the loop bound.

Oracle: :func:`repro.kernels.ref.flash_attention_ref` (fp32 softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(scale: float, causal: bool, bq: int, bk: int,
                  skp: int, sk_true: int, offset: int,
                  q_ref, k_ref, v_ref, o_ref):
    """One q block against the kv stream.

    ``offset = sk_true - sq_true`` aligns the causal diagonal (decode
    convention: the last query row sees the full kv horizon).  Padded kv
    rows (``kpos >= sk_true``) are masked unconditionally.
    """
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    d = q.shape[-1]

    nk = skp // bk
    if causal:
        last_row = qi * bq + bq - 1 + offset
        upper = jnp.clip(last_row // bk + 1, 0, nk)
    else:
        upper = nk

    def body(j, carry):
        acc, m, l = carry
        kblk = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        vblk = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, kblk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bq, bk)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < sk_true
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0) + offset
            valid = valid & (qpos >= kpos)
        logits = jnp.where(valid, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.where(logits > NEG_INF / 2,
                      jnp.exp(logits - m_new[:, None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, vblk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc, m0, l0))
    l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = True) -> jnp.ndarray:
    """(B, H, Sq, D) attention over (B, H, Sk, D) keys/values.

    Sq/Sk are padded to block multiples internally; the causal diagonal is
    aligned to the *unpadded* sizes (decode convention).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d))
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    pq, pk = -sq % bq, -sk % bk
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    sqp, skp = qf.shape[1], kf.shape[1]

    kernel = functools.partial(_flash_kernel, scale, causal, bq, bk,
                               skp, sk, sk - sq)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sqp // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, skp, d), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, skp, d), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :sq, :].reshape(b, h, sq, d)
