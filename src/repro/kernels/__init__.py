"""Pallas TPU kernels for the framework's compute hot-spots.

  mdgather      — MVE vsld multi-dim strided gather (TMU/crossbar -> VMEM
                  tile + iota-arithmetic adaptation)
  mdscatter     — MVE vsst multi-dim strided scatter (store-side TMU)
  bitplane_gemm — bit-serial -> bit-plane int GEMM on the MXU
  flash_attention — online-softmax attention forward

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jit'd
dispatch wrappers the models call.
"""
from . import ops, ref  # noqa: F401
