"""Pallas TPU kernels for the framework's compute hot-spots.

  mdgather      — MVE vsld multi-dim strided gather (TMU/crossbar -> VMEM
                  tile + iota-arithmetic adaptation)
  mdscatter     — MVE vsst multi-dim strided scatter (store-side TMU)
  bitplane_gemm — bit-serial -> bit-plane int GEMM on the MXU
  flash_attention — online-softmax attention forward

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jit'd
dispatch wrappers the models call.

Submodules import lazily (PEP 562, like :mod:`repro.core`): ``ref``
holds only pure-jnp oracles and is what :mod:`repro.nn` validates
against, while ``ops`` pulls in the Pallas TPU kernel modules — eager
import here would drag the TPU lowering stack into CPU-only consumers.
"""
_LAZY = {"ops", "ref", "mdgather", "mdscatter", "bitplane_gemm",
         "flash_attention"}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _LAZY)
