"""Pallas TPU kernel: multi-dimensional strided gather (MVE ``vsld``).

Hardware adaptation (see DESIGN.md): in the paper, the MVE controller walks
Algorithm-1 addresses through the MSHRs, and a Transpose Memory Unit +
crossbar route words onto bitlines.  On TPU the analogous structure is a
grid of DMA-fed VMEM tiles whose *index arithmetic* (not data) encodes the
multi-dimensional access:

  * lane blocks (8 x 128, one VREG tile) play the role of a CB's bitlines;
  * the per-lane address computation is vectorized iota arithmetic — the
    TMU/crossbar becomes an in-register gather from a VMEM-resident source
    tile;
  * stride-0 dimensions (replication) are *free* at the register level,
    exactly the paper's motivation for encoding them in the ISA.

The source array must fit in VMEM for this kernel (the ops.py wrapper falls
back to the XLA gather for larger sources and documents the tiling
strategy for an HBM-resident variant).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANE_TILE = (8, 128)   # sublanes x lanes of one TPU vector register


def _gather_kernel(dims: Tuple[int, ...], strides: Tuple[int, ...],
                   base: int, total: int,
                   src_ref, out_ref):
    """One grid step fills one (8,128) lane tile of the output."""
    tile = pl.program_id(0)
    rows, cols = LANE_TILE
    lane0 = tile * rows * cols
    # lane ids of this tile, shaped (8, 128)
    lane = (lane0
            + jax.lax.broadcasted_iota(jnp.int32, LANE_TILE, 0) * cols
            + jax.lax.broadcasted_iota(jnp.int32, LANE_TILE, 1))
    addr = jnp.full(LANE_TILE, base, dtype=jnp.int32)
    rem = lane
    for length, stride in zip(dims, strides):
        idx = rem % length
        rem = rem // length
        addr = addr + idx * stride
    # lanes beyond prod(dims) are inactive -> clamp and zero-fill
    active = lane < total
    addr = jnp.where(active, addr, 0)
    vals = src_ref[addr.reshape(-1)].reshape(LANE_TILE)
    out_ref[...] = jnp.where(active, vals, 0)


@functools.partial(jax.jit,
                   static_argnames=("dims", "strides", "base", "interpret"))
def mdgather(src: jnp.ndarray, dims: Tuple[int, ...],
             strides: Tuple[int, ...], base: int = 0,
             interpret: bool = True) -> jnp.ndarray:
    """Gather ``prod(dims)`` elements of flat ``src`` per Algorithm 1.

    Returns a flat (padded to lane-tile multiple) vector; callers slice
    ``[:prod(dims)]``.
    """
    total = int(np.prod(dims))
    rows, cols = LANE_TILE
    tile_elems = rows * cols
    n_tiles = -(-total // tile_elems)
    out_shape = jax.ShapeDtypeStruct((n_tiles * rows, cols), src.dtype)

    kernel = functools.partial(_gather_kernel, tuple(dims), tuple(strides),
                               base, total)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(src.shape, lambda i: (0,) * src.ndim)],
        out_specs=pl.BlockSpec(LANE_TILE, lambda i: (i, 0)),
        out_shape=out_shape,
        interpret=interpret,
    )(src)
    return out.reshape(-1)[:total]
