"""Pallas TPU kernel: bit-plane quantized GEMM (bit-serial, TPU-adapted).

The paper's engine computes n-bit multiplies bit-serially (n^2+5n cycles,
Table II) because SRAM peripherals only see one bit-slice per cycle.  The
TPU-native translation of "bit-serial" is *bit-plane* decomposition: an
int8 weight matrix is a sum of 8 binary planes

    W = -128*P7 + sum_{b=0..6} 2^b * Pb,     Pb in {0,1}

so an int8 GEMM becomes 8 binary GEMMs on the MXU with shifted int32
accumulation.  The same O(bits) structure the paper exploits for
low-precision speedups (Section VII-E) shows up here as: fewer planes for
int4 weights -> proportionally less MXU work.

Two kernels:
  * ``int8_matmul``   — direct int8 x int8 -> int32 tiled MXU matmul
                        (the production path).
  * ``bitplane_matmul`` — the bit-serial-structured variant, numerically
                        identical, used for the precision-scaling study.

Tiles are MXU-aligned (128 x 128); K is resident per tile pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BM, BN = 128, 128


def _int8_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _bitplane_kernel(nbits: int, x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    wu = w_ref[...].astype(jnp.int32) & 0xFF
    acc = jnp.zeros((x.shape[0], wu.shape[1]), jnp.int32)
    for b in range(nbits):                      # bit-serial over planes
        plane = (wu >> b) & 1
        partial = jax.lax.dot_general(
            x, plane, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        # two's complement: the top plane is the negative power
        sign = -1 if b == nbits - 1 else 1
        acc = acc + sign * (partial << b)
    o_ref[...] = acc


def _tiled_call(kernel, x, w, interpret):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    pm, pn = -m % BM, -n % BN
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pn)))
    gm, gn = x.shape[0] // BM, w.shape[1] // BN
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, BN), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]), jnp.int32),
        interpret=interpret,
    )(x, w)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(x: jnp.ndarray, w: jnp.ndarray,
                interpret: bool = True) -> jnp.ndarray:
    """Exact int8 x int8 -> int32 matmul, (M,K) @ (K,N)."""
    return _tiled_call(_int8_kernel, x, w, interpret)


@functools.partial(jax.jit, static_argnames=("nbits", "interpret"))
def bitplane_matmul(x: jnp.ndarray, w: jnp.ndarray, nbits: int = 8,
                    interpret: bool = True) -> jnp.ndarray:
    """Bit-serial-structured int matmul; identical to int8_matmul for
    nbits=8, proportionally cheaper for narrower weights."""
    return _tiled_call(functools.partial(_bitplane_kernel, nbits),
                       x, w, interpret)
