"""Public jit'd wrappers around the Pallas kernels.

Every op dispatches between the Pallas kernel (TPU target; ``interpret``
mode on CPU) and the pure-jnp oracle in :mod:`repro.kernels.ref`.  The
models call through here so a single flag flips the whole framework
between kernel and XLA paths.
"""
from __future__ import annotations

import functools
import os
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitplane_gemm import bitplane_matmul, int8_matmul
from .flash_attention import flash_attention as _flash_pallas
from .mdgather import mdgather as _mdgather_pallas

# Models use the oracle path by default on CPU (fast XLA fusion); tests and
# TPU deployments flip this on.
_USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
# Sources above this size do not fit a VMEM-resident gather tile.
_VMEM_GATHER_LIMIT = 2 ** 20


def use_pallas() -> bool:
    return _USE_PALLAS


def mdv_gather(src: jnp.ndarray, dims: Sequence[int],
               strides: Sequence[int], base: int = 0,
               force_pallas: bool | None = None) -> jnp.ndarray:
    """MVE vsld: multi-dimensional strided gather from a flat buffer."""
    dims = tuple(int(d) for d in dims)
    strides = tuple(int(s) for s in strides)
    pallas = _USE_PALLAS if force_pallas is None else force_pallas
    if pallas and src.size <= _VMEM_GATHER_LIMIT:
        return _mdgather_pallas(src, dims, strides, base)
    return ref.mdgather_ref(src, dims, strides, base)


def quantized_matmul(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
                     bitserial: bool = False,
                     force_pallas: bool | None = None) -> jnp.ndarray:
    """x(float) @ dequant(wq int8, per-col scale) with int8 activations.

    Serving-path op: activations quantized per-row, weights pre-quantized
    per-column; exact int32 accumulation then one fp rescale.
    """
    xq, xs = ref.quantize_rowwise_ref(x)
    pallas = _USE_PALLAS if force_pallas is None else force_pallas
    if pallas:
        fn = bitplane_matmul if bitserial else int8_matmul
        acc = fn(xq, wq)
    else:
        acc = (ref.bitplane_matmul_ref(xq, wq) if bitserial
               else ref.int8_matmul_ref(xq, wq))
    return acc.astype(jnp.float32) * xs * scale[None, :]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    force_pallas: bool | None = None) -> jnp.ndarray:
    pallas = _USE_PALLAS if force_pallas is None else force_pallas
    if pallas:
        return _flash_pallas(q, k, v, causal=causal, scale=scale)
    return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
