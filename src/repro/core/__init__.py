"""MVE core: the paper's contribution as a composable module.

Layers:
  isa      — instruction set (Table II), stride modes, intrinsics
  machine  — cache geometry, control registers, lane flattening
  interp   — step executor (the semantic oracle; see docs/ISA.md)
  engine   — whole-program compiler + executor front-end (docs/ENGINE.md):
             mode "vm" (default) or "fused"
  vm       — program-as-data datapath: one XLA executable per signature,
             shared by every program with that signature
  cost     — BS/BP/BH/AC cycle models + controller/CB timeline
  rvv      — 1D long-vector baseline lowering (Figures 10/11/13)
  patterns — Section IV data-parallel patterns for 12 mobile libraries
  packing  — the MVE lane/masking abstraction reused by the LM framework
"""
from . import (cost, engine, interp, isa, machine, packing, patterns,  # noqa: F401
               rvv, vm)
from .engine import (CompiledProgram, cache_info,  # noqa: F401
                     compile_program)
from .interp import MVEInterpreter  # noqa: F401
from .machine import MVEConfig  # noqa: F401
from .patterns import run_pattern  # noqa: F401
