"""MVE core: the paper's contribution as a composable module.

Layers:
  isa      — instruction set (Table II), stride modes, intrinsics
  machine  — cache geometry, control registers, lane flattening
  interp   — step executor (the semantic oracle; see docs/ISA.md)
  engine   — whole-program compiler + executor front-end (docs/ENGINE.md):
             mode "vm" (default) or "fused"
  vm       — program-as-data datapath: one XLA executable per signature,
             shared by every program with that signature
  cost     — BS/BP/BH/AC cycle models + controller/CB timeline
  rvv      — 1D long-vector baseline lowering (Figures 10/11/13)
  patterns — Section IV data-parallel patterns for 12 mobile libraries
             (built with the kernel frontend, :mod:`repro.frontend`)
  packing  — the MVE lane/masking abstraction reused by the LM framework

Kernels are authored one level up, in :mod:`repro.frontend`
(docs/FRONTEND.md): a tracing builder over named operands that lowers to
the ``isa.Program`` IR these modules execute.
"""
from . import (cost, engine, interp, isa, machine, packing,  # noqa: F401
               rvv, vm)
from .engine import (CompiledProgram, cache_info,  # noqa: F401
                     compile_program)
from .interp import MVEInterpreter  # noqa: F401
from .machine import MVEConfig  # noqa: F401

# ``patterns`` is imported lazily (PEP 562): it builds its programs with
# the kernel frontend (:mod:`repro.frontend`), which itself imports this
# package for the ISA — eager import here would be circular.
_LAZY = {"patterns", "run_pattern"}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        patterns = importlib.import_module(".patterns", __name__)
        return patterns if name == "patterns" else patterns.run_pattern
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _LAZY)
