"""MVE core: the paper's contribution as a composable module.

Layers:
  isa      — instruction set (Table II), stride modes, intrinsics
  machine  — cache geometry, control registers, lane flattening
  interp   — functional executor (the semantic oracle)
  cost     — BS/BP/BH/AC cycle models + controller/CB timeline
  rvv      — 1D long-vector baseline lowering (Figures 10/11/13)
  patterns — Section IV data-parallel patterns for 12 mobile libraries
  packing  — the MVE lane/masking abstraction reused by the LM framework
"""
from . import cost, interp, isa, machine, packing, patterns, rvv  # noqa: F401
from .interp import MVEInterpreter  # noqa: F401
from .machine import MVEConfig  # noqa: F401
