"""MVE core: the paper's contribution as a composable module.

Layers:
  isa      — instruction set (Table II), stride modes, intrinsics
  machine  — cache geometry, control registers, lane flattening
  interp   — step executor (the semantic oracle; see docs/ISA.md)
  engine   — whole-program compiler + fused jit/vmap executor
             (docs/ENGINE.md; the default execution path)
  cost     — BS/BP/BH/AC cycle models + controller/CB timeline
  rvv      — 1D long-vector baseline lowering (Figures 10/11/13)
  patterns — Section IV data-parallel patterns for 12 mobile libraries
  packing  — the MVE lane/masking abstraction reused by the LM framework
"""
from . import (cost, engine, interp, isa, machine, packing, patterns,  # noqa: F401
               rvv)
from .engine import CompiledProgram, compile_program  # noqa: F401
from .interp import MVEInterpreter  # noqa: F401
from .machine import MVEConfig  # noqa: F401
from .patterns import run_pattern  # noqa: F401
