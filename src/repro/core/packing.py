"""MDV lane packing — the MVE abstraction applied to framework layers.

The paper's central insight is that mobile kernels expose *limited 1D
parallelism* (average 635 elements, Section I), so a very wide SIMD engine
must be fed by flattening several loop dimensions onto the lane axis, with
*dimension-level* (not per-element) masking for irregularity.

This module reuses that insight at two places of the LM framework:

  * **Continuous-batching decode** (`LaneGrid`): decode exposes only
    ``batch`` parallelism per step — the analogue of a short 1D loop.  The
    grid packs (requests x speculative-draft positions / beams) onto a fixed
    lane axis and keeps one mask *bit per request* (the highest dimension),
    exactly like the paper's mask CR, instead of per-token predicates.

  * **Sequence packing** in the data pipeline (`pack_documents`): documents
    are the highest dimension; masking whole documents out of the loss is a
    dimension-level mask, while attention segmentation uses segment ids.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class LaneGrid:
    """Fixed-geometry lane grid with dimension-level masking.

    ``dims`` is (inner, ..., top) like an MVE logical register; the top
    dimension carries the mask (one bit per top element, capped the same
    way as the paper's 256-entry mask CR).
    """

    dims: Tuple[int, ...]
    max_top_mask: int = 256

    def __post_init__(self):
        if self.dims[-1] > self.max_top_mask:
            raise ValueError(
                f"top dimension {self.dims[-1]} exceeds mask capacity "
                f"{self.max_top_mask}")
        self._mask = np.zeros(self.dims[-1], dtype=bool)
        self._payload: List[Optional[object]] = [None] * self.dims[-1]

    @property
    def lanes(self) -> int:
        return int(np.prod(self.dims))

    @property
    def top(self) -> int:
        return self.dims[-1]

    @property
    def mask(self) -> np.ndarray:
        return self._mask.copy()

    def lane_mask(self) -> np.ndarray:
        """Expand the top-dim mask to a per-lane boolean of shape dims."""
        inner = int(np.prod(self.dims[:-1]))
        return np.repeat(self._mask, inner).reshape(
            tuple(reversed(self.dims)))

    def occupancy(self) -> float:
        return float(self._mask.mean())

    def allocate(self, payload: object) -> Optional[int]:
        """Claim a top-dim slot; returns its index or None when full."""
        free = np.nonzero(~self._mask)[0]
        if len(free) == 0:
            return None
        slot = int(free[0])
        self._mask[slot] = True
        self._payload[slot] = payload
        return slot

    def release(self, slot: int) -> object:
        if not self._mask[slot]:
            raise KeyError(f"slot {slot} is not allocated")
        self._mask[slot] = False
        payload, self._payload[slot] = self._payload[slot], None
        return payload

    def payload(self, slot: int):
        return self._payload[slot]

    def active_slots(self) -> np.ndarray:
        return np.nonzero(self._mask)[0]


def pack_documents(docs: Sequence[np.ndarray], seq_len: int,
                   pad_id: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy first-fit packing of documents into rows of ``seq_len``.

    Returns (tokens, segment_ids, positions); ``segment_ids == 0`` marks
    padding (the dimension-level "masked off" documents).  Documents longer
    than ``seq_len`` are split.
    """
    rows: List[List[np.ndarray]] = []
    room: List[int] = []
    pieces: List[np.ndarray] = []
    for d in docs:
        d = np.asarray(d)
        for s in range(0, len(d), seq_len):
            pieces.append(d[s:s + seq_len])
    for piece in pieces:
        placed = False
        for i in range(len(rows)):
            if room[i] >= len(piece):
                rows[i].append(piece)
                room[i] -= len(piece)
                placed = True
                break
        if not placed:
            rows.append([piece])
            room.append(seq_len - len(piece))

    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, dtype=np.int32)
    segment_ids = np.zeros((n, seq_len), dtype=np.int32)
    positions = np.zeros((n, seq_len), dtype=np.int32)
    for i, row in enumerate(rows):
        ofs = 0
        for j, piece in enumerate(row):
            k = len(piece)
            tokens[i, ofs:ofs + k] = piece
            segment_ids[i, ofs:ofs + k] = j + 1
            positions[i, ofs:ofs + k] = np.arange(k)
            ofs += k
    return tokens, segment_ids, positions
