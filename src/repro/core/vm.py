"""Program-as-data MVE virtual machine: one XLA executable per signature.

The fused engine (:mod:`repro.core.engine`) emits one ``jax.jit`` function
*per program*, so a data-dependent program stream — one spmm program per
sparsity pattern, one gemm per tile shape — retraces and recompiles XLA on
every variant; ``BENCH_engine.json`` recorded 3.59 s of compilation against
33 ms of execution for the 14-pattern sweep.  This module removes the
per-program compile by treating the program itself as *data*:

* the static step list produced by the engine's compile walk is lowered to
  dense tensors — an opcode/subcode table, packed register operands and
  immediates, flag bits, and compact deduplicated address-pattern / mask /
  scatter-index tables referenced by per-slot row indices;
* a single pre-jitted ``lax.while_loop`` (dynamic trip count: padded slots
  are never executed) steps over instruction slots, dispatching through
  ``lax.switch`` op-group handlers over a fixed ``(n_regs, lanes)``
  register file instead of a Python dict;
* the jitted executable is keyed only by a static *signature* —
  ``(lanes, n_regs, slot bucket, memory bucket, random bucket, pattern
  bucket, mask bucket, scatter bucket)`` — so every program with the same
  signature (all 14 patterns, every spmm sparsity variant, every seed)
  reuses one XLA compilation.

Bit-exactness discipline (the stepwise interpreter stays the oracle):

* JAX runs in its default 32-bit mode, so every architectural value fits
  32 bits.  The register file holds int32 *bit patterns*: integer values
  are stored sign-extended (wrapped to their declared width), floats are
  stored as their float32 bits (float16 extends exactly).  Per-slot flag
  bits record how each operand register is currently stored — that
  evolution is static, like everything else about MVE addressing.
* Integer ops compute in natively-wrapping int32 on operands wrapped to
  the instruction width, then re-wrap — exactly the eager per-dtype
  semantics.  Float ops compute on dtype-rounded operands, in f16 where
  the result rounds (add/sub/mul), so every instruction keeps its own
  rounding point; ``while_loop`` iterations are hard boundaries, which
  also makes the fused path's FP-contraction workaround unnecessary here.
* Memory stores use the layouts of :func:`repro.core.machine.store_layout`:
  contiguous stores become slice blends, everything else a collision-
  ordered ``mode="drop"`` scatter behind ``lax.cond`` (XLA:CPU scatter
  costs ~1 ms per 8K lanes; a skipped cond costs ~30 us).

The one datapath compile a process ever pays can also be cached across
processes via JAX's persistent compilation cache (:func:`enable_disk_cache`
— opt-in; ``benchmarks/engine_bench.py`` enables it for its section, or
set ``REPRO_MVE_XLA_CACHE=<dir>``), and :func:`prewarm` can overlap it
with program lowering on a background thread.

Design note with the full tensor encoding: docs/ENGINE.md ("VM lowering").
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import isa
from .isa import DType, Op
from .machine import MVEConfig, OOB_BASE, next_pow2

# -- signature buckets ------------------------------------------------------
N_REGS = 8           # dense register-file slots (virtual regs are remapped)
MIN_SLOTS = 128      # instruction-slot bucket floor
MIN_MEM = 131072     # memory bucket floor (elements)
MIN_PATTERNS = 32    # address-pattern table floor (rows)
MIN_MASKS = 16       # mask table floor (rows)

# -- flag columns (bool table) ----------------------------------------------
F_WRITES_REG, F_WRITES_TAG, F_BLEND, F_SCATTER, F_RAND, F_PRED, \
    F_A_ISF, F_B_ISF, F_OLD_ISF, F_F16, F_FLOAT, F_SETDUP, F_LOAD = range(13)
N_FLAGS = 13

# -- int columns (int32 table) ----------------------------------------------
I_OPC, I_VD, I_VS1, I_VS2, I_SUB, I_SBASE, I_IMM, I_AMT, I_BMA, I_MASK, \
    I_SIGN, I_LO, I_HI, I_SROW, I_AROW, I_ABASE, I_PROW, I_PBASE, \
    I_MROW = range(19)
N_INTS = 19

# -- opcodes (lax.switch branch indices) ------------------------------------
# Few, wide branches: XLA compile (and trace) time scales with the number
# of switch arms, so moves/shifts ride as ALU subcodes instead of arms.
(OPC_NOP, OPC_LOAD, OPC_STORE, OPC_INT, OPC_FLOAT, OPC_CMP) = range(6)

# subcodes
_INT_SUB = {Op.ADD: 0, Op.SUB: 1, Op.MUL: 2, Op.MIN: 3, Op.MAX: 4,
            Op.XOR: 5, Op.AND: 6, Op.OR: 7}
SUB_SHI, SUB_ROTI, SUB_SHR, SUB_MOVE_I = 8, 9, 10, 11
_FLT_SUB = {Op.ADD: 0, Op.SUB: 1, Op.MUL: 2, Op.MIN: 3, Op.MAX: 4}
SUB_MOVE_F = 5
_CMP_SUB = {Op.GT: 0, Op.GTE: 1, Op.LT: 2, Op.LTE: 3, Op.EQ: 4, Op.NEQ: 5}

# numpy views of the canonical int32 register file, per final dtype
_NP_DTYPE = {DType.B: np.uint8, DType.W: np.int16, DType.DW: np.int32,
             DType.QW: np.int32, DType.HF: np.float16, DType.F: np.float32}


class VMUnsupported(Exception):
    """Program cannot be lowered to the VM (e.g. too many live registers);
    :func:`repro.core.engine.compile_program` falls back to the fused path."""


# ---------------------------------------------------------------------------
# Executor-boundary fault hook (chaos engineering; repro.resilience).
# ---------------------------------------------------------------------------

# One process-wide hook shared by the VM and the fused engine (engine.py
# imports these — vm is the lower layer, so the registry lives here).  A
# :class:`repro.resilience.FaultInjector` installs its ``engine_hook`` to
# inject failures/latency at the real executor boundaries; ``None`` (the
# default) costs one attribute load per dispatch.
_FAULT_HOOK = None
_FAULT_HOOK_LOCK = threading.Lock()


def set_fault_hook(hook):
    """Install ``hook(site, **ctx)`` at the executor boundaries; returns
    the previous hook (restore it when done).  Sites fired here:
    ``vm.dispatch`` / ``vm.finalize``; :mod:`repro.core.engine` adds
    ``engine.compile`` / ``engine.dispatch`` / ``engine.finalize``."""
    global _FAULT_HOOK
    with _FAULT_HOOK_LOCK:
        prev, _FAULT_HOOK = _FAULT_HOOK, hook
    return prev


def fire_fault_hook(site: str, **ctx) -> None:
    hook = _FAULT_HOOK
    if hook is not None:
        hook(site, **ctx)


def enable_disk_cache(path: Optional[str] = None):
    """Opt into JAX's persistent compilation cache: the VM's "compile the
    machine once" then holds per *machine*, not per process.

    Opt-in, not default: jax 0.4.x's cache serialization aborts on some
    executables outside the VM's (observed with the training-step jits of
    this repo on XLA:CPU), so the process-global cache is only switched on
    for workloads that want it — ``benchmarks/engine_bench.py`` does, and
    setting ``REPRO_MVE_XLA_CACHE=<dir>`` enables it at import.  Returns
    the previous (cache_dir, min_compile_secs) pair for
    :func:`restore_disk_cache`; both config updates happen only after the
    cache directory exists, so a failure leaves the config untouched.
    """
    prev = (jax.config.jax_compilation_cache_dir,
            jax.config.jax_persistent_cache_min_compile_time_secs)
    path = path or os.environ.get("REPRO_MVE_XLA_CACHE") or \
        os.path.expanduser("~/.cache/repro_mve_xla")
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    return prev


def restore_disk_cache(prev) -> None:
    """Undo :func:`enable_disk_cache` with its returned value."""
    jax.config.update("jax_compilation_cache_dir", prev[0])
    jax.config.update("jax_persistent_cache_min_compile_time_secs", prev[1])


if os.environ.get("REPRO_MVE_XLA_CACHE"):      # explicit opt-in only
    try:
        enable_disk_cache()
    except Exception:                          # pragma: no cover - best effort
        pass


# ---------------------------------------------------------------------------
# AOT-capable jit wrapper (shared with the fused engine).
# ---------------------------------------------------------------------------

class AotJit:
    """``jax.jit`` plus explicit AOT warmup and a compile counter.

    ``jit_fn.lower(...).compile()`` does *not* populate the jit's internal
    dispatch cache in jax 0.4.x — calling the wrapped function afterwards
    would silently re-trace.  This wrapper keeps the AOT executable and
    routes calls with matching (shape, dtype) signatures to it, so
    :meth:`warmup` genuinely removes the first-call compile cliff.
    ``compiles`` counts distinct XLA compilations this wrapper triggered.
    """

    def __init__(self, fn, donate_argnums=()):
        self._jit = jax.jit(fn, donate_argnums=donate_argnums)
        self._aot = {}
        self._seen = set()
        self._lock = threading.Lock()
        self.compiles = 0
        self.calls = 0

    @staticmethod
    def _key(args):
        return tuple((tuple(a.shape), str(a.dtype)) for a in args)

    def __call__(self, *args):
        self.calls += 1
        key = self._key(args)
        compiled = self._aot.get(key)
        if compiled is not None:
            return compiled(*args)
        if key in self._seen:            # already compiled via the jit path
            return self._jit(*args)
        # First call for this key: the lock makes a call issued while a
        # background warmup (e.g. ``prewarm(block=False)``) is mid-compile
        # wait for that compile instead of racing a duplicate trace+compile.
        with self._lock:
            compiled = self._aot.get(key)
            if compiled is not None:
                return compiled(*args)
            out = self._jit(*args)
            if key not in self._seen:
                self._seen.add(key)
                self.compiles += 1
            return out

    def warmup(self, *args):
        """AOT-compile for the given (abstract or concrete) arguments."""
        key = self._key(args)
        with self._lock:
            if key not in self._aot:
                abstract = [jax.ShapeDtypeStruct(a.shape, a.dtype)
                            for a in args]
                self._aot[key] = self._jit.lower(*abstract).compile()
                if key not in self._seen:
                    self._seen.add(key)
                    self.compiles += 1
            return self._aot[key]


# ---------------------------------------------------------------------------
# Op-group handlers: 9 lax.switch branches over pre-cast operands.
# Operand reads/casts are hoisted into the loop body (they are shared by
# every group), keeping each branch small — XLA compile time of the switch
# scales with total branch HLO.
# ---------------------------------------------------------------------------

_F16, _F32, _I32, _U32 = jnp.float16, jnp.float32, jnp.int32, jnp.uint32


def _canon_f(x):
    return lax.bitcast_convert_type(x, _I32)


def _build_branches(lanes: int):
    def no_cmp():
        return jnp.zeros(lanes, dtype=bool)

    def wrap(v, iv):
        w = v & iv[I_MASK]
        return w - ((w & iv[I_SIGN]) << 1)

    def select8(sub, r):
        return jnp.where(
            sub < 4,
            jnp.where(sub < 2, jnp.where(sub == 0, r[0], r[1]),
                      jnp.where(sub == 2, r[2], r[3])),
            jnp.where(sub < 6, jnp.where(sub == 4, r[4], r[5]),
                      jnp.where(sub == 6, r[6], r[7])))

    def select5(sub, r):
        return jnp.where(sub < 2, jnp.where(sub == 0, r[0], r[1]),
                         jnp.where(sub == 2, r[2],
                                   jnp.where(sub == 3, r[3], r[4])))

    def cmp_select(sub, gt, lt, eq):
        return jnp.where(sub < 2, jnp.where(sub == 0, gt, gt | eq),
                         jnp.where(sub < 4, jnp.where(sub == 2, lt, lt | eq),
                                   jnp.where(sub == 4, eq, ~eq)))

    def out_row(fl, keep, ri, rf, old_i, old_f):
        """Write-back row: result under ``keep``, else the old value cast
        to the instruction dtype (mirrors the eager ``finish``)."""
        oi = jnp.where(keep, ri, old_i)
        of = _canon_f(jnp.where(keep, rf, old_f))
        return jnp.where(fl[F_FLOAT], of, oi)

    def h_nop(a_i, b_i, old_i, a_f, b_f, old_f, loaded, keep, fl, iv, fimm):
        return old_i, no_cmp()

    def h_load(a_i, b_i, old_i, a_f, b_f, old_f, loaded, keep, fl, iv, fimm):
        # Clamp to the dtype range before the int conversion: the eager
        # executors' direct f32->narrow astype saturates (XLA converts
        # saturate), and the clamp reproduces that bit for bit.
        clamped = jnp.clip(loaded, iv[I_LO].astype(_F32),
                           iv[I_HI].astype(_F32))
        gi = wrap(clamped.astype(_I32), iv)
        gf = jnp.where(fl[F_F16], loaded.astype(_F16).astype(_F32), loaded)
        return out_row(fl, keep, gi, gf, old_i, old_f), no_cmp()

    def h_store(a_i, b_i, old_i, a_f, b_f, old_f, loaded, keep, fl, iv,
                fimm):
        # Source lane values as memory words (f32), canonicalized so the
        # loop body can bitcast them back for the blend/scatter.
        return _canon_f(jnp.where(fl[F_FLOAT], a_f, a_i.astype(_F32))), \
            no_cmp()

    def h_int(a_i, b_i, old_i, a_f, b_f, old_f, loaded, keep, fl, iv, fimm):
        sub = iv[I_SUB]
        binop = select8(sub, [
            a_i + b_i, a_i - b_i, a_i * b_i, jnp.minimum(a_i, b_i),
            jnp.maximum(a_i, b_i), a_i ^ b_i, a_i & b_i, a_i | b_i])
        amt, bma = iv[I_AMT], iv[I_BMA]
        r_shi = (a_i << amt) >> bma           # one of amt/bma is zero
        ua = lax.bitcast_convert_type(a_i, _U32)
        r_rot = lax.bitcast_convert_type(
            (ua << amt.astype(_U32)) | (ua >> bma.astype(_U32)), _I32)
        r_shr = a_i << b_i                    # vshr: shift by register
        mv = jnp.where(fl[F_SETDUP], iv[I_IMM], a_i)   # vsetdup/vcpy/vcvt
        hi = jnp.where(sub == SUB_SHI, r_shi,
                       jnp.where(sub == SUB_ROTI, r_rot,
                                 jnp.where(sub == SUB_SHR, r_shr, mv)))
        r = jnp.where(sub < 8, binop, hi)
        return out_row(fl, keep, wrap(r, iv), a_f, old_i, old_f), no_cmp()

    def h_float(a_i, b_i, old_i, a_f, b_f, old_f, loaded, keep, fl, iv,
                fimm):
        # Operands are already rounded to the instruction dtype; min/max
        # pick an operand (no rounding), add/sub/mul must round in f16.
        a16, b16 = a_f.astype(_F16), b_f.astype(_F16)
        f16 = fl[F_F16]

        def rounded(f32_r, f16_r):
            return jnp.where(f16, f16_r.astype(_F32), f32_r)

        sub = iv[I_SUB]
        mvf = jnp.where(fl[F_SETDUP], fimm, a_f)       # vsetdup/vcpy/vcvt
        r = select5(sub, [
            rounded(a_f + b_f, a16 + b16),
            rounded(a_f - b_f, a16 - b16),
            rounded(a_f * b_f, a16 * b16),
            jnp.minimum(a_f, b_f), jnp.maximum(a_f, b_f)])
        r = jnp.where(sub == SUB_MOVE_F, mvf, r)
        return out_row(fl, keep, a_i, r, old_i, old_f), no_cmp()

    def h_cmp(a_i, b_i, old_i, a_f, b_f, old_f, loaded, keep, fl, iv,
              fimm):
        # dtype-rounded float operands compare identically in f32
        # (exact subset), so one branch serves every compare dtype.
        isf = fl[F_FLOAT]
        gt = jnp.where(isf, a_f > b_f, a_i > b_i)
        lt = jnp.where(isf, a_f < b_f, a_i < b_i)
        eq = jnp.where(isf, a_f == b_f, a_i == b_i)
        return old_i, cmp_select(iv[I_SUB], gt, lt, eq)

    return [h_nop, h_load, h_store, h_int, h_float, h_cmp]


# ---------------------------------------------------------------------------
# The signature-keyed executable.
# ---------------------------------------------------------------------------

def _make_execute(lanes: int, n_regs: int, slots: int):
    branches = _build_branches(lanes)

    def execute(memory, mem_hi, n_steps, ints, flags, fimm,
                pat_t, mask_t, scat_t, perm_t):
        regfile = jnp.zeros((n_regs, lanes), dtype=jnp.int32)
        tag = jnp.ones(lanes, dtype=bool)
        addrs_out = jnp.zeros((slots, lanes), dtype=jnp.int32)

        def read_operand(bits, isf, iv):
            """Canonical bits -> (wrapped int value, f32 numeric value).

            Float-stored registers read as integers clamp to the
            instruction dtype's range first: the eager executors cast with
            a direct (saturating) XLA convert, and clamp-then-convert
            reproduces that exactly for narrow dtypes."""
            as_f = lax.bitcast_convert_type(bits, _F32)
            f32 = jnp.where(isf, as_f, bits.astype(_F32))
            clamped = jnp.clip(as_f, iv[I_LO].astype(_F32),
                               iv[I_HI].astype(_F32))
            i_raw = jnp.where(isf, clamped.astype(_I32), bits)
            w = i_raw & iv[I_MASK]
            return w - ((w & iv[I_SIGN]) << 1), f32

        def body(carry):
            i, memory, regfile, tag, addrs_out = carry
            iv = ints[i]
            fl = flags[i]
            pat_row = lax.dynamic_index_in_dim(pat_t, iv[I_AROW],
                                               keepdims=False)
            addr_static = pat_row + iv[I_ABASE]
            mask_row = lax.dynamic_index_in_dim(mask_t, iv[I_MROW],
                                                keepdims=False)

            def rand_addr(_):
                ptr_pat = lax.dynamic_index_in_dim(pat_t, iv[I_PROW],
                                                   keepdims=False)
                ptr_idx = jnp.clip(ptr_pat + iv[I_PBASE], 0, mem_hi)
                return memory[ptr_idx].astype(jnp.int32) + addr_static

            addr = lax.cond(fl[F_RAND], rand_addr,
                            lambda _: addr_static, None)
            loaded = lax.cond(
                fl[F_LOAD],
                lambda _: memory[jnp.clip(addr, 0, mem_hi)],
                lambda _: jnp.zeros(lanes, dtype=memory.dtype), None)

            a_i, a_f32 = read_operand(regfile[iv[I_VS1]], fl[F_A_ISF], iv)
            b_i, b_f32 = read_operand(regfile[iv[I_VS2]], fl[F_B_ISF], iv)
            old_raw = regfile[iv[I_VD]]
            old_i, old_f32 = read_operand(old_raw, fl[F_OLD_ISF], iv)
            f16 = fl[F_F16]
            a_f = jnp.where(f16, a_f32.astype(_F16).astype(_F32), a_f32)
            b_f = jnp.where(f16, b_f32.astype(_F16).astype(_F32), b_f32)
            old_f = jnp.where(f16, old_f32.astype(_F16).astype(_F32),
                              old_f32)
            keep = mask_row & jnp.where(fl[F_PRED], tag, True)

            row, cmp = lax.switch(iv[I_OPC], branches, a_i, b_i, old_i,
                                  a_f, b_f, old_f, loaded, keep, fl, iv,
                                  fimm[i])

            regfile = regfile.at[iv[I_VD]].set(
                jnp.where(fl[F_WRITES_REG], row, old_raw))
            tag = jnp.where(fl[F_WRITES_TAG] & mask_row, cmp, tag)

            def blend(mem):
                base = iv[I_SBASE]
                window = lax.dynamic_slice(mem, (base,), (lanes,))
                src = lax.bitcast_convert_type(row, jnp.float32)
                return lax.dynamic_update_slice(
                    mem, jnp.where(mask_row, src, window), (base,))

            def scatter(mem):
                sidx = lax.dynamic_index_in_dim(scat_t, iv[I_SROW],
                                                keepdims=False)
                prow = lax.dynamic_index_in_dim(perm_t, iv[I_SROW],
                                                keepdims=False)
                idx = jnp.where(fl[F_RAND],
                                jnp.where(mask_row, addr, -1), sidx)
                src = lax.bitcast_convert_type(row, jnp.float32)[prow]
                return mem.at[idx].set(src, mode="drop")

            memory = lax.cond(fl[F_BLEND], blend, lambda m: m, memory)
            memory = lax.cond(fl[F_SCATTER], scatter, lambda m: m, memory)
            addrs_out = lax.cond(
                fl[F_RAND],
                lambda ao: lax.dynamic_update_slice(ao, addr[None], (i, 0)),
                lambda ao: ao, addrs_out)
            return i + 1, memory, regfile, tag, addrs_out

        _, memory, regfile, tag, addrs_out = lax.while_loop(
            lambda c: c[0] < n_steps, body,
            (jnp.int32(0), memory, regfile, tag, addrs_out))
        return memory, regfile, tag, addrs_out

    return execute


class _Executor:
    """One compiled VM datapath (single-image jit + vmapped batch jit)."""

    def __init__(self, sig: Tuple[int, ...]):
        self.sig = sig
        lanes, n_regs, slots = sig[0], sig[1], sig[2]
        fn = _make_execute(lanes, n_regs, slots)
        self.single = AotJit(fn, donate_argnums=(0,))
        self.batch = AotJit(jax.vmap(fn, in_axes=(0,) + (None,) * 9),
                            donate_argnums=(0,))

    def table_structs(self):
        """Abstract (shape, dtype) of the table operands for this sig."""
        lanes, _, slots = self.sig[0], self.sig[1], self.sig[2]
        pat, msk, scat = self.sig[5], self.sig[6], self.sig[7]
        sds = jax.ShapeDtypeStruct
        return (sds((slots, N_INTS), jnp.int32),
                sds((slots, N_FLAGS), jnp.bool_),
                sds((slots,), jnp.float32),
                sds((pat, lanes), jnp.int32),
                sds((msk, lanes), jnp.bool_),
                sds((scat, lanes), jnp.int32),
                sds((scat, lanes), jnp.int32))


_EXECUTORS: Dict[Tuple[int, ...], _Executor] = {}
_EXECUTORS_LOCK = threading.Lock()
_HITS = 0


def _executor(sig: Tuple[int, ...]) -> _Executor:
    global _HITS
    with _EXECUTORS_LOCK:
        ex = _EXECUTORS.get(sig)
        if ex is None:
            ex = _EXECUTORS[sig] = _Executor(sig)
        else:
            _HITS += 1
    return ex


def default_signature(cfg: MVEConfig | None = None,
                      mem_size: int = MIN_MEM) -> Tuple[int, ...]:
    """The signature every bucket-floor program maps to — all 14 Section-IV
    patterns and their data-dependent variants share this one executable."""
    cfg = cfg or MVEConfig()
    bucket = next_pow2(max(mem_size, MIN_MEM))
    return (cfg.lanes, N_REGS, MIN_SLOTS, bucket, MIN_SLOTS, MIN_PATTERNS,
            MIN_MASKS, 1)


def prewarm(cfg: MVEConfig | None = None, mem_size: int = MIN_MEM,
            block: bool = True) -> Optional[threading.Thread]:
    """AOT-compile (or load from the persistent cache) the default-
    signature datapath.  With ``block=False`` the compile runs on a daemon
    thread so callers can lower programs concurrently; join the returned
    thread (or just call :meth:`VMProgram.run`) before timing executions.
    """
    sig = default_signature(cfg, mem_size)

    def _warm():
        ex = _executor(sig)
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        mem = jax.ShapeDtypeStruct((sig[3] + sig[0],), jnp.float32)
        ex.single.warmup(mem, scalar, scalar, *ex.table_structs())

    if block:
        _warm()
        return None
    t = threading.Thread(target=_warm, daemon=True, name="mve-vm-prewarm")
    t.start()
    return t


def clear_executors() -> None:
    """Drop all signature-keyed executables (tests / cold-start measures).
    The on-disk XLA cache (when enabled) is unaffected."""
    global _HITS
    with _EXECUTORS_LOCK:
        _EXECUTORS.clear()
        _HITS = 0


@dataclasses.dataclass(frozen=True)
class VMCacheInfo:
    signatures: int          # distinct executors alive
    hits: int                # executor-cache hits
    xla_compiles: int        # distinct XLA compilations (incl. batch/AOT)


def cache_info() -> VMCacheInfo:
    compiles = sum(ex.single.compiles + ex.batch.compiles
                   for ex in _EXECUTORS.values())
    return VMCacheInfo(signatures=len(_EXECUTORS), hits=_HITS,
                       xla_compiles=compiles)


# ---------------------------------------------------------------------------
# Lowering: engine steps -> dense tensors.
# ---------------------------------------------------------------------------

_I32_MIN, _I32_MAX = -(1 << 31), (1 << 31) - 1


def _dtype_fields(dt: DType) -> Tuple[int, int, int, int, bool, bool]:
    """(wrap_mask, sign_bit, clamp_lo, clamp_hi, is_float, is_f16) for the
    32-bit datapath.  QW runs as a 32-bit integer — identical to the eager
    paths, which also canonicalize int64 to int32 under JAX's default
    32-bit mode.  clamp_lo/hi bound float->int reads so they saturate like
    the eager executors' direct converts (for 32-bit targets the f32->i32
    convert saturates natively, so the bounds are the i32 extremes)."""
    if dt.is_float:
        return -1, 0, _I32_MIN, _I32_MAX, True, dt is DType.HF
    bits = min(dt.bits, 32)
    if bits >= 32:
        return -1, 0, _I32_MIN, _I32_MAX, False, False
    mask = (1 << bits) - 1
    if dt is DType.B:
        return mask, 0, 0, mask, False, False
    sign = 1 << (bits - 1)
    return mask, sign, -sign, sign - 1, False, False


def _wrap_host(value: int, mask: int, sign: int) -> int:
    if mask == -1:                   # full 32-bit register
        v = int(value) & 0xFFFFFFFF
        return v - (1 << 32) if v >= (1 << 31) else v
    v = int(value) & mask
    if sign and v & sign:
        v -= sign << 1
    return v


class _RowInterner:
    """Deduplicate (lanes,) rows; returns stable row indices."""

    def __init__(self, first_row: np.ndarray):
        self.rows = [first_row]
        self._index = {first_row.tobytes(): 0}

    def add(self, row: np.ndarray) -> int:
        key = row.tobytes()
        idx = self._index.get(key)
        if idx is None:
            idx = self._index[key] = len(self.rows)
            self.rows.append(row)
        return idx


class VMProgram:
    """A program lowered to VM tensors; executes via the signature cache.

    Built by :class:`repro.core.engine.CompiledProgram` in ``mode="vm"``;
    raises :class:`VMUnsupported` when the program does not fit the fixed
    datapath (more than ``N_REGS`` live registers).
    """

    def __init__(self, steps, cfg: MVEConfig, n_random: int):
        self.cfg = cfg
        self.n_random = n_random
        lanes = cfg.lanes
        self._lower(steps, lanes)
        self.slots_bucket = next_pow2(max(self.n_steps, MIN_SLOTS))
        self._pad_tables(lanes)

    # -- lowering ----------------------------------------------------------
    def _lower(self, steps, lanes: int) -> None:
        regmap: Dict[int, int] = {}
        stored_float: Dict[int, bool] = {}
        final_dtype: Dict[int, DType] = {}

        def slot_of(vreg: Optional[int]) -> int:
            if vreg is None:
                return 0
            if vreg not in regmap:
                if len(regmap) >= N_REGS:
                    raise VMUnsupported(
                        f"program uses more than {N_REGS} live registers")
                regmap[vreg] = len(regmap)
            return regmap[vreg]

        ints: List[np.ndarray] = []
        flags: List[np.ndarray] = []
        fimm: List[float] = []
        patterns = _RowInterner(np.zeros(lanes, dtype=np.int32))
        masks = _RowInterner(np.zeros(lanes, dtype=bool))
        self._scat_rows: List[np.ndarray] = []
        self._perm_rows: List[np.ndarray] = []
        self.rand_slot_to_step: List[int] = [0] * self.n_random
        self.max_blend_base = 0

        for step in steps:
            instr = step.instr
            op = instr.op
            if op in isa.CONFIG_OPS or op is Op.SCALAR:
                continue                       # pure no-ops in the datapath

            iv = np.zeros(N_INTS, dtype=np.int64)
            fl = np.zeros(N_FLAGS, dtype=bool)
            fv = 0.0
            dt = instr.dtype
            mask, sign, lo, hi, is_f, is_f16 = _dtype_fields(dt)
            iv[I_MASK], iv[I_SIGN] = mask, sign
            iv[I_LO], iv[I_HI] = lo, hi
            fl[F_FLOAT], fl[F_F16] = is_f, is_f16
            # The eager executors honor the Tag latch only on compute
            # write-backs (their ``finish``); memory ops use the lane mask
            # alone — mirror that exactly.
            fl[F_PRED] = instr.predicated and op not in isa.MEMORY_OPS
            iv[I_MROW] = masks.add(step.lane_mask)

            def src(vreg, col, fl=fl):
                s = slot_of(vreg)
                fl[col] = stored_float.get(s, False)
                return s

            def dst(vreg, fl=fl, iv=iv):
                s = slot_of(vreg)
                iv[I_VD] = s
                fl[F_OLD_ISF] = stored_float.get(s, False)
                fl[F_WRITES_REG] = True
                return s

            def wrote(vreg, slot, is_float=is_f, dt=dt):
                stored_float[slot] = is_float
                final_dtype[vreg] = dt

            def static_addr(iv=iv, step=step):
                base = int(step.instr.base)
                iv[I_ABASE] = base
                iv[I_AROW] = patterns.add(
                    (step.addr - base).astype(np.int32))

            def rand_addr(iv=iv, fl=fl, step=step, at=len(ints)):
                fl[F_RAND] = True
                iv[I_AROW] = patterns.add(step.offsets.astype(np.int32))
                iv[I_PBASE] = int(step.ptr_base)
                iv[I_PROW] = patterns.add(step.top_idx.astype(np.int32))
                self.rand_slot_to_step[step.rand_slot] = at

            if op in (Op.SLD, Op.RLD):
                iv[I_OPC] = OPC_LOAD
                fl[F_LOAD] = True
                s = dst(instr.vd)
                if step.rand_slot is not None:
                    rand_addr()
                else:
                    static_addr()
                wrote(instr.vd, s)
            elif op in (Op.SST, Op.RST):
                iv[I_OPC] = OPC_STORE
                iv[I_VS1] = src(instr.vs1, F_A_ISF)
                if step.rand_slot is not None:
                    fl[F_SCATTER] = True
                    rand_addr()
                else:
                    layout = step.store_layout
                    if layout[0] == "contig":
                        fl[F_BLEND] = True
                        iv[I_SBASE] = layout[1]
                        self.max_blend_base = max(self.max_blend_base,
                                                  layout[1])
                    elif layout[0] == "scatter":
                        fl[F_SCATTER] = True
                        iv[I_SROW] = len(self._scat_rows) + 1  # row 0 shared
                        self._scat_rows.append(layout[1])
                        self._perm_rows.append(layout[2])
                    else:                      # fully masked store: no-op
                        continue
            elif op is Op.SET_DUP:
                iv[I_OPC] = OPC_FLOAT if is_f else OPC_INT
                iv[I_SUB] = SUB_MOVE_F if is_f else SUB_MOVE_I
                fl[F_SETDUP] = True
                s = dst(instr.vd)
                if is_f:
                    fv = float(np.float32(np.float16(instr.imm))) if is_f16 \
                        else float(np.float32(instr.imm))
                else:
                    iv[I_IMM] = _wrap_host(int(instr.imm), mask, sign)
                wrote(instr.vd, s)
            elif op in (Op.CPY, Op.CVT):
                iv[I_OPC] = OPC_FLOAT if is_f else OPC_INT
                iv[I_SUB] = SUB_MOVE_F if is_f else SUB_MOVE_I
                iv[I_VS1] = src(instr.vs1, F_A_ISF)
                s = dst(instr.vd)
                wrote(instr.vd, s)
            elif op in isa.COMPARE_OPS:
                iv[I_OPC] = OPC_CMP
                fl[F_WRITES_TAG] = True
                iv[I_SUB] = _CMP_SUB[op]
                iv[I_VS1] = src(instr.vs1, F_A_ISF)
                iv[I_VS2] = src(instr.vs2, F_B_ISF)
            elif op in (Op.SHI, Op.ROTI, Op.SHR):
                if is_f:
                    raise ValueError("shift on float register")
                iv[I_OPC] = OPC_INT
                iv[I_SUB] = {Op.SHI: SUB_SHI, Op.ROTI: SUB_ROTI,
                             Op.SHR: SUB_SHR}[op]
                if op is Op.SHI:
                    iv[I_AMT] = max(instr.imm, 0)
                    iv[I_BMA] = max(-instr.imm, 0)
                elif op is Op.ROTI:
                    # Mirror the eager expression exactly: amt = imm % bits
                    # with the *declared* width; the u32 datapath then
                    # matches the eager u32-canonicalized rotate for every
                    # in-range amount.
                    amt = instr.imm % dt.bits
                    iv[I_AMT], iv[I_BMA] = amt, dt.bits - amt
                iv[I_VS1] = src(instr.vs1, F_A_ISF)
                if instr.vs2 is not None:
                    iv[I_VS2] = src(instr.vs2, F_B_ISF)
                s = dst(instr.vd)
                wrote(instr.vd, s, is_float=False)
            else:
                table = _FLT_SUB if is_f else _INT_SUB
                if op not in table:
                    raise ValueError(f"op {op} on dtype {dt}")
                iv[I_OPC] = OPC_FLOAT if is_f else OPC_INT
                iv[I_SUB] = table[op]
                iv[I_VS1] = src(instr.vs1, F_A_ISF)
                iv[I_VS2] = src(instr.vs2, F_B_ISF)
                s = dst(instr.vd)
                wrote(instr.vd, s)

            ints.append(iv)
            flags.append(fl)
            fimm.append(fv)

        self.n_steps = len(ints)
        self._ints = ints
        self._flags = flags
        self._fimm = fimm
        self._patterns = patterns
        self._masks = masks
        self.final_dtype = final_dtype
        self.regmap = regmap

    def _pad_tables(self, lanes: int) -> None:
        slots = self.slots_bucket
        self.pat_bucket = next_pow2(max(len(self._patterns.rows),
                                        MIN_PATTERNS))
        self.mask_bucket = next_pow2(max(len(self._masks.rows), MIN_MASKS))
        self.scat_bucket = next_pow2(len(self._scat_rows) + 1)  # + row 0
        ints = np.zeros((slots, N_INTS), dtype=np.int32)
        flags = np.zeros((slots, N_FLAGS), dtype=bool)
        fimm = np.zeros(slots, dtype=np.float32)
        pat_t = np.zeros((self.pat_bucket, lanes), dtype=np.int32)
        mask_t = np.zeros((self.mask_bucket, lanes), dtype=bool)
        n = self.n_steps
        if n:
            ints[:n] = np.stack(self._ints).astype(np.int32)
            flags[:n] = np.stack(self._flags)
            fimm[:n] = np.asarray(self._fimm, dtype=np.float32)
        pat_t[:len(self._patterns.rows)] = np.stack(self._patterns.rows)
        mask_t[:len(self._masks.rows)] = np.stack(self._masks.rows)
        if self._scat_rows:
            scat = np.zeros((self.scat_bucket, lanes), dtype=np.int64)
            perm = np.tile(np.arange(lanes, dtype=np.int32),
                           (self.scat_bucket, 1))
            scat[0] = OOB_BASE + np.arange(lanes, dtype=np.int64)
            for i, row in enumerate(self._scat_rows):
                scat[i + 1] = row
            for i, row in enumerate(self._perm_rows):
                perm[i + 1] = row
            scat_t = jnp.asarray(np.minimum(
                scat, np.iinfo(np.int32).max).astype(np.int32))
            perm_t = jnp.asarray(perm)
        else:
            scat_t = _empty_scat_table(lanes)
            perm_t = _identity_perm_table(lanes)
        self.tables = (jnp.asarray(ints), jnp.asarray(flags),
                       jnp.asarray(fimm), jnp.asarray(pat_t),
                       jnp.asarray(mask_t), scat_t, perm_t)
        # Observability: live row counts of the deduplicated tables
        # (before bucket padding).  The optimizer's IR-level CSE shrinks
        # the *instruction stream*; these counters let benchmarks and
        # tests show how that composes with the VM's own row interning
        # (``benchmarks/opt_bench.py``).
        self.table_rows = {
            "steps": self.n_steps,
            "patterns": len(self._patterns.rows),
            "masks": len(self._masks.rows),
            "scatters": len(self._scat_rows),
        }
        del (self._ints, self._flags, self._fimm, self._patterns,
             self._masks, self._scat_rows, self._perm_rows)

    # -- execution ---------------------------------------------------------
    def _signature(self, mem_size: int) -> Tuple[int, ...]:
        bucket = next_pow2(max(mem_size, MIN_MEM, self.max_blend_base + 1))
        # Random-access bucket: one address row per slot, so programs with
        # and without random ops share one executable (docs/ENGINE.md).
        return (self.cfg.lanes, N_REGS, self.slots_bucket, bucket,
                self.slots_bucket, self.pat_bucket, self.mask_bucket,
                self.scat_bucket)

    def _pad_memory(self, memory, bucket: int) -> np.ndarray:
        mem = np.asarray(memory)
        buf = np.zeros(mem.shape[:-1] + (bucket + self.cfg.lanes,),
                       dtype=np.float32)
        buf[..., : mem.shape[-1]] = mem
        return buf

    def _args(self, mem_size: int):
        return (jnp.int32(mem_size - 1), jnp.int32(self.n_steps))

    def run_async(self, memory):
        """Dispatch one memory image without waiting: returns an opaque
        pending handle whose device buffers are still being computed.
        Pass it to :meth:`finalize` to materialize host results — the
        split lets a serving loop (:mod:`repro.runtime.scheduler`) enqueue
        many executions back to back and pay one sync, instead of a
        host round trip per request."""
        fire_fault_hook("vm.dispatch", tier="vm")
        mem_size = np.asarray(memory).shape[0]
        sig = self._signature(mem_size)
        ex = _executor(sig)
        # copy=True: the executable donates (and therefore writes through)
        # this buffer — it must be jax-owned, not a zero-copy alias of the
        # short-lived numpy padding buffer.
        buf = jnp.array(self._pad_memory(memory, sig[3]), copy=True)
        out = ex.single(buf, *self._args(mem_size), *self.tables)
        return (mem_size, out)

    def finalize(self, pending):
        """Host results of a :meth:`run_async` dispatch (blocks on it).

        Memory and registers come back as host (numpy) views of the fixed-
        shape device outputs: slicing/casting them on device would compile
        one trivial XLA executable per distinct program geometry, defeating
        the signature sharing.
        """
        fire_fault_hook("vm.finalize", tier="vm")
        mem_size, (mem, regfile, tag, addrs) = pending
        return (np.array(np.asarray(mem)[:mem_size]), self._regs(regfile),
                tag, self._rand_addrs(addrs))

    def run(self, memory):
        """Execute one memory image; returns ``(mem, regs, tag, rand)``
        with ``rand`` the per-random-op address vectors for the trace."""
        return self.finalize(self.run_async(memory))

    def run_batch_async(self, memories):
        """Batched :meth:`run_async`: one vmapped dispatch over a leading
        batch of memory images; finalize with :meth:`finalize_batch`."""
        fire_fault_hook("vm.dispatch", tier="vm")
        mems = np.asarray(memories)
        mem_size = mems.shape[-1]
        sig = self._signature(mem_size)
        ex = _executor(sig)
        buf = jnp.array(self._pad_memory(mems, sig[3]), copy=True)
        out = ex.batch(buf, *self._args(mem_size), *self.tables)
        return (mem_size, out)

    def finalize_batch(self, pending):
        fire_fault_hook("vm.finalize", tier="vm")
        mem_size, (mem, regfile, tag, _) = pending
        return (np.array(np.asarray(mem)[..., :mem_size]),
                self._regs(regfile, batched=True), tag)

    def run_batch(self, memories):
        return self.finalize_batch(self.run_batch_async(memories))

    def warmup(self, mem_size: int, batch: Optional[int] = None) -> None:
        sig = self._signature(mem_size)
        ex = _executor(sig)
        padded = sig[3] + self.cfg.lanes
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        if batch is None:
            m = jax.ShapeDtypeStruct((padded,), jnp.float32)
            ex.single.warmup(m, scalar, scalar, *self.tables)
        else:
            m = jax.ShapeDtypeStruct((batch, padded), jnp.float32)
            ex.batch.warmup(m, scalar, scalar, *self.tables)

    # -- result reconstruction ---------------------------------------------
    def _regs(self, regfile, batched: bool = False):
        """Typed register values, reconstructed host-side in numpy (no
        per-program XLA dispatches; values are bit-identical to the eager
        executors' typed arrays)."""
        rf = np.array(regfile)           # owned copy, not a device view
        regs = {}
        for vreg, s in self.regmap.items():
            dt = self.final_dtype.get(vreg)
            if dt is None:
                continue
            row = np.ascontiguousarray(rf[:, s] if batched else rf[s])
            if dt.is_float:
                val = row.view(np.float32)
                if dt is DType.HF:
                    val = val.astype(np.float16)
            else:
                val = row.astype(_NP_DTYPE[dt])
            regs[vreg] = val
        return regs

    def _rand_addrs(self, addrs_out):
        if not self.n_random:
            return []
        addrs = np.asarray(addrs_out)
        return [addrs[self.rand_slot_to_step[r]].astype(np.int64)
                for r in range(self.n_random)]


@functools.lru_cache(maxsize=16)
def _empty_scat_table(lanes: int):
    row = np.minimum(OOB_BASE + np.arange(lanes, dtype=np.int64),
                     np.iinfo(np.int32).max).astype(np.int32)
    return jnp.asarray(row[None, :])


@functools.lru_cache(maxsize=16)
def _identity_perm_table(lanes: int):
    return jnp.asarray(np.arange(lanes, dtype=np.int32)[None, :])
