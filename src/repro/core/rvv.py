"""RISC-V RVV-style 1D long-vector baseline.

The paper's key ISA comparison (Sections VII-B, Figures 10/11/13) runs the
*same* bit-serial in-cache engine but drives it with a one-dimensional
vector ISA: every multi-dimensional access must be decomposed into

    #segments = ceil(active_lanes / len(inner 1D segment))

partial 1D strided accesses, each needing a mask/config instruction, the
partial access itself, and a move to pack the segment into the long vector
register — plus scalar address-generation instructions (Section III-C:
"RVV would employ 6 strided load instructions ... further scalar
instructions are needed to compute the mask").

This module *compiles* the MVE memory instructions of a program into that
1D form, producing a trace that runs through the same cost model.  Results
remain bit-exact with MVE (it is the same access, sliced) — a first-class
invariant asserted across executors in ``tests/test_conformance.py`` and
``tests/test_targets.py`` — while the dynamic instruction counts and
timeline differ.

This lowering is the performance adapter behind the ``rvv-1d`` target of
:mod:`repro.targets` (docs/TARGETS.md): execution goes through the shared
functional engine, and :func:`compile_to_rvv` prices the same program as
a 1D ISA would issue it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from . import isa
from .isa import DType, Instr, Op
from .cost import TraceEvent
from .machine import (ControlState, MVEConfig, apply_config, cbs_touched,
                      lane_dim_mask)


@dataclasses.dataclass
class RVVStats:
    vector_instructions: int = 0
    mask_instructions: int = 0
    move_instructions: int = 0
    memory_instructions: int = 0
    scalar_instructions: int = 0
    config_instructions: int = 0
    # One entry per lowered memory instruction:
    # ``(segments, inner_len, active_lanes)`` — the Section III-C
    # decomposition ``segments = ceil(active_lanes / inner_len)`` (times
    # the pointer count for random-base accesses).  Tested as an exact
    # invariant against the emitted trace in ``tests/test_conformance.py``.
    segment_log: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)


def segments_for(ctrl: ControlState, instr: Instr, lanes: int
                 ) -> Tuple[int, int]:
    """(#partial accesses, 1D segment length) for one memory instruction.

    RVV has ONE flexible stride per access (Table I), so a competent 1D
    implementation picks the best vectorization axis:

      * a dense (mode-2) multi-dim access collapses to flat 1D loads;
      * any single strided dimension is loadable in one instruction
        (this is the paper's ``#lanes / len(1D segment)`` count, e.g.
        8192/3136 ~ 3 for the MobileNet GEMM);
      * short contiguous runs under a stride map to segment loads
        (vlsseg, <= 8 fields);
      * stride-0 replication and deeper stride levels must be unpacked
        segment by segment (mask + partial access + move each).
    """
    dims = ctrl.active_dims()
    store = instr.op in (Op.SST, Op.RST)
    random = instr.op in (Op.RLD, Op.RST)
    strides = ctrl.resolve_strides(instr.modes or (), store)
    use = list(zip(dims, strides))
    if random:
        use = use[:-1]                     # top dim is the random base set
    nz = sorted((s, ln) for ln, s in use if s != 0)
    run = 1
    for s, ln in nz:
        if s == run:
            run *= ln
        else:
            break
    best = run
    for s, ln in nz:
        if s != 0 and s > run - 1 and ln > 1 and s != 1:
            # one strided dim, possibly carrying a short dense chain
            best = max(best, ln * (run if run <= 8 else 1))
    seg_len = max(best, 1)
    inner_total = min(int(np.prod([ln for ln, _ in use])) if use else 1,
                      lanes)
    per_base = max(1, -(-inner_total // seg_len))
    tops = dims[-1] if random else 1
    return per_base * tops, min(seg_len, inner_total)


def compile_to_rvv(program: isa.Program, cfg: MVEConfig | None = None
                   ) -> Tuple[List[TraceEvent], RVVStats]:
    """Lower an MVE program to a 1D-ISA trace on the same engine.

    Non-memory vector ops translate 1:1 (the engine width is the same); the
    multi-dimensional loads/stores and the dimension-level mask ops expand
    as described above.
    """
    cfg = cfg or MVEConfig()
    ctrl = ControlState()
    trace: List[TraceEvent] = []
    stats = RVVStats()

    def emit_scalar(n: int):
        if n <= 0:
            return
        trace.append(TraceEvent(op=Op.SCALAR, dtype=None, elements=0,
                                cb_mask=np.zeros(cfg.num_cbs, dtype=bool),
                                scalar_count=n))
        stats.scalar_instructions += n

    for instr in program:
        op = instr.op
        if op is Op.SCALAR:
            emit_scalar(instr.scalar_count)
            continue
        if op in isa.CONFIG_OPS:
            if op in (Op.SET_MASK, Op.UNSET_MASK):
                # Dimension-level masking does not exist in a 1D ISA: the
                # mask must be materialized in memory by the scalar core and
                # loaded into a vector mask register (Section III-E).
                dims = ctrl.active_dims()
                seg = dims[0] if dims else 1
                emit_scalar(seg)                       # compute mask values
                trace.append(TraceEvent(op=Op.SLD, dtype=DType.B,
                                        elements=cfg.lanes,
                                        cb_mask=np.ones(cfg.num_cbs, bool),
                                        segments=1, contiguous_run=seg,
                                        unique_elements=seg,
                                        lines=max(1, seg // 64)))
                stats.vector_instructions += 1
                stats.mask_instructions += 1
            else:
                apply_config(ctrl, instr)
                trace.append(TraceEvent(op=op, dtype=None, elements=0,
                                        cb_mask=np.zeros(cfg.num_cbs, bool)))
                stats.config_instructions += 1
            continue

        dims = ctrl.active_dims()
        lm = lane_dim_mask(dims, ctrl.dim_mask, cfg.lanes)
        elements = int(lm.sum())
        cbm = cbs_touched(dims, ctrl.dim_mask, cfg)

        if op in isa.MEMORY_OPS:
            segments, inner = segments_for(ctrl, instr, cfg.lanes)
            stats.segment_log.append((segments, inner, elements))
            per_seg_elems = max(1, elements // max(segments, 1))
            for _ in range(segments):
                # scalar address computation for this segment's base
                emit_scalar(2)
                # vsetvl / predicate config targeting the segment window
                trace.append(TraceEvent(op=Op.SET_DIML, dtype=None,
                                        elements=0,
                                        cb_mask=np.zeros(cfg.num_cbs, bool)))
                stats.vector_instructions += 1
                stats.mask_instructions += 1
                # the partial 1D access itself (only `inner` lanes active)
                nb = instr.dtype.nbytes
                trace.append(TraceEvent(op=op, dtype=instr.dtype,
                                        elements=per_seg_elems,
                                        cb_mask=cbm, segments=1,
                                        contiguous_run=inner,
                                        unique_elements=per_seg_elems,
                                        lines=max(1, (inner * nb) // 64)))
                stats.vector_instructions += 1
                stats.memory_instructions += 1
                # pack/unpack move into the long register slice
                trace.append(TraceEvent(op=Op.CPY, dtype=instr.dtype,
                                        elements=per_seg_elems,
                                        cb_mask=cbm))
                stats.vector_instructions += 1
                stats.move_instructions += 1
            continue

        # arithmetic / move: 1:1
        trace.append(TraceEvent(op=op, dtype=instr.dtype, elements=elements,
                                cb_mask=cbm))
        stats.vector_instructions += 1
    return trace, stats


def mve_stats(program: isa.Program) -> RVVStats:
    """Dynamic instruction counts of the *MVE* encoding (for Figure 11)."""
    stats = RVVStats()
    for instr in program:
        if instr.op is Op.SCALAR:
            stats.scalar_instructions += instr.scalar_count
        elif instr.op in isa.CONFIG_OPS:
            stats.config_instructions += 1
            if instr.op in (Op.SET_MASK, Op.UNSET_MASK):
                stats.mask_instructions += 1
        else:
            stats.vector_instructions += 1
            if instr.op in isa.MEMORY_OPS:
                stats.memory_instructions += 1
            elif instr.op in isa.MOVE_OPS:
                stats.move_instructions += 1
    return stats
