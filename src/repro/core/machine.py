"""MVE machine geometry and controller state.

Models the cache architecture of Section V: a 256 KB L2 slice repurposed as
32 compute-capable 8 KB SRAM arrays.  Each array has 256 bitlines; data
elements are transposed onto bitlines (Neural Cache layout), so every bitline
is one SIMD lane:

    lanes = num_arrays * bitlines = 32 * 256 = 8192

Arrays are grouped 4-per-Control-Block (CB); each CB has one FSM and can be
masked off per-instruction by the dimension-level mask (Section V-B).

A physical register (PR) occupies ``width`` wordlines out of 256, so the
number of live PRs is ``wordlines // width`` (Section III-B: constant vector
length, *variable* register count).

The addressing semantics (stride modes, dimension flattening, masking) are
documented with worked examples in docs/ISA.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from .isa import MAX_DIMS, MAX_TOP_DIM, DType, Instr, Op, ProgramError

# Byte data in the mobile kernels (pixels, characters) is unsigned; wider
# integer types model the signed variants (the ISA has both, Section III-F).
JNP_DTYPE = {
    DType.B: jnp.uint8,
    DType.W: jnp.int16,
    DType.DW: jnp.int32,
    DType.QW: jnp.int64,
    DType.HF: jnp.float16,
    DType.F: jnp.float32,
}


@dataclasses.dataclass(frozen=True)
class MVEConfig:
    """Geometry + compute-scheme knobs (Table IV `MVE` row by default)."""

    num_arrays: int = 32          # 4 cache ways x 8 arrays
    bitlines: int = 256           # SIMD lanes per array
    wordlines: int = 256          # bits of register file per lane
    arrays_per_cb: int = 4        # Section V-B (Duality-Cache granularity)
    scheme: str = "bs"            # bs | bp | bh | ac
    bh_segment_bits: int = 4      # EVE segment width for the bh scheme
    freq_ghz: float = 2.8         # clocked with the core (Table IV)

    #: Compute schemes of Section II-B the cost models understand.
    KNOWN_SCHEMES = ("bs", "bp", "bh", "ac")

    def __post_init__(self) -> None:
        """Geometry sanity checks at construction time.

        A bad geometry (non-power-of-two array dimensions, an array count
        the CB grouping can't divide, an unknown scheme) used to flow
        silently into ``lanes``/``effective_lanes`` and produce nonsense
        lane counts far downstream; reject it here with a readable
        :class:`ProgramError` instead.
        """
        for field, value in (("num_arrays", self.num_arrays),
                             ("arrays_per_cb", self.arrays_per_cb)):
            if not (isinstance(value, int) and value > 0):
                raise ProgramError(
                    f"MVEConfig.{field} must be a positive int, "
                    f"got {value!r}")
        for field, value in (("bitlines", self.bitlines),
                             ("wordlines", self.wordlines),
                             ("bh_segment_bits", self.bh_segment_bits)):
            if not (isinstance(value, int) and value > 0
                    and value & (value - 1) == 0):
                raise ProgramError(
                    f"MVEConfig.{field} must be a positive power of two "
                    f"(the bitline/wordline decoders are binary trees), "
                    f"got {value!r}")
        if self.num_arrays % self.arrays_per_cb:
            raise ProgramError(
                f"MVEConfig.num_arrays={self.num_arrays} is not divisible "
                f"by arrays_per_cb={self.arrays_per_cb}; control blocks "
                f"must group whole arrays (Section V-B)")
        if self.scheme not in self.KNOWN_SCHEMES:
            raise ProgramError(
                f"unknown compute scheme {self.scheme!r}; known schemes: "
                f"{', '.join(self.KNOWN_SCHEMES)}")
        if self.freq_ghz <= 0:
            raise ProgramError(
                f"MVEConfig.freq_ghz must be positive, got {self.freq_ghz!r}")

    @property
    def lanes(self) -> int:
        return self.num_arrays * self.bitlines

    @property
    def num_cbs(self) -> int:
        return self.num_arrays // self.arrays_per_cb

    @property
    def lanes_per_cb(self) -> int:
        return self.bitlines * self.arrays_per_cb

    def num_physical_registers(self, width_bits: int) -> int:
        """Variable register count: 256 wordlines / live register width."""
        return self.wordlines // max(width_bits, 1)

    def effective_lanes(self, width_bits: int) -> int:
        """SIMD lanes available under each compute scheme (Section II-B).

        bs: every bitline is a lane.
        bp: n-bit data lies horizontally -> 8K/n lanes (VRAM).
        bh: p-bit segments lie horizontally -> 8K/p lanes (EVE).
        ac: bit-slices lie horizontally across arrays; lanes = wordlines x
            arrays/bits ~= 8K/ (bits/arrays)... CAPE keeps 8K-element tiles,
            we model the same lane count as bs (latency differs).
        """
        if self.scheme == "bs":
            return self.lanes
        if self.scheme == "bp":
            return self.lanes // max(width_bits, 1)
        if self.scheme == "bh":
            return self.lanes // max(self.bh_segment_bits, 1)
        if self.scheme == "ac":
            return self.lanes
        raise ValueError(f"unknown scheme {self.scheme!r}")


@dataclasses.dataclass
class ControlState:
    """The controller CRs (Section III-B / V-B)."""

    dim_count: int = 1
    dim_lens: List[int] = dataclasses.field(
        default_factory=lambda: [1] * MAX_DIMS)
    ld_strides: List[int] = dataclasses.field(
        default_factory=lambda: [0] * MAX_DIMS)
    st_strides: List[int] = dataclasses.field(
        default_factory=lambda: [0] * MAX_DIMS)
    # one mask bit per element of the highest dimension (max 256)
    dim_mask: np.ndarray = dataclasses.field(
        default_factory=lambda: np.ones(MAX_TOP_DIM, dtype=bool))
    kernel_width: int = 32

    def active_dims(self) -> Tuple[int, ...]:
        return tuple(self.dim_lens[: self.dim_count])

    def active_elements(self) -> int:
        return int(np.prod(self.active_dims()))

    def resolve_strides(self, modes: Tuple[int, ...], store: bool
                        ) -> Tuple[int, ...]:
        """Resolve 2-bit stride modes to absolute strides (Section III-C).

        mode 2 derives S_i = S_{i-1} * L_{i-1} with S_{-1} = 1, which is the
        "dense row-major continuation" stride.
        """
        crs = self.st_strides if store else self.ld_strides
        strides = []
        prev = 1
        for d in range(self.dim_count):
            mode = modes[d] if d < len(modes) else 1
            if mode == 0:
                s = 0
            elif mode == 1:
                s = 1
            elif mode == 2:
                prev_len = self.dim_lens[d - 1] if d > 0 else 1
                s = (strides[d - 1] if d > 0 else 1) * prev_len
            elif mode == 3:
                s = crs[d]
            else:
                raise ValueError(f"bad stride mode {mode}")
            strides.append(s)
            prev = s
        return tuple(strides)


def flatten_indices(dims: Tuple[int, ...], lanes: int) -> np.ndarray:
    """Map lane id -> multi-dim logical index, x fastest (Figure 5).

    Returns an int array of shape (lanes, len(dims)); lanes beyond
    prod(dims) are marked inactive with -1 in every coordinate.
    Memoized: compile walks resolve the same (dims, lanes) pair for every
    instruction under one configuration, and the result is pure.  Treat
    the returned array as read-only.
    """
    return _flatten_indices_cached(tuple(dims), lanes)


@functools.lru_cache(maxsize=512)
def _flatten_indices_cached(dims: Tuple[int, ...], lanes: int) -> np.ndarray:
    total = int(np.prod(dims))
    lane = np.arange(lanes, dtype=np.int64)
    coords = np.full((lanes, len(dims)), -1, dtype=np.int64)
    active = lane < total
    rem = np.where(active, lane, 0)
    for d, length in enumerate(dims):       # d=0 is x (fastest)
        coords[:, d] = np.where(active, rem % length, -1)
        rem = rem // length
    coords.setflags(write=False)
    return coords


def lane_dim_mask(dims: Tuple[int, ...], dim_mask: np.ndarray,
                  lanes: int) -> np.ndarray:
    """Expand the highest-dimension mask CR to a per-lane boolean mask."""
    coords = flatten_indices(dims, lanes)
    top = coords[:, len(dims) - 1]
    active = top >= 0
    top_clipped = np.clip(top, 0, len(dim_mask) - 1)
    return active & dim_mask[top_clipped]


def apply_config(ctrl: ControlState, instr: Instr) -> None:
    """Apply one config instruction to the control registers.

    Shared by the step interpreter, the program compiler
    (:mod:`repro.core.engine`), and the RVV lowering — the config ops are
    what both execution paths resolve *statically* (docs/ENGINE.md).
    """
    op = instr.op
    if op is Op.SET_DIMC:
        ctrl.dim_count = instr.imm
    elif op is Op.SET_DIML:
        # The mask CR only covers the first MAX_TOP_DIM elements of the
        # highest dimension (Section III-E); longer highest dims are
        # legal but can only be dimension-masked on that prefix.
        ctrl.dim_lens[instr.dim] = instr.length
    elif op is Op.SET_LDSTR:
        ctrl.ld_strides[instr.dim] = instr.stride
    elif op is Op.SET_STSTR:
        ctrl.st_strides[instr.dim] = instr.stride
    elif op is Op.SET_MASK:
        ctrl.dim_mask[instr.mask_index] = True
    elif op is Op.UNSET_MASK:
        ctrl.dim_mask[instr.mask_index] = False
    elif op is Op.SET_WIDTH:
        ctrl.kernel_width = instr.imm
    else:
        raise ValueError(f"not a config op: {op}")


def config_cell(instr: Instr) -> Tuple:
    """The control-register *cell* a config instruction writes.

    Cells are the unit of the optimizer's dead-config analysis and the
    frontend's duplicate-emission suppression: two writes touch the same
    architectural state iff they have the same cell.
    """
    op = instr.op
    if op is Op.SET_DIMC:
        return ("dimc",)
    if op is Op.SET_DIML:
        return ("diml", instr.dim)
    if op is Op.SET_LDSTR:
        return ("ldstr", instr.dim)
    if op is Op.SET_STSTR:
        return ("ststr", instr.dim)
    if op in (Op.SET_MASK, Op.UNSET_MASK):
        return ("mask", instr.mask_index)
    if op is Op.SET_WIDTH:
        return ("width",)
    raise ValueError(f"not a config op: {op}")


def read_config_cell(ctrl: ControlState, cell: Tuple):
    """Current value of one config cell (see :func:`config_cell`)."""
    kind = cell[0]
    if kind == "dimc":
        return ctrl.dim_count
    if kind == "diml":
        return ctrl.dim_lens[cell[1]]
    if kind == "ldstr":
        return ctrl.ld_strides[cell[1]]
    if kind == "ststr":
        return ctrl.st_strides[cell[1]]
    if kind == "mask":
        return bool(ctrl.dim_mask[cell[1]])
    if kind == "width":
        return ctrl.kernel_width
    raise ValueError(f"unknown config cell {cell!r}")


def stream_shape(dims: Tuple[int, ...], strides: Tuple[int, ...],
                 lanes: int) -> Tuple[int, int, int]:
    """(contiguous run, segments, unique elements) of a strided access.

    Cost-model metadata: stride-0 dims are replication (free through the
    TMU crossbar); among the rest, runs grow while each stride equals the
    current dense run size (mode-2 "derived" accesses collapse to a single
    contiguous run).
    """
    nz = sorted((s, ln) for ln, s in zip(dims, strides) if s != 0)
    run, segments, unique = 1, 1, 1
    for s, ln in nz:
        unique *= ln
        if s == run:
            run *= ln
        else:
            segments *= ln
    return run, segments, min(unique, lanes)


def touched_lines(addr: np.ndarray, mask: np.ndarray, nbytes: int) -> int:
    """Exact 64-byte cache lines covered by a masked address stream."""
    if not mask.any():
        return 0
    return int(np.unique((addr[mask] * nbytes) // 64).size)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (bucketing helper for the executors)."""
    return 1 << max(int(n) - 1, 0).bit_length()


# Out-of-bounds scatter sentinel base.  Dropped lanes get ``_OOB + lane`` so
# a sorted-unique index vector stays sorted and unique after masking (JAX
# ``mode="drop"`` scatters skip out-of-bounds rows; every modeled memory is
# far below 2**30 elements).
OOB_BASE = 1 << 30


def store_layout(addr: np.ndarray, mask: np.ndarray):
    """Classify a static store's per-lane addresses for the executors.

    Both the fused engine and the VM avoid XLA:CPU's scalar scatter loop
    (~1 ms per 8K-lane scatter) whenever the layout allows:

    * ``("none",)``            — no active lane; the store is a no-op.
    * ``("contig", base)``     — every active lane ``l`` writes ``base + l``
      (true for all dense row-major-continuation stores, i.e. every static
      store in the Section-IV patterns): executable as a slice blend.
    * ``("scatter", idx, perm)`` — general case: ``idx`` is a sorted,
      unique, collision-resolved index vector (masked lanes and all but the
      last writer of each address are pushed out of bounds, preserving the
      last-lane-wins scatter order) and ``perm`` reorders the source lanes
      to match.
    """
    lanes = addr.shape[0]
    if not mask.any():
        return ("none",)
    lane = np.arange(lanes, dtype=np.int64)
    delta = addr[mask] - lane[mask]
    base = int(delta[0])
    if base >= 0 and (delta == base).all():
        return ("contig", base)
    # Keep, per distinct address, only the highest active lane (last wins).
    act = np.flatnonzero(mask)
    order_a = np.argsort(addr[act], kind="stable")
    sorted_a = addr[act][order_a]
    last = np.ones(len(act), dtype=bool)
    last[:-1] = sorted_a[:-1] != sorted_a[1:]
    winners = act[order_a[last]]
    key = OOB_BASE + lane
    key[winners] = addr[winners]
    perm = np.argsort(key, kind="stable")
    return ("scatter", key[perm].astype(np.int64), perm.astype(np.int32))


def cbs_touched(dims: Tuple[int, ...], dim_mask: np.ndarray,
                cfg: MVEConfig) -> np.ndarray:
    """Which control blocks have at least one active lane (mask bit-vector

    the controller keeps per instruction, Section V-B)."""
    return cbs_from_lane_mask(lane_dim_mask(dims, dim_mask, cfg.lanes), cfg)


def cbs_from_lane_mask(lane_mask: np.ndarray, cfg: MVEConfig) -> np.ndarray:
    """CB participation derived from an already-expanded lane mask (the
    compile walks have one in hand; avoids re-expanding the dim mask)."""
    per_cb = lane_mask.reshape(cfg.num_cbs, cfg.lanes_per_cb)
    return per_cb.any(axis=1)
