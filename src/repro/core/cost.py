"""Cycle-accurate cost models for the in-cache engine.

Latencies follow Table II (bit-serial, BS) and Section II-B for the other
In-SRAM computing schemes:

  BS (Neural Cache):  add n, sub 2n, mul n^2+5n, min/max 2n, xor n, cmp n,
                      shift-imm n, shift-reg n*log2(n), cvt/cpy n.
  BP (VRAM):          n-bit data horizontal; parallelism /n, latency /n.
  BH (EVE):           p-bit segments; parallelism /p, latency ~ /p with a
                      bit-serial carry between segments.
  AC (CAPE):          add/sub 8n+2 (search/update per truth-table row with
                      sequential carry); mul decomposes into n adds.

The *timeline* model reproduces the execution-time breakdown of Section
VII-A (idle / compute / data access) with the controller semantics of
Section V-B: instructions are enqueued by the scalar core, CBs execute
independently (skipping instructions their mask bit-vector drops), and the
controller blocks on vector memory accesses until every CB has finished.

Hardware constants not given in closed form by the paper are documented
inline and kept in one place (:class:`TimingParams`) so the benchmarks can
state their assumptions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import numpy as np

from .isa import (ARITH_OPS, COMPARE_OPS, CONFIG_OPS, MEMORY_OPS, MOVE_OPS,
                  DType, Op)
from .machine import MVEConfig


@dataclasses.dataclass
class TraceEvent:
    """One executed instruction with everything the cost model needs.

    Trace events are *data-independent* for strided accesses (addresses are
    fully determined by the control registers), which is what lets the
    compiled engine (:mod:`repro.core.engine`, docs/ENGINE.md) emit them at
    compile time.  Random-base accesses (Eq. 1) additionally depend on the
    pointer array contents, so their exact ``lines`` count is filled in
    after execution.
    """

    op: Op
    dtype: "DType | None"
    elements: int              # active elements (post dimension mask)
    cb_mask: np.ndarray        # which CBs participate
    segments: int = 1          # distinct contiguous runs in memory
    scalar_count: int = 0
    contiguous_run: int = 1    # elements per contiguous run
    unique_elements: int = 1   # memory words actually touched (stride-0
                               # replication is free through the crossbar)
    lines: int = 1             # exact 64B cache lines touched

    def same_as(self, other: "TraceEvent") -> bool:
        """Field-by-field equality (``cb_mask`` is an array, so the
        generated dataclass ``__eq__`` would be ambiguous)."""
        return (self.op is other.op and self.dtype is other.dtype
                and self.elements == other.elements
                and self.segments == other.segments
                and self.scalar_count == other.scalar_count
                and self.contiguous_run == other.contiguous_run
                and self.unique_elements == other.unique_elements
                and self.lines == other.lines
                and bool(np.array_equal(self.cb_mask, other.cb_mask)))


# ---------------------------------------------------------------------------
# Per-operation compute latency (cycles) per scheme.
# ---------------------------------------------------------------------------

def _bs_cycles(op: Op, n: int) -> float:
    if op in (Op.CVT, Op.CPY, Op.SET_DUP):
        return n
    if op is Op.ADD:
        return n
    if op is Op.SUB:
        return 2 * n
    if op is Op.MUL:
        return n * n + 5 * n
    if op in (Op.MIN, Op.MAX):
        return 2 * n
    if op in (Op.XOR, Op.AND, Op.OR):
        return n
    if op in (Op.SHI, Op.ROTI):
        return n
    if op is Op.SHR:
        return n * max(1.0, math.log2(n))
    if op in COMPARE_OPS:
        return n
    raise ValueError(f"no BS latency for {op}")


def _float_cycles(op: Op, bits: int) -> float:
    """Duality Cache [35] extends BS integer ops to floating point:
    multiply is dominated by the mantissa multiply; add/sub by mantissa
    alignment (variable shift) + normalize (~4x the integer add)."""
    mant = 24 if bits == 32 else 11
    if op is Op.MUL:
        return mant * mant + 5 * mant + 3 * bits     # + exp add, normalize
    if op in (Op.ADD, Op.SUB):
        return 4 * bits
    if op in (Op.MIN, Op.MAX) or op in COMPARE_OPS:
        return 2 * bits
    if op in (Op.CVT, Op.CPY, Op.SET_DUP):
        return bits
    return 4 * bits


def _scalar_op_cycles(op: Op, dtype: DType) -> float:
    """Engine-independent per-element serial cost (n-bit slices)."""
    if dtype.is_float:
        return _float_cycles(op, dtype.bits)
    return _bs_cycles(op, dtype.bits)


def compute_cycles(op: Op, dtype: DType, cfg: MVEConfig) -> float:
    """Latency (cycles) of one in-SRAM vector operation on the full engine."""
    n = dtype.bits
    base = _scalar_op_cycles(op, dtype)
    if cfg.scheme == "bs":
        return base
    if cfg.scheme == "bp":
        # VRAM: latency improves by ~n; carry chain across bitlines adds a
        # constant per op. Parallelism loss is accounted by lane count.
        return max(2.0, base / n + 2)
    if cfg.scheme == "bh":
        p = cfg.bh_segment_bits
        segs = max(1, n // p)
        # EVE: p-bit segments bit-parallel (Manchester carry), combined
        # bit-serially across segments.
        return max(2.0, base / n * segs + segs)
    if cfg.scheme == "ac":
        ff = 2.0 if dtype.is_float else 1.0
        if op in (Op.ADD, Op.SUB):
            return (8 * n + 2) * ff
        if op is Op.MUL:
            return n * (8 * n + 2) * ff      # shift-add decomposition
        if op in (Op.XOR, Op.AND, Op.OR) or op in COMPARE_OPS:
            return 8.0                        # O(1) truth-table rows [18]
        return (8 * n + 2) * ff
    raise ValueError(f"unknown scheme {cfg.scheme}")


# ---------------------------------------------------------------------------
# Timeline model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TimingParams:
    """Micro-architectural constants (Table IV unless noted).

    * ``issue_cycles``: core->controller issue of one MVE instruction over
      the fine-grain core/L2 interface; the ROB head commit plus queue write.
    * ``l2_bytes_per_cycle``: regular-half L2 bandwidth feeding the TMU; one
      64 B line per 2 cycles (shared tag/data pipeline).
    * ``l2_latency``: 12-cycle L2 hit latency (Table IV).
    * ``dram_latency``: Ramulator-average miss penalty for misses; we fold a
      hit-rate model instead of simulating DRAM.
    * ``tmu_fill``: cycles to write one bit-slice from TMU into the data
      array (one wordline write per bit).
    * ``scalar_ipc``: 4-way out-of-order core (Table IV).
    """

    issue_cycles: float = 16.0
    l2_bytes_per_cycle: float = 64.0
    l2_latency: float = 12.0
    dram_latency: float = 100.0
    l2_hit_rate: float = 0.85
    tmu_fill_per_bit: float = 1.0
    scalar_ipc: float = 4.0
    segment_overhead: float = 2.0   # pipelined per-run address generation


@dataclasses.dataclass
class Timeline:
    total_cycles: float = 0.0
    compute_cycles: float = 0.0
    data_cycles: float = 0.0
    idle_cycles: float = 0.0
    scalar_cycles: float = 0.0
    issue_cycles: float = 0.0
    vector_instructions: int = 0
    scalar_instructions: int = 0
    config_instructions: int = 0
    busy_cb_cycles: float = 0.0
    cb_slots: float = 0.0
    busy_lane_cycles: float = 0.0
    lane_slots: float = 0.0
    #: Cycles lost at issue, per cause — filled by the pipeline model
    #: (:mod:`repro.timing`: ``dependency`` / ``structural`` /
    #: ``memory-port`` / ``frontend``); empty for analytic timelines,
    #: which don't resolve *why* an instruction waited.
    stalls: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def cb_utilization(self) -> float:
        return self.busy_cb_cycles / self.cb_slots if self.cb_slots else 0.0

    @property
    def lane_utilization(self) -> float:
        """Fraction of (SIMD lane x cycle) slots doing useful work — the
        utilization metric of Section VII-C (23% RVV -> 60% MVE for BS):
        partial 1D accesses activate only a segment of the 8K lanes."""
        return (self.busy_lane_cycles / self.lane_slots
                if self.lane_slots else 0.0)

    def us(self, freq_ghz: float) -> float:
        return self.total_cycles / (freq_ghz * 1e3)


def memory_access_cycles(ev: TraceEvent, cfg: MVEConfig,
                         tp: TimingParams) -> float:
    """Data-access latency of one vector load/store.

    The controller walks ``segments`` contiguous runs (stride-0 dims are
    pure replication through the TMU crossbar — no traffic); each run
    streams cache lines through the MSHRs, then the TMU is drained into
    the arrays bit-serially (one wordline write per bit-slice per CB).
    """
    if ev.dtype is None:
        return 0.0
    lines = max(1, ev.lines)
    stream = lines * 64.0 / tp.l2_bytes_per_cycle
    # Address generation is a hardware 4D walker in the MVE controller
    # (Algorithm 1) pipelined with the MSHR stream — covered by the
    # per-line term.  (RVV pays through its many *instructions* instead.)
    addr_gen = lines * 0.5
    miss = (1.0 - tp.l2_hit_rate) * tp.dram_latency
    tmu = ev.dtype.bits * tp.tmu_fill_per_bit * \
        max(1, math.ceil(ev.elements / cfg.lanes_per_cb))
    return tp.l2_latency + miss + stream + addr_gen + tmu


def data_bytes(trace: List[TraceEvent]) -> float:
    """Unique memory bytes moved by a trace (replication is free)."""
    total = 0.0
    for ev in trace:
        if ev.op in MEMORY_OPS and ev.dtype is not None:
            total += ev.unique_elements * ev.dtype.nbytes
    return total


def simulate(trace: List[TraceEvent], cfg: MVEConfig,
             tp: TimingParams | None = None) -> Timeline:
    """Replay a trace through the controller/CB timeline model.

    Scalar work and MVE issue happen on the core timeline ``t_core``; each CB
    has its own completion time ``t_cb``.  Vector memory accesses are
    serialized across CBs (Section V-B: "MVE controller blocks on vector
    memory accesses until all CBs finish executing it").
    """
    tp = tp or TimingParams()
    ncb = cfg.num_cbs
    t_core = 0.0
    t_cb = np.zeros(ncb)
    tl = Timeline()

    for ev in trace:
        if ev.op is Op.SCALAR:
            dur = ev.scalar_count / tp.scalar_ipc
            t_core += dur
            tl.scalar_cycles += dur
            tl.scalar_instructions += ev.scalar_count
            continue
        if ev.op in CONFIG_OPS:
            t_core += tp.issue_cycles
            tl.issue_cycles += tp.issue_cycles
            tl.config_instructions += 1
            continue

        # vector instruction: issued at t_core, executed by masked CBs
        t_core += tp.issue_cycles
        tl.issue_cycles += tp.issue_cycles
        tl.vector_instructions += 1
        issue_t = t_core

        if ev.op in MEMORY_OPS:
            dur = memory_access_cycles(ev, cfg, tp)
            start = max(issue_t, float(t_cb.max()))   # barrier across CBs
            end = start + dur
            t_cb[:] = np.where(ev.cb_mask, end, np.maximum(t_cb, end))
            tl.data_cycles += dur
            tl.busy_cb_cycles += dur * ev.cb_mask.sum()
            tl.busy_lane_cycles += dur * ev.elements
        else:
            # BP/BH trade lanes for latency (Section II-B): fewer
            # effective lanes mean multiple serial passes over the data.
            eff = cfg.effective_lanes(ev.dtype.bits if ev.dtype else 32)
            passes = max(1, -(-ev.elements // max(eff, 1)))
            dur = compute_cycles(ev.op, ev.dtype, cfg) * passes
            for cb in range(ncb):
                if ev.cb_mask[cb]:
                    start = max(issue_t, t_cb[cb])
                    t_cb[cb] = start + dur
            tl.compute_cycles += dur
            tl.busy_cb_cycles += dur * ev.cb_mask.sum()
            tl.busy_lane_cycles += dur * min(ev.elements, eff)

    tl.total_cycles = max(t_core, float(t_cb.max()) if ncb else t_core)
    tl.cb_slots = tl.total_cycles * ncb
    tl.lane_slots = tl.total_cycles * cfg.lanes
    tl.idle_cycles = max(0.0, tl.cb_slots - tl.busy_cb_cycles) / max(ncb, 1)
    return tl


# ---------------------------------------------------------------------------
# Energy model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EnergyParams:
    """Energy component model (pJ) — one source of truth for the
    benchmarks (:mod:`benchmarks.paper_claims`) and the pluggable target
    API (:mod:`repro.targets`, docs/TARGETS.md).

    The paper's qualitative claims — large energy wins from
    instruction-count reduction + SRAM-local compute — are what the repo
    validates, not absolute joules; these constants state the assumptions
    in one documented place (they used to be module globals of
    ``benchmarks/paper_claims.py``).

    In-cache engine:

    * ``e_array_cycle`` — per SRAM array per active compute cycle (two
      wordline activations + peripheral logic, Neural-Cache-scale, 7nm);
    * ``e_l2_byte`` — L2 data movement per byte over the in-situ
      L2->TMU path (incl. the transpose write; no core round trip);
    * ``e_issue`` — one MVE instruction issue/dispatch through the
      controller.

    Mobile core baseline (Neon / scalar):

    * ``e_scalar`` — one OoO-core scalar instruction;
    * ``e_simd_op`` — one 128-bit ASIMD operation;
    * ``e_l1_byte`` — L1+L2+register-file round trip per byte.

    Mobile GPU baseline: ``e_gpu_flop`` per int-MAC flop,
    ``e_gpu_launch`` fixed per kernel launch, ``e_gpu_copy_byte`` per
    byte copied into pinned unified memory.
    """

    e_array_cycle: float = 8.0
    e_l2_byte: float = 8.0
    e_issue: float = 50.0
    e_scalar: float = 150.0
    e_simd_op: float = 250.0
    e_l1_byte: float = 25.0
    e_gpu_flop: float = 2.5
    e_gpu_launch: float = 2.0e7
    e_gpu_copy_byte: float = 30.0

    @classmethod
    def derive(cls, cfg: MVEConfig, scheme: "str | None" = None
               ) -> "EnergyParams":
        """Derive the in-cache constants for one (scheme, geometry) from
        the parametric SRAM model (:mod:`repro.silicon`, docs/SILICON.md)
        instead of the fixed defaults.

        Calibration contract: the parametric model supplies *relative*
        scaling only — each derived constant is the default times the
        model's ratio between ``cfg`` and the default Table IV geometry —
        so at the default geometry under the bit-serial scheme the result
        is byte-identical to :data:`DEFAULT_ENERGY` and every frozen
        golden row is preserved exactly.
        """
        from ..silicon.params import derived_energy
        return derived_energy(cfg, scheme)[0]


DEFAULT_ENERGY = EnergyParams()


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Per-component energy (pJ) of one kernel execution on one target.

    ``total_pj`` is stored (not derived) so models control their exact
    summation order — the golden benchmark rows compare floats exactly.

    ``params_source`` records the provenance of the
    :class:`EnergyParams` the report was priced with: ``"default"`` for
    the fixed point-constants, ``"derived:<geometry-digest>"`` when they
    came from the parametric silicon model
    (:func:`repro.silicon.params.derived_energy`) — so a benchmark row
    can always be traced back to the exact (scheme, geometry) pricing.
    """

    compute_pj: float = 0.0
    data_pj: float = 0.0
    issue_pj: float = 0.0
    scalar_pj: float = 0.0
    total_pj: float = 0.0
    params_source: str = "default"


def mve_energy(tl: Timeline, cfg: MVEConfig, mem_bytes: float,
               ep: EnergyParams | None = None,
               params_source: str | None = None) -> EnergyReport:
    """Energy of one in-cache execution: array compute + L2 movement +
    instruction issue + interleaved scalar work.  Shared by every
    in-cache target (MVE under any compute scheme, and the RVV-driven
    engine, which pays through its larger instruction counts).

    ``params_source`` labels the provenance of ``ep`` in the report
    (``"derived:<digest>"`` for silicon-model-derived params); ``None``
    keeps the ``"default"`` label.
    """
    ep = ep or DEFAULT_ENERGY
    compute = tl.compute_cycles * cfg.num_arrays * ep.e_array_cycle
    data = mem_bytes * ep.e_l2_byte
    issue = (tl.vector_instructions + tl.config_instructions) * ep.e_issue
    scalar = tl.scalar_instructions * ep.e_scalar
    return EnergyReport(compute_pj=compute, data_pj=data, issue_pj=issue,
                        scalar_pj=scalar,
                        total_pj=compute + data + issue + scalar,
                        params_source=params_source or "default")


def neon_energy(simd_ops: float, mem_bytes: float,
                ep: EnergyParams | None = None,
                params_source: str | None = None) -> EnergyReport:
    """Energy of a packed-SIMD execution: ``simd_ops`` 128-bit ASIMD ops
    plus loop/address scalar overhead (0.5 scalar per SIMD op) plus the
    L1 round trip for every byte."""
    ep = ep or DEFAULT_ENERGY
    scalar_ops = simd_ops * 0.5
    compute = simd_ops * ep.e_simd_op
    scalar = scalar_ops * ep.e_scalar
    data = mem_bytes * ep.e_l1_byte
    return EnergyReport(compute_pj=compute, data_pj=data, scalar_pj=scalar,
                        total_pj=compute + scalar + data,
                        params_source=params_source or "default")


# ---------------------------------------------------------------------------
# Baseline cost models for comparison figures.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NeonModel:
    """Packed-SIMD baseline: 2x128-bit ASIMD pipes on a Cortex-A76.

    Throughput model: each pipe retires one vector op/cycle; loads hit L1 at
    16 B/cycle.  Linear scaling with precision (Section VII-E: "Neon ASIMD
    units achieve linear scaling with lower bit precision").
    """

    simd_bits: int = 128
    pipes: int = 2
    l1_bytes_per_cycle: float = 16.0
    freq_ghz: float = 2.8

    def kernel_cycles(self, vector_ops: float, elements: float,
                      bits: int, mem_bytes: float) -> float:
        lanes = self.simd_bits // bits
        compute = vector_ops * elements / (lanes * self.pipes)
        mem = mem_bytes / self.l1_bytes_per_cycle
        return max(compute, mem) + min(compute, mem) * 0.3  # partial overlap


@dataclasses.dataclass(frozen=True)
class GPUModel:
    """Adreno 640-style model (Section VII-A, Figure 9).

    Key effects the paper measures: a fixed kernel-launch overhead through
    the OpenCL runtime + system fabric, a data-copy cost into pinned unified
    memory, and high raw MAC throughput (13.6x MVE for int32).
    """

    launch_overhead_us: float = 45.0
    copy_bytes_per_us: float = 8_000.0
    int_macs_per_cycle: float = 768.0       # 2 cores x 384 ALUs
    freq_ghz: float = 0.685

    def kernel_us(self, flops: float, copy_bytes: float) -> float:
        compute_us = flops / 2.0 / (self.int_macs_per_cycle *
                                    self.freq_ghz * 1e3)
        copy_us = copy_bytes / self.copy_bytes_per_us
        return self.launch_overhead_us + copy_us + compute_us


def breakdown(tl: Timeline) -> Dict[str, float]:
    """Idle/compute/data fractions as reported in Figure 7(a)."""
    busy = tl.compute_cycles + tl.data_cycles
    total = max(tl.total_cycles, 1e-9)
    comp = tl.compute_cycles / total
    data = tl.data_cycles / total
    return {
        "idle": max(0.0, 1.0 - min(1.0, comp + data)),
        "compute": comp,
        "data": data,
    }
