"""Compiled MVE execution engine: whole-program compile + fused execution.

The step interpreter (:class:`repro.core.interp.MVEInterpreter`) walks a
program one instruction at a time, paying Python dispatch and host/device
round trips on every step.  This module exploits a structural property of
the ISA: *all* addressing state lives in control registers written by
config instructions with immediate operands, so a single symbolic pass over
the program can resolve every per-instruction address vector, lane mask,
CB mask and :class:`~repro.core.cost.TraceEvent` ahead of time.  What
remains — the data path — is emitted as one fused ``jax.jit`` function for
the whole program, with ``jax.vmap`` support for evaluating the same
program over a batch of memory images.

Static vs dynamic split (design note: docs/ENGINE.md):

  static  — control-register evolution, per-lane addresses of strided
            accesses, dimension/lane/CB masks, trace metadata;
  dynamic — register values, the Tag predicate latch, memory contents,
            and the addresses of random-base accesses (Eq. 1), whose
            pointer arrays are fetched from memory at run time.

Random-base accesses are the one place the trace is data-dependent: their
exact cache-line count depends on the pointer values, so the jitted
function also returns those address vectors and :meth:`CompiledProgram.run`
fills the ``lines`` field after execution.  Everything else about the
trace is emitted at compile time.

Bit-exactness.  The engine must reproduce the step interpreter bit for bit,
but XLA:CPU selects instructions with FP-contraction enabled: any ``fmul``
directly feeding an ``fadd`` in one fused loop becomes an FMA, skipping the
intermediate rounding that per-instruction eager execution performs.  The
fix is architectural rather than a compiler flag (none exists): every
register write-back is guarded by its instruction's *own* dimension-mask
vector, streamed in as run-time data (one row per instruction).  LLVM
cannot prove two mask rows equal, so the selects survive optimization and
no multiply result ever reaches an add without an intervening rounding
point — exactly the semantics of distinct in-cache instructions.

The interpreter remains the semantic oracle: ``tests/test_engine.py``
asserts bit-identical memory results and identical trace events on every
registered pattern.

Execution modes.  ``compile_program(..., mode=...)`` selects the executor:

  "vm"    — (default) the program-as-data virtual machine
            (:mod:`repro.core.vm`, docs/ENGINE.md "VM lowering"): the step
            list is lowered to dense tensors and executed by one pre-jitted
            ``lax.while_loop``/``lax.switch`` datapath shared by *every*
            program with the same signature, so data-dependent program
            streams (one spmm program per sparsity pattern) never recompile
            XLA;
  "fused" — one jitted straight-line function per program: peak
            steady-state throughput once its (per-program) compile is paid.

Both modes run/run_batch/trace identically and are equivalence-tested
against the stepwise oracle.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import Instr, Op
from .cost import TraceEvent
from .machine import (JNP_DTYPE, ControlState, MVEConfig, apply_config,
                      cbs_from_lane_mask, flatten_indices, lane_dim_mask,
                      store_layout, stream_shape, touched_lines)
from .vm import AotJit, VMProgram, VMUnsupported, fire_fault_hook
from .vm import cache_info as _vm_cache_info
from .vm import set_fault_hook  # noqa: F401  (re-export: one hook registry)


class ExecutorError(RuntimeError):
    """Base of the typed executor failures.

    The execution stack used to let whatever exception an executor's
    internals raised — an XLA error at sync time, a numpy shape error
    three frames deep — escape to callers untyped, which made the serving
    runtime's failure handling guesswork.  Each subclass names the
    boundary that failed; the original exception is chained as
    ``__cause__``.  User-input errors (:class:`~repro.core.isa.ProgramError`,
    ``TypeError``/``ValueError`` from malformed arguments) are *not*
    wrapped: they mean "fix the request", not "the executor failed".
    """


class CompileError(ExecutorError):
    """Compiling/lowering a program to an executable failed."""


class DispatchError(ExecutorError):
    """Launching an execution (single or batched) failed."""


class FinalizeError(ExecutorError):
    """Materializing results of a dispatched execution failed."""


# exception types that pass through untyped (user errors / control flow)
_PASSTHROUGH = (isa.ProgramError, VMUnsupported, TypeError, ValueError,
                ExecutorError)


@dataclasses.dataclass
class _Step:
    """One vector/scalar instruction with its statically resolved context."""

    instr: Instr
    lane_mask: Optional[np.ndarray] = None   # per-lane dimension mask
    cb_mask: Optional[np.ndarray] = None
    event: Optional[TraceEvent] = None
    mask_slot: Optional[int] = None          # row in the runtime mask stack
    addr: Optional[np.ndarray] = None        # static element addresses
    store_layout: Optional[tuple] = None     # machine.store_layout result
    # random-base (Eq. 1) accesses: pointer slice + static inner offsets
    ptr_base: Optional[int] = None
    top_len: Optional[int] = None
    top_idx: Optional[np.ndarray] = None
    offsets: Optional[np.ndarray] = None
    rand_slot: Optional[int] = None          # index into returned addresses


@dataclasses.dataclass
class ExecutionResult:
    """Duck-type compatible with :class:`repro.core.interp.MachineState`.

    ``operands`` materialises named, shaped result views lazily when the
    program was compiled from a frontend kernel (:mod:`repro.frontend`)
    — reading results by name costs nothing until accessed."""

    memory: jnp.ndarray
    regs: Dict[int, jnp.ndarray]
    tag: jnp.ndarray
    ctrl: ControlState
    trace: List[TraceEvent]
    kernel: Optional[object] = None       # frontend Kernel, if any
    _operands: Optional[Dict[str, np.ndarray]] = dataclasses.field(
        default=None, repr=False)

    @property
    def operands(self) -> Optional[Dict[str, np.ndarray]]:
        """Results read back by operand name (``None`` for raw programs)."""
        if self._operands is None and self.kernel is not None:
            self._operands = self.kernel.unpack(self.memory)
        return self._operands


class CompiledProgram:
    """An MVE program lowered to one fused JAX function + a static trace.

    Use :func:`compile_program` (cached) rather than constructing directly.
    The compiled object is memory-image independent: it executes any image
    of a given size (or a vmapped batch of them) without re-tracing.
    """

    def __init__(self, program: isa.Program, cfg: MVEConfig,
                 mode: str = "fused"):
        self.cfg = cfg
        self.program = tuple(program)
        self.kernel = None    # frontend Kernel when compiled from one
        self._kernels_seen = None      # WeakSet of accepted kernels
        self._kernel_conflict = False  # distinct kernels share this text
        self.steps: List[_Step] = []
        self.n_random = 0
        # Build-time checks (readable one-line errors) before the walk:
        # a malformed program fails here, not deep inside addressing
        # resolution.  Lenient mode — executors keep accepting programs
        # that deliberately rely on clip/drop semantics.
        isa.validate(self.program, wordlines=cfg.wordlines)
        self._compile_walk()
        self._masks = None       # built lazily: only the fused path streams
        self._zeros = None       # the mask stack / power-on register row
        self._jit = AotJit(self._execute, donate_argnums=(0,))
        self._batch_jit = None
        self._vm: Optional[VMProgram] = None
        self.mode = mode
        if mode == "vm":
            try:
                self._vm = VMProgram(self.steps, cfg, self.n_random)
            except VMUnsupported:
                global _VM_FALLBACKS
                _VM_FALLBACKS += 1
                self.mode = "fused"

    # -- compilation -------------------------------------------------------
    def _compile_walk(self) -> None:
        """Symbolically execute config ops; resolve every access statically."""
        cfg = self.cfg
        ctrl = ControlState()
        n_masked = 0
        for instr in self.program:
            op = instr.op
            if op in isa.CONFIG_OPS:
                apply_config(ctrl, instr)
                self.steps.append(_Step(instr, event=TraceEvent(
                    op=op, dtype=None, elements=0,
                    cb_mask=np.zeros(cfg.num_cbs, dtype=bool))))
                continue
            if op is Op.SCALAR:
                self.steps.append(_Step(instr, event=TraceEvent(
                    op=op, dtype=None, elements=0,
                    cb_mask=np.zeros(cfg.num_cbs, dtype=bool),
                    scalar_count=instr.scalar_count)))
                continue

            dims = ctrl.active_dims()
            lane_mask = lane_dim_mask(dims, ctrl.dim_mask, cfg.lanes)
            cbm = cbs_from_lane_mask(lane_mask, cfg)
            elements = int(lane_mask.sum())
            step = _Step(instr, lane_mask=lane_mask, cb_mask=cbm,
                         mask_slot=n_masked)
            n_masked += 1

            if op in isa.MEMORY_OPS:
                store = op in (Op.SST, Op.RST)
                random = op in (Op.RLD, Op.RST)
                strides = ctrl.resolve_strides(instr.modes or (), store)
                run, segs, uniq = stream_shape(dims, strides, cfg.lanes)
                coords = flatten_indices(dims, cfg.lanes)
                if random:
                    top_len = dims[-1]
                    offsets = np.zeros(cfg.lanes, dtype=np.int64)
                    for d in range(len(dims) - 1):
                        offsets += np.where(coords[:, d] >= 0,
                                            coords[:, d], 0) * strides[d]
                    step.ptr_base = instr.base
                    step.top_len = top_len
                    step.top_idx = np.clip(coords[:, len(dims) - 1],
                                           0, top_len - 1)
                    step.offsets = offsets
                    step.rand_slot = self.n_random
                    self.n_random += 1
                    lines = 0          # filled from run-time addresses
                else:
                    addr = np.full(cfg.lanes, instr.base, dtype=np.int64)
                    for d in range(len(dims)):
                        addr += np.where(coords[:, d] >= 0,
                                         coords[:, d], 0) * strides[d]
                    step.addr = addr
                    if store:
                        step.store_layout = store_layout(addr, lane_mask)
                    lines = touched_lines(addr, lane_mask,
                                          instr.dtype.nbytes)
                step.event = TraceEvent(op, instr.dtype, elements, cbm,
                                        segments=segs, contiguous_run=run,
                                        unique_elements=uniq, lines=lines)
            else:
                step.event = TraceEvent(op, instr.dtype, elements, cbm)
            self.steps.append(step)
        self.final_ctrl = copy.deepcopy(ctrl)

    # -- fused data path ---------------------------------------------------
    def _execute(self, memory, masks, zeros):
        """The whole program as one traced JAX computation.

        Mirrors :meth:`MVEInterpreter._step` semantics exactly (the
        equivalence tests depend on it) with all addressing constant-folded.
        ``masks`` carries one dimension-mask row per vector instruction and
        ``zeros`` the power-on register value; both arrive as run-time data
        so each write-back keeps its own rounding point (see the module
        docstring on FP contraction).
        """
        cfg = self.cfg
        regs: Dict[int, jnp.ndarray] = {}
        tag = jnp.ones(cfg.lanes, dtype=bool)
        rand_addrs: List[jnp.ndarray] = [None] * self.n_random
        hi = memory.shape[0] - 1

        for step in self.steps:
            instr = step.instr
            op = instr.op
            if op in isa.CONFIG_OPS or op is Op.SCALAR:
                continue

            dt = JNP_DTYPE.get(instr.dtype, jnp.float32)
            jmask = masks[step.mask_slot]

            def old(vd, dt=dt):
                v = regs.get(vd)
                return (zeros if v is None else v).astype(dt)

            if op in (Op.SLD, Op.RLD):
                addr = self._address_vector(step, memory)
                if step.rand_slot is not None:
                    rand_addrs[step.rand_slot] = addr
                gathered = memory[jnp.clip(addr, 0, hi)].astype(dt)
                regs[instr.vd] = jnp.where(jmask, gathered, old(instr.vd))
                continue
            if op in (Op.SST, Op.RST):
                src = old(instr.vs1).astype(memory.dtype)
                if step.rand_slot is not None:
                    # Runtime addresses: masked lanes dropped out of
                    # bounds; later lanes win on address collisions
                    # (scatter order matches a sequential loop).
                    addr = self._address_vector(step, memory)
                    rand_addrs[step.rand_slot] = addr
                    memory = memory.at[jnp.where(jmask, addr, -1)].set(
                        src, mode="drop")
                    continue
                layout = step.store_layout
                if layout[0] == "contig":
                    # Dense store (addr = base + lane): a slice blend
                    # instead of XLA:CPU's scalar scatter loop.  Lanes
                    # past the end of memory are dropped, as before.
                    base = layout[1]
                    w = min(cfg.lanes, memory.shape[0] - base)
                    if w > 0:
                        window = memory[base:base + w]
                        memory = memory.at[base:base + w].set(
                            jnp.where(jmask[:w], src[:w], window))
                elif layout[0] == "scatter":
                    # Pre-sorted collision-ordered indices: masked lanes
                    # and all but the last writer per address are out of
                    # bounds, so one sorted-unique drop-scatter keeps
                    # last-lane-wins semantics without the old gather.
                    memory = memory.at[jnp.asarray(layout[1])].set(
                        src[jnp.asarray(layout[2])], mode="drop",
                        indices_are_sorted=True, unique_indices=True)
                # ("none",): fully masked store — no effect
                continue

            def finish(result, instr=instr, jmask=jmask, dt=dt, old=old):
                result = result.astype(dt)
                keep = jmask
                if instr.predicated:
                    keep = keep & tag
                regs[instr.vd] = jnp.where(keep, result, old(instr.vd))

            if op is Op.SET_DUP:
                finish(jnp.full(cfg.lanes, instr.imm, dtype=dt))
                continue
            if op is Op.CPY:
                finish(old(instr.vs1))
                continue
            if op is Op.CVT:
                v = regs.get(instr.vs1)
                src = zeros if v is None else v
                finish(src.astype(dt))
                continue

            a = old(instr.vs1)
            b = old(instr.vs2) if instr.vs2 is not None else None

            if op is Op.ADD:
                finish(a + b)
            elif op is Op.SUB:
                finish(a - b)
            elif op is Op.MUL:
                finish(a * b)
            elif op is Op.MIN:
                finish(jnp.minimum(a, b))
            elif op is Op.MAX:
                finish(jnp.maximum(a, b))
            elif op is Op.XOR:
                finish(a ^ b)
            elif op is Op.AND:
                finish(a & b)
            elif op is Op.OR:
                finish(a | b)
            elif op is Op.SHI:
                if instr.dtype.is_float:
                    raise ValueError("shift on float register")
                amt = instr.imm
                finish(a << amt if amt >= 0 else a >> (-amt))
            elif op is Op.ROTI:
                bits = instr.dtype.bits
                amt = instr.imm % bits
                ua = a.astype(jnp.uint32 if bits <= 32 else jnp.uint64)
                finish(((ua << amt) | (ua >> (bits - amt))).astype(dt))
            elif op is Op.SHR:
                finish(a << b.astype(jnp.int32))
            elif op in isa.COMPARE_OPS:
                cmp = {Op.GT: a > b, Op.GTE: a >= b, Op.LT: a < b,
                       Op.LTE: a <= b, Op.EQ: a == b, Op.NEQ: a != b}[op]
                tag = jnp.where(jmask, cmp, tag)
            else:
                raise NotImplementedError(f"op {op}")

        return memory, regs, tag, rand_addrs

    @staticmethod
    def _address_vector(step: _Step, memory):
        """Element addresses: constant for strided, traced for random-base
        (the pointer array is part of the data, Eq. 1)."""
        if step.addr is not None:
            return jnp.asarray(step.addr)
        ptrs = memory[step.ptr_base: step.ptr_base + step.top_len]
        ptrs = ptrs.astype(jnp.int32)
        return ptrs[step.top_idx] + jnp.asarray(step.offsets)

    # -- public API --------------------------------------------------------
    def _fused_operands(self):
        """Mask-stack / zeros operands of the fused function (uploaded on
        first fused execution only — the VM path never needs them)."""
        if self._masks is None:
            masks = [s.lane_mask for s in self.steps
                     if s.mask_slot is not None]
            self._masks = jnp.asarray(np.stack(masks)) if masks else \
                jnp.zeros((0, self.cfg.lanes), dtype=bool)
            self._zeros = jnp.zeros(self.cfg.lanes, dtype=jnp.float32)
        return self._masks, self._zeros

    def _donatable(self, memory) -> jnp.ndarray:
        """The executables donate (write through) their memory operand, so
        it must be a jax-owned buffer: copy=True protects caller-owned
        device arrays and prevents zero-copy aliasing of caller numpy
        buffers (same-dtype CPU device_put does not copy)."""
        return jnp.array(memory, copy=True)

    @staticmethod
    def _vm_memory_dtype(memory) -> bool:
        """True when the memory image is float32-canonical (float64 or
        float32 — what every pattern and the 32-bit-mode eager executors
        use); reads ``memory.dtype`` without materializing the array."""
        dtype = getattr(memory, "dtype", None)
        if dtype is None:
            dtype = np.asarray(memory).dtype
        return np.dtype(dtype) in (np.float64, np.float32)

    def _use_vm(self, memory) -> bool:
        """Route through the VM datapath unless the memory dtype needs the
        exact eager semantics of the per-program fused function."""
        return self.mode == "vm" and self._vm_memory_dtype(memory)

    def _bound_kernel(self):
        """The kernel whose plan names this program's operands; raises a
        readable error when there is none or when the binding is
        ambiguous (several non-equivalent kernels share the text)."""
        if self.kernel is None:
            if self._kernel_conflict:
                raise TypeError(
                    "this program text was compiled from multiple "
                    "distinct kernels (different operand plans or init "
                    "data) — pack explicitly with kernel.pack(...) or "
                    "execute via kernel.run()/kernel.run_batch()")
            raise TypeError(
                "named-operand execution needs a frontend kernel: "
                "compile with compile_program(kernel) or pass a flat "
                "memory image")
        return self.kernel

    def _as_memory(self, memory):
        """Accept a flat memory image or — when this program was compiled
        from a frontend kernel — a dict of named operand arrays."""
        if isinstance(memory, dict):
            return self._bound_kernel().pack(memory)
        return memory

    def run_async(self, memory):
        """Dispatch one execution without blocking on host results.

        Returns an opaque pending handle for :meth:`finalize_run`.  JAX's
        async dispatch (CPU included) keeps computing while the caller
        prepares the next request, so a serving loop
        (:mod:`repro.runtime.scheduler`) pays one sync per drain cycle
        instead of one per request."""
        fire_fault_hook("engine.dispatch", tier=self.mode)
        try:
            memory = self._as_memory(memory)
            if self._use_vm(memory):
                return ("vm", self._vm.run_async(memory))
            masks, zeros = self._fused_operands()
            return ("fused", self._jit(self._donatable(memory), masks,
                                       zeros))
        except _PASSTHROUGH:
            raise
        except Exception as e:
            raise DispatchError(f"dispatch failed ({self.mode} mode): "
                                f"{type(e).__name__}: {e}") from e

    def finalize_run(self, pending) -> Tuple[jnp.ndarray, ExecutionResult]:
        """Materialize a :meth:`run_async` dispatch into ``(mem, state)``."""
        fire_fault_hook("engine.finalize", tier=self.mode)
        try:
            kind, out = pending
            if kind == "vm":
                mem, regs, tag, rand_addrs = self._vm.finalize(out)
            else:
                mem, regs, tag, rand_addrs = out
        except _PASSTHROUGH:
            raise
        except Exception as e:
            raise FinalizeError(f"finalize failed ({self.mode} mode): "
                                f"{type(e).__name__}: {e}") from e
        trace = self._finalize_trace(rand_addrs)
        # Fresh ctrl/trace objects per run: callers may mutate the returned
        # state (the stepwise oracle hands out fresh state too), and this
        # CompiledProgram is shared through the compile cache.
        state = ExecutionResult(memory=mem, regs=dict(regs), tag=tag,
                                ctrl=copy.deepcopy(self.final_ctrl),
                                trace=trace, kernel=self.kernel)
        return mem, state

    def run(self, memory) -> Tuple[jnp.ndarray, ExecutionResult]:
        """Execute on one memory image; returns ``(memory, state)`` exactly
        like :meth:`MVEInterpreter.run` (trace included).  Dispatches to
        the VM datapath or the per-program fused function per ``mode``."""
        return self.finalize_run(self.run_async(memory))

    def run_batch(self, memories) -> Tuple[jnp.ndarray,
                                           Dict[int, jnp.ndarray],
                                           jnp.ndarray]:
        """Evaluate the program over a leading batch of memory images.

        Returns ``(memories, regs, tag)`` with a leading batch axis on
        every array.  No trace is produced: the cost-model trace of a
        batched run is that of any single element (and for random-base
        programs each element may touch different cache lines — use
        :meth:`run` on a representative image to price it).
        """
        return self.finalize_batch(self.run_batch_async(memories))

    def run_batch_async(self, memories):
        """Dispatch a batched execution without blocking (see
        :meth:`run_async`); finalize with :meth:`finalize_batch`."""
        fire_fault_hook("engine.dispatch", tier=self.mode)
        try:
            if isinstance(memories, dict):
                memories = self._bound_kernel().pack_batch(memories)
            if self._use_vm(memories):
                return ("vm", self._vm.run_batch_async(memories))
            masks, zeros = self._fused_operands()
            mem, regs, tag, _ = self._get_batch_jit()(
                self._donatable(memories), masks, zeros)
            return ("fused", (mem, dict(regs), tag))
        except _PASSTHROUGH:
            raise
        except Exception as e:
            raise DispatchError(f"batch dispatch failed ({self.mode} "
                                f"mode): {type(e).__name__}: {e}") from e

    def finalize_batch(self, pending):
        fire_fault_hook("engine.finalize", tier=self.mode)
        try:
            kind, out = pending
            if kind == "vm":
                return self._vm.finalize_batch(out)
            return out
        except _PASSTHROUGH:
            raise
        except Exception as e:
            raise FinalizeError(f"batch finalize failed ({self.mode} "
                                f"mode): {type(e).__name__}: {e}") from e

    def batch_group_key(self, memory) -> tuple:
        """Scheduling key: requests whose keys are equal can be stacked
        into one ``run_batch`` dispatch and — under ``mode="vm"`` — share
        one signature-keyed XLA executable.  The key is the VM signature
        bucket for VM-routed requests (program identity rides along:
        batching stacks *memories* under one program) and the program
        itself for fused-routed ones."""
        mem = np.asarray(memory) if not hasattr(memory, "shape") else memory
        size = int(mem.shape[-1])
        dtype = str(getattr(mem, "dtype", "float64"))
        if self._use_vm(memory):
            return ("vm", self._vm._signature(size), size, dtype)
        return ("fused", id(self), size, dtype)

    def _get_batch_jit(self) -> AotJit:
        if self._batch_jit is None:
            self._batch_jit = AotJit(
                jax.vmap(self._execute, in_axes=(0, None, None)),
                donate_argnums=(0,))
        return self._batch_jit

    def warmup(self, memory_size, batch: Optional[int] = None,
               dtype=jnp.float32) -> "CompiledProgram":
        """AOT-compile (``.lower().compile()``) the executable for a memory
        geometry, removing the silent first-call compile cliff.

        ``memory_size`` is an element count (or an example memory image);
        pass ``batch`` to warm the vmapped batch executable instead.
        Returns ``self`` so calls chain with :func:`compile_program`.
        """
        if not isinstance(memory_size, int):
            memory_size = int(np.asarray(memory_size).shape[-1])
        dtype = jax.dtypes.canonicalize_dtype(dtype)
        # Warm the executor run() will actually pick for this dtype: the
        # VM datapath for float32-canonical images, the fused jit
        # otherwise (matching ``_use_vm``).
        if self.mode == "vm" and np.dtype(dtype) in (np.float64, np.float32):
            self._vm.warmup(memory_size, batch)
            return self
        shape = (memory_size,) if batch is None else (batch, memory_size)
        mem = jax.ShapeDtypeStruct(shape, dtype)
        masks, zeros = self._fused_operands()
        if batch is None:
            self._jit.warmup(mem, masks, zeros)
        else:
            self._get_batch_jit().warmup(mem, masks, zeros)
        return self

    def _finalize_trace(self, rand_addrs) -> List[TraceEvent]:
        trace: List[TraceEvent] = []
        for step in self.steps:
            ev = step.event
            if step.rand_slot is not None:
                addr = np.asarray(rand_addrs[step.rand_slot],
                                  dtype=np.int64)
                ev = dataclasses.replace(ev, lines=touched_lines(
                    addr, step.lane_mask, step.instr.dtype.nbytes))
            else:
                ev = dataclasses.replace(ev)
            trace.append(ev)
        return trace

    @property
    def static_trace(self) -> List[TraceEvent]:
        """The compile-time trace; exact unless the program uses
        random-base accesses (then run() fills the ``lines`` fields)."""
        return [s.event for s in self.steps]


# ---------------------------------------------------------------------------
# Compile cache: programs are tuples of frozen Instr, so they hash.  Bounded
# LRU — data-dependent program streams (e.g. one program per sparsity
# pattern) would otherwise retain a lowering per variant forever.  Under
# ``mode="vm"`` an eviction only drops host-side tables; the XLA executable
# lives in the signature cache (:mod:`repro.core.vm`) and is never retraced.
# ---------------------------------------------------------------------------

_CACHE: "OrderedDict[tuple, CompiledProgram]" = OrderedDict()
_CACHE_CAPACITY = 256
_CACHE_LOCK = threading.RLock()   # submit() may compile from many threads
_HITS = _MISSES = _EVICTIONS = 0
_VM_FALLBACKS = 0
# Per-target LRU counters: cache_tag -> [hits, misses].  Tagged compiles
# (one tag per :mod:`repro.targets` target) get their own key space, so
# an RVV or Neon compilation of a program never aliases — or evicts in
# place of — the MVE entry for the same text.
_TAG_COUNTS: Dict[str, List[int]] = {}

#: Default execution mode: ``"vm"`` (program-as-data datapath, one XLA
#: compilation per signature) or ``"fused"`` (one jitted function per
#: program — peak steady-state throughput).  The stepwise interpreter
#: remains the semantic oracle for both.
DEFAULT_MODE = "vm"


@dataclasses.dataclass(frozen=True)
class EngineCacheInfo:
    """Snapshot of the compile caches (see :func:`cache_info`)."""

    program_hits: int          # compile_program served from the LRU
    program_misses: int        # fresh compile walks (+ VM lowerings)
    program_evictions: int
    program_size: int
    vm_fallbacks: int          # vm-mode requests lowered to fused instead
    vm_signatures: int         # distinct VM executables alive
    vm_hits: int               # VM executor-cache hits
    vm_xla_compiles: int       # distinct VM XLA compilations (incl. batch)
    # cache_tag -> {"hits": n, "misses": n} for target-tagged compiles
    # (docs/TARGETS.md); untagged compiles count only in the totals above.
    per_target: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)


def cache_info() -> EngineCacheInfo:
    """Hit/miss/eviction counters for the program LRU plus the VM
    signature-keyed executable cache — the observability handle for the
    "compile the machine once" contract (docs/ENGINE.md).  ``per_target``
    breaks the LRU counters down by compile tag, one per registered
    :mod:`repro.targets` target that has compiled anything."""
    v = _vm_cache_info()
    with _CACHE_LOCK:
        per_target = {tag: {"hits": c[0], "misses": c[1]}
                      for tag, c in _TAG_COUNTS.items()}
    return EngineCacheInfo(
        program_hits=_HITS, program_misses=_MISSES,
        program_evictions=_EVICTIONS, program_size=len(_CACHE),
        vm_fallbacks=_VM_FALLBACKS, vm_signatures=v.signatures,
        vm_hits=v.hits, vm_xla_compiles=v.xla_compiles,
        per_target=per_target)


def _attach_kernel(cp: CompiledProgram, kernel) -> CompiledProgram:
    """Bind a frontend kernel to a (shared, cached) compilation.

    Distinct kernels can emit identical program text with *different*
    operand plans or init data; serving the first kernel's data to the
    second would be silent corruption.  Equivalent kernels (same plan,
    same inits) share the binding; a non-equivalent one poisons it, so
    dict-of-operands execution on this object raises instead of packing
    the wrong kernel's data (``kernel.run()`` is never ambiguous — it
    packs with its own plan before dispatch).
    """
    if kernel is None or kernel is cp.kernel:
        return cp
    import weakref
    with _CACHE_LOCK:
        if cp._kernels_seen is None:
            cp._kernels_seen = weakref.WeakSet()
        if kernel in cp._kernels_seen:
            return cp
        if cp.kernel is None and not cp._kernel_conflict:
            cp.kernel = kernel
        elif cp.kernel is not None and not cp.kernel.equivalent(kernel):
            cp.kernel = None
            cp._kernel_conflict = True
        cp._kernels_seen.add(kernel)
    return cp


def _count_tag(tag: Optional[str], hit: bool) -> None:
    """Record a tagged LRU hit/miss (caller holds ``_CACHE_LOCK``)."""
    if tag is None:
        return
    counts = _TAG_COUNTS.setdefault(tag, [0, 0])
    counts[0 if hit else 1] += 1


def compile_program(program: isa.Program,
                    cfg: MVEConfig | None = None,
                    mode: str | None = None,
                    cache_tag: Optional[str] = None,
                    opt_level: Optional[int] = None) -> CompiledProgram:
    """Compile (with caching) an MVE program for the given machine config.

    Accepts a raw instruction sequence or a frontend
    :class:`~repro.frontend.Kernel` — for kernels, ``run``/``run_batch``
    additionally accept a dict of named operand arrays and results are
    read back by name (``state.operands``).

    The returned :class:`CompiledProgram` is memory-image independent: the
    same object executes any number of images (or a vmapped batch) without
    re-tracing, and repeated calls with an equal program return the cached
    compilation.  ``mode`` selects the executor (default
    :data:`DEFAULT_MODE`): ``"vm"`` shares one XLA executable across every
    program with the same signature; ``"fused"`` emits one jitted function
    per program.  Programs the VM cannot host fall back to fused
    (``cache_info().vm_fallbacks``).

    ``cache_tag`` namespaces the LRU key: compilations made on behalf of
    one :mod:`repro.targets` target (the target's name) never alias —
    or compete in LRU order with — another target's entries for the same
    program text, and ``cache_info().per_target`` reports hits/misses
    per tag.

    ``opt_level`` (default ``None`` = no optimization) runs the program
    through the :mod:`repro.opt` pass pipeline before compilation — the
    optimized text is just another program, so caching, signatures and
    executors compose unchanged (docs/OPTIMIZER.md).
    """
    global _HITS, _MISSES, _EVICTIONS
    cfg = cfg or MVEConfig()
    mode = mode or DEFAULT_MODE
    if mode not in ("vm", "fused"):
        raise ValueError(f"unknown engine mode {mode!r}")
    kernel = None
    if hasattr(program, "plan") and hasattr(program, "program"):
        kernel = program            # a frontend Kernel (duck-typed:
        program = kernel.program    # no core -> frontend import cycle)
    if opt_level:
        from .. import opt          # late: opt sits above core
        program = opt.optimize(program, level=opt_level)
    key = (tuple(program), cfg, mode, cache_tag)
    with _CACHE_LOCK:
        cp = _CACHE.get(key)
        if cp is not None:
            _HITS += 1
            _count_tag(cache_tag, hit=True)
            _CACHE.move_to_end(key)
            return _attach_kernel(cp, kernel)
    # Construct outside the lock: a multi-ms compile walk must not stall
    # concurrent lookups (scheduler submit() runs on many client threads).
    # A racing duplicate construction is possible but harmless — the
    # first insertion wins below and the loser is dropped.
    fire_fault_hook("engine.compile", tier=mode)
    try:
        built = CompiledProgram(program, cfg, mode=mode)
    except _PASSTHROUGH:
        raise
    except Exception as e:
        raise CompileError(f"compile walk failed ({mode} mode): "
                           f"{type(e).__name__}: {e}") from e
    with _CACHE_LOCK:
        cp = _CACHE.get(key)
        if cp is not None:
            _HITS += 1
            _count_tag(cache_tag, hit=True)
            _CACHE.move_to_end(key)
            return _attach_kernel(cp, kernel)
        _MISSES += 1
        _count_tag(cache_tag, hit=False)
        cp = _CACHE[key] = built
        _attach_kernel(cp, kernel)
        if cp.mode != mode:
            # VM-unsupported fallback: alias the fused key too, so an
            # explicit mode="fused" request reuses this compilation
            # instead of walking and tracing the same program again.
            _CACHE.setdefault((key[0], key[1], cp.mode, cache_tag), cp)
        while len(_CACHE) > _CACHE_CAPACITY:
            _CACHE.popitem(last=False)
            _EVICTIONS += 1
    return cp


def clear_cache() -> None:
    """Drop all cached compilations and reset the LRU counters (tests /
    memory pressure).  VM executables persist — clear them separately via
    :func:`repro.core.vm.clear_executors` when measuring cold starts."""
    global _HITS, _MISSES, _EVICTIONS, _VM_FALLBACKS
    with _CACHE_LOCK:
        _CACHE.clear()
        _TAG_COUNTS.clear()
        _HITS = _MISSES = _EVICTIONS = 0
        _VM_FALLBACKS = 0
