"""Compiled MVE execution engine: whole-program compile + fused execution.

The step interpreter (:class:`repro.core.interp.MVEInterpreter`) walks a
program one instruction at a time, paying Python dispatch and host/device
round trips on every step.  This module exploits a structural property of
the ISA: *all* addressing state lives in control registers written by
config instructions with immediate operands, so a single symbolic pass over
the program can resolve every per-instruction address vector, lane mask,
CB mask and :class:`~repro.core.cost.TraceEvent` ahead of time.  What
remains — the data path — is emitted as one fused ``jax.jit`` function for
the whole program, with ``jax.vmap`` support for evaluating the same
program over a batch of memory images.

Static vs dynamic split (design note: docs/ENGINE.md):

  static  — control-register evolution, per-lane addresses of strided
            accesses, dimension/lane/CB masks, trace metadata;
  dynamic — register values, the Tag predicate latch, memory contents,
            and the addresses of random-base accesses (Eq. 1), whose
            pointer arrays are fetched from memory at run time.

Random-base accesses are the one place the trace is data-dependent: their
exact cache-line count depends on the pointer values, so the jitted
function also returns those address vectors and :meth:`CompiledProgram.run`
fills the ``lines`` field after execution.  Everything else about the
trace is emitted at compile time.

Bit-exactness.  The engine must reproduce the step interpreter bit for bit,
but XLA:CPU selects instructions with FP-contraction enabled: any ``fmul``
directly feeding an ``fadd`` in one fused loop becomes an FMA, skipping the
intermediate rounding that per-instruction eager execution performs.  The
fix is architectural rather than a compiler flag (none exists): every
register write-back is guarded by its instruction's *own* dimension-mask
vector, streamed in as run-time data (one row per instruction).  LLVM
cannot prove two mask rows equal, so the selects survive optimization and
no multiply result ever reaches an add without an intervening rounding
point — exactly the semantics of distinct in-cache instructions.

The interpreter remains the semantic oracle: ``tests/test_engine.py``
asserts bit-identical memory results and identical trace events on every
registered pattern.
"""
from __future__ import annotations

import copy
import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import DType, Instr, Op
from .cost import TraceEvent
from .machine import (JNP_DTYPE, ControlState, MVEConfig, apply_config,
                      cbs_touched, flatten_indices, lane_dim_mask,
                      stream_shape, touched_lines)


@dataclasses.dataclass
class _Step:
    """One vector/scalar instruction with its statically resolved context."""

    instr: Instr
    lane_mask: Optional[np.ndarray] = None   # per-lane dimension mask
    cb_mask: Optional[np.ndarray] = None
    event: Optional[TraceEvent] = None
    mask_slot: Optional[int] = None          # row in the runtime mask stack
    addr: Optional[np.ndarray] = None        # static element addresses
    # random-base (Eq. 1) accesses: pointer slice + static inner offsets
    ptr_base: Optional[int] = None
    top_len: Optional[int] = None
    top_idx: Optional[np.ndarray] = None
    offsets: Optional[np.ndarray] = None
    rand_slot: Optional[int] = None          # index into returned addresses


@dataclasses.dataclass
class ExecutionResult:
    """Duck-type compatible with :class:`repro.core.interp.MachineState`."""

    memory: jnp.ndarray
    regs: Dict[int, jnp.ndarray]
    tag: jnp.ndarray
    ctrl: ControlState
    trace: List[TraceEvent]


class CompiledProgram:
    """An MVE program lowered to one fused JAX function + a static trace.

    Use :func:`compile_program` (cached) rather than constructing directly.
    The compiled object is memory-image independent: it executes any image
    of a given size (or a vmapped batch of them) without re-tracing.
    """

    def __init__(self, program: isa.Program, cfg: MVEConfig):
        self.cfg = cfg
        self.program = tuple(program)
        self.steps: List[_Step] = []
        self.n_random = 0
        self._compile_walk()
        masks = [s.lane_mask for s in self.steps if s.mask_slot is not None]
        self._masks = jnp.asarray(np.stack(masks)) if masks else \
            jnp.zeros((0, cfg.lanes), dtype=bool)
        self._zeros = jnp.zeros(cfg.lanes, dtype=jnp.float32)
        self._jit = jax.jit(self._execute)
        self._batch_jit = None

    # -- compilation -------------------------------------------------------
    def _compile_walk(self) -> None:
        """Symbolically execute config ops; resolve every access statically."""
        cfg = self.cfg
        ctrl = ControlState()
        n_masked = 0
        for instr in self.program:
            op = instr.op
            if op in isa.CONFIG_OPS:
                apply_config(ctrl, instr)
                self.steps.append(_Step(instr, event=TraceEvent(
                    op=op, dtype=None, elements=0,
                    cb_mask=np.zeros(cfg.num_cbs, dtype=bool))))
                continue
            if op is Op.SCALAR:
                self.steps.append(_Step(instr, event=TraceEvent(
                    op=op, dtype=None, elements=0,
                    cb_mask=np.zeros(cfg.num_cbs, dtype=bool),
                    scalar_count=instr.scalar_count)))
                continue

            dims = ctrl.active_dims()
            lane_mask = lane_dim_mask(dims, ctrl.dim_mask, cfg.lanes)
            cbm = cbs_touched(dims, ctrl.dim_mask, cfg)
            elements = int(lane_mask.sum())
            step = _Step(instr, lane_mask=lane_mask, cb_mask=cbm,
                         mask_slot=n_masked)
            n_masked += 1

            if op in isa.MEMORY_OPS:
                store = op in (Op.SST, Op.RST)
                random = op in (Op.RLD, Op.RST)
                strides = ctrl.resolve_strides(instr.modes or (), store)
                run, segs, uniq = stream_shape(dims, strides, cfg.lanes)
                coords = flatten_indices(dims, cfg.lanes)
                if random:
                    top_len = dims[-1]
                    offsets = np.zeros(cfg.lanes, dtype=np.int64)
                    for d in range(len(dims) - 1):
                        offsets += np.where(coords[:, d] >= 0,
                                            coords[:, d], 0) * strides[d]
                    step.ptr_base = instr.base
                    step.top_len = top_len
                    step.top_idx = np.clip(coords[:, len(dims) - 1],
                                           0, top_len - 1)
                    step.offsets = offsets
                    step.rand_slot = self.n_random
                    self.n_random += 1
                    lines = 0          # filled from run-time addresses
                else:
                    addr = np.full(cfg.lanes, instr.base, dtype=np.int64)
                    for d in range(len(dims)):
                        addr += np.where(coords[:, d] >= 0,
                                         coords[:, d], 0) * strides[d]
                    step.addr = addr
                    lines = touched_lines(addr, lane_mask,
                                          instr.dtype.nbytes)
                step.event = TraceEvent(op, instr.dtype, elements, cbm,
                                        segments=segs, contiguous_run=run,
                                        unique_elements=uniq, lines=lines)
            else:
                step.event = TraceEvent(op, instr.dtype, elements, cbm)
            self.steps.append(step)
        self.final_ctrl = copy.deepcopy(ctrl)

    # -- fused data path ---------------------------------------------------
    def _execute(self, memory, masks, zeros):
        """The whole program as one traced JAX computation.

        Mirrors :meth:`MVEInterpreter._step` semantics exactly (the
        equivalence tests depend on it) with all addressing constant-folded.
        ``masks`` carries one dimension-mask row per vector instruction and
        ``zeros`` the power-on register value; both arrive as run-time data
        so each write-back keeps its own rounding point (see the module
        docstring on FP contraction).
        """
        cfg = self.cfg
        regs: Dict[int, jnp.ndarray] = {}
        tag = jnp.ones(cfg.lanes, dtype=bool)
        rand_addrs: List[jnp.ndarray] = [None] * self.n_random
        hi = memory.shape[0] - 1

        for step in self.steps:
            instr = step.instr
            op = instr.op
            if op in isa.CONFIG_OPS or op is Op.SCALAR:
                continue

            dt = JNP_DTYPE.get(instr.dtype, jnp.float32)
            jmask = masks[step.mask_slot]

            def old(vd, dt=dt):
                v = regs.get(vd)
                return (zeros if v is None else v).astype(dt)

            if op in (Op.SLD, Op.RLD):
                addr = self._address_vector(step, memory)
                if step.rand_slot is not None:
                    rand_addrs[step.rand_slot] = addr
                gathered = memory[jnp.clip(addr, 0, hi)].astype(dt)
                regs[instr.vd] = jnp.where(jmask, gathered, old(instr.vd))
                continue
            if op in (Op.SST, Op.RST):
                addr = self._address_vector(step, memory)
                if step.rand_slot is not None:
                    rand_addrs[step.rand_slot] = addr
                src = old(instr.vs1)
                # Drop masked lanes; later lanes win on address collisions
                # (well-defined scatter order, matches a sequential loop).
                idx = jnp.where(jmask, addr, -1)
                valid = idx >= 0
                safe_idx = jnp.where(valid, idx, 0)
                mem_dt = memory.dtype
                update = jnp.where(valid, src.astype(mem_dt),
                                   memory[safe_idx])
                memory = memory.at[safe_idx].set(update)
                continue

            def finish(result, instr=instr, jmask=jmask, dt=dt, old=old):
                result = result.astype(dt)
                keep = jmask
                if instr.predicated:
                    keep = keep & tag
                regs[instr.vd] = jnp.where(keep, result, old(instr.vd))

            if op is Op.SET_DUP:
                finish(jnp.full(cfg.lanes, instr.imm, dtype=dt))
                continue
            if op is Op.CPY:
                finish(old(instr.vs1))
                continue
            if op is Op.CVT:
                v = regs.get(instr.vs1)
                src = zeros if v is None else v
                finish(src.astype(dt))
                continue

            a = old(instr.vs1)
            b = old(instr.vs2) if instr.vs2 is not None else None

            if op is Op.ADD:
                finish(a + b)
            elif op is Op.SUB:
                finish(a - b)
            elif op is Op.MUL:
                finish(a * b)
            elif op is Op.MIN:
                finish(jnp.minimum(a, b))
            elif op is Op.MAX:
                finish(jnp.maximum(a, b))
            elif op is Op.XOR:
                finish(a ^ b)
            elif op is Op.AND:
                finish(a & b)
            elif op is Op.OR:
                finish(a | b)
            elif op is Op.SHI:
                if instr.dtype.is_float:
                    raise ValueError("shift on float register")
                amt = instr.imm
                finish(a << amt if amt >= 0 else a >> (-amt))
            elif op is Op.ROTI:
                bits = instr.dtype.bits
                amt = instr.imm % bits
                ua = a.astype(jnp.uint32 if bits <= 32 else jnp.uint64)
                finish(((ua << amt) | (ua >> (bits - amt))).astype(dt))
            elif op is Op.SHR:
                finish(a << b.astype(jnp.int32))
            elif op in isa.COMPARE_OPS:
                cmp = {Op.GT: a > b, Op.GTE: a >= b, Op.LT: a < b,
                       Op.LTE: a <= b, Op.EQ: a == b, Op.NEQ: a != b}[op]
                tag = jnp.where(jmask, cmp, tag)
            else:
                raise NotImplementedError(f"op {op}")

        return memory, regs, tag, rand_addrs

    @staticmethod
    def _address_vector(step: _Step, memory):
        """Element addresses: constant for strided, traced for random-base
        (the pointer array is part of the data, Eq. 1)."""
        if step.addr is not None:
            return jnp.asarray(step.addr)
        ptrs = memory[step.ptr_base: step.ptr_base + step.top_len]
        ptrs = ptrs.astype(jnp.int32)
        return ptrs[step.top_idx] + jnp.asarray(step.offsets)

    # -- public API --------------------------------------------------------
    def run(self, memory) -> Tuple[jnp.ndarray, ExecutionResult]:
        """Execute on one memory image; returns ``(memory, state)`` exactly
        like :meth:`MVEInterpreter.run` (trace included)."""
        mem, regs, tag, rand_addrs = self._jit(
            jnp.asarray(memory), self._masks, self._zeros)
        trace = self._finalize_trace(rand_addrs)
        # Fresh ctrl/trace objects per run: callers may mutate the returned
        # state (the stepwise oracle hands out fresh state too), and this
        # CompiledProgram is shared through the compile cache.
        state = ExecutionResult(memory=mem, regs=dict(regs), tag=tag,
                                ctrl=copy.deepcopy(self.final_ctrl),
                                trace=trace)
        return mem, state

    def run_batch(self, memories) -> Tuple[jnp.ndarray,
                                           Dict[int, jnp.ndarray],
                                           jnp.ndarray]:
        """vmap the fused program over a leading batch of memory images.

        Returns ``(memories, regs, tag)`` with a leading batch axis on
        every array.  No trace is produced: the cost-model trace of a
        batched run is that of any single element (and for random-base
        programs each element may touch different cache lines — use
        :meth:`run` on a representative image to price it).
        """
        if self._batch_jit is None:
            self._batch_jit = jax.jit(
                jax.vmap(self._execute, in_axes=(0, None, None)))
        mem, regs, tag, _ = self._batch_jit(
            jnp.asarray(memories), self._masks, self._zeros)
        return mem, dict(regs), tag

    def _finalize_trace(self, rand_addrs) -> List[TraceEvent]:
        trace: List[TraceEvent] = []
        for step in self.steps:
            ev = step.event
            if step.rand_slot is not None:
                addr = np.asarray(rand_addrs[step.rand_slot],
                                  dtype=np.int64)
                ev = dataclasses.replace(ev, lines=touched_lines(
                    addr, step.lane_mask, step.instr.dtype.nbytes))
            else:
                ev = dataclasses.replace(ev)
            trace.append(ev)
        return trace

    @property
    def static_trace(self) -> List[TraceEvent]:
        """The compile-time trace; exact unless the program uses
        random-base accesses (then run() fills the ``lines`` fields)."""
        return [s.event for s in self.steps]


# ---------------------------------------------------------------------------
# Compile cache: programs are tuples of frozen Instr, so they hash.  Bounded
# LRU — data-dependent program streams (e.g. one program per sparsity
# pattern) would otherwise retain a jitted executable per variant forever.
# ---------------------------------------------------------------------------

_CACHE: "OrderedDict[Tuple[Tuple[Instr, ...], MVEConfig], CompiledProgram]" \
    = OrderedDict()
_CACHE_CAPACITY = 256


def compile_program(program: isa.Program,
                    cfg: MVEConfig | None = None) -> CompiledProgram:
    """Compile (with caching) an MVE program for the given machine config.

    The returned :class:`CompiledProgram` is memory-image independent: the
    same object executes any number of images (or a vmapped batch) without
    re-tracing, and repeated calls with an equal program return the cached
    compilation.
    """
    cfg = cfg or MVEConfig()
    key = (tuple(program), cfg)
    cp = _CACHE.get(key)
    if cp is None:
        cp = _CACHE[key] = CompiledProgram(program, cfg)
        while len(_CACHE) > _CACHE_CAPACITY:
            _CACHE.popitem(last=False)
    else:
        _CACHE.move_to_end(key)
    return cp


def clear_cache() -> None:
    """Drop all cached compilations (tests / memory pressure)."""
    _CACHE.clear()
