"""Common data-parallel patterns of Section IV, as frontend-built kernels.

Each pattern models one representative kernel of the 12 Swan libraries
(Table III).  A pattern supplies:

  * an MVE program built with the *kernel frontend*
    (:mod:`repro.frontend`, docs/FRONTEND.md): named tensor operands,
    dimension scopes and operator-overloaded vector handles instead of
    hand-assigned register numbers and raw base offsets.  The emitted
    programs are instruction-for-instruction equivalent (modulo the
    register renaming chosen by the allocator) to the original
    hand-coded instruction lists, which live on as equivalence
    references in ``tests/legacy_patterns.py``;
  * an initial flat memory image and a correctness check (numpy oracle)
    that reads results back *by operand name*;
  * an analytic workload descriptor for the packed-SIMD (Neon) and GPU
    baseline cost models of Figure 7/8/9.

The RVV baseline trace for the same pattern is obtained by lowering the
MVE program with :func:`repro.core.rvv.compile_to_rvv`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from . import isa
from .isa import DType
from .machine import MVEConfig
from ..frontend import BCAST, CR, DERIVED, SEQ, Kernel, KernelBuilder

LANES = MVEConfig().lanes  # 8192


@dataclasses.dataclass
class NeonWork:
    vector_ops: float      # SIMD ops per element
    elements: float        # total elements processed
    bits: int
    mem_bytes: float


@dataclasses.dataclass
class PatternRun:
    name: str
    library: str
    dim: str                                  # "1D" / "2D" / "3D" / "4D"
    program: isa.Program
    memory: np.ndarray
    check: Callable[[np.ndarray, object], None]
    neon: NeonWork
    flops: float = 0.0                        # for the GPU model
    copy_bytes: float = 0.0
    kernel: Optional[Kernel] = None           # the frontend build

    def results(self, mem_after) -> Dict[str, np.ndarray]:
        """Named result tensors of an executed memory image."""
        return self.kernel.unpack(mem_after)


def _pattern(kernel: Kernel, library: str, dim: str,
             check: Callable[[np.ndarray, object], None], neon: NeonWork,
             flops: float = 0.0, copy_bytes: float = 0.0,
             memory: Optional[np.ndarray] = None) -> PatternRun:
    """Shared PatternRun construction: program + packed memory from one
    built kernel (``memory`` overrides for pointer tables that need the
    planner's layout — see ``upsample``)."""
    return PatternRun(kernel.name, library, dim, kernel.program,
                      kernel.pack() if memory is None else memory,
                      check, neon, flops, copy_bytes, kernel=kernel)


# ---------------------------------------------------------------------------
# 1. Linpack: daxpy (1D)                        y[i] += alpha * x[i]
# ---------------------------------------------------------------------------

def daxpy(n: int = LANES, seed: int = 0) -> PatternRun:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    alpha = 1.5
    expected = y + np.float32(alpha) * x

    b = KernelBuilder("daxpy")
    xo = b.input("x", (n,), DType.F, init=x)
    yo = b.inout("y", (n,), DType.F, init=y)
    b.width(32)
    with b.dims(n):
        b.scalar(4)
        vx = xo.load(SEQ)
        vy = yo.load(SEQ)
        vy += alpha * vx
        yo.store(vy, SEQ)
    k = b.build()

    def check(mem_after, state):
        np.testing.assert_allclose(k.unpack(mem_after)["y"], expected,
                                   rtol=1e-5)

    return _pattern(k, "Linpack", "1D", check,
                    NeonWork(vector_ops=2, elements=n, bits=32,
                             mem_bytes=3 * 4 * n),
                    flops=2 * n, copy_bytes=8 * n)


# ---------------------------------------------------------------------------
# 2. XNNPACK: row-wise GEMM with multi-dimensional replication (Section IV)
# ---------------------------------------------------------------------------

def gemm(n_rows: int = 128, k: int = 16, m: int = 64, seed: int = 1,
         lanes: int = LANES, dtype: DType = DType.F) -> PatternRun:
    """C[N,M] = A[N,K] @ B[K,M] with input/weight replication (2D).

    ``dtype=DType.W`` gives the quantized-CNN (int16) variant used for
    the Figure 9 GPU-crossover sweep."""
    rng = np.random.default_rng(seed)
    if dtype is DType.W:
        a = rng.integers(-8, 8, (n_rows, k)).astype(np.float32)
        w = rng.integers(-8, 8, (k, m)).astype(np.float32)
    else:
        a = rng.standard_normal((n_rows, k)).astype(np.float32)
        w = rng.standard_normal((k, m)).astype(np.float32)
    rows_per_iter = min(lanes // m, n_rows, 256)
    expected = (a @ w).astype(np.float32)

    b = KernelBuilder("gemm")
    ao = b.input("a", (n_rows, k), dtype, init=a)
    wo = b.input("b", (k, m), dtype, init=w)
    co = b.output("c", (n_rows, m), dtype)
    b.width(dtype.bits)
    # input column stride (CR d1) = K; output row stride (CR d1) = M
    with b.dims(m, rows_per_iter, ld_strides={1: k}, st_strides={1: m}):
        for n0 in range(0, n_rows, rows_per_iter):
            b.scalar(6)                       # loop + addressing
            acc = b.const(dtype, 0)
            for kk in range(k):
                b.scalar(4)
                # input column A[n0:n0+R, kk] replicated horizontally
                col = ao.at(n0, kk).load(BCAST, CR)
                # weight row B[kk, :] replicated vertically
                row = wo.at(kk, 0).load(SEQ, BCAST)
                acc += col * row
            # store R output rows sequentially (S0=1, S1=M via mode 2)
            co.at(n0, 0).store(acc, SEQ, DERIVED)
    kern = b.build()

    def check(mem_after, state):
        got = kern.unpack(mem_after)["c"]
        np.testing.assert_allclose(got, expected, rtol=1e-4, atol=1e-4)

    flops = 2.0 * n_rows * k * m
    return _pattern(kern, "XNNPACK", "2D", check,
                    NeonWork(vector_ops=2 * k, elements=n_rows * m, bits=32,
                             mem_bytes=4.0 * (n_rows * k + k * m +
                                              n_rows * m)),
                    flops=flops,
                    copy_bytes=4.0 * (n_rows * k + k * m + n_rows * m))


# ---------------------------------------------------------------------------
# 3. XNNPACK: SpMM — CSR sparse inputs, random weight-row loads (Section IV)
# ---------------------------------------------------------------------------

def spmm(rows: int = 64, cols: int = 64, m: int = 64, density: float = 0.25,
         seed: int = 2, lanes: int = LANES) -> PatternRun:
    """out[r,:] = sum_nz A[r,c] * W[c,:] using random-base loads."""
    rng = np.random.default_rng(seed)
    a = (rng.random((rows, cols)) < density) * \
        rng.standard_normal((rows, cols))
    a = a.astype(np.float32)
    w = rng.standard_normal((cols, m)).astype(np.float32)
    expected = (a @ w).astype(np.float32)

    nnz_r, nnz_c = np.nonzero(a)
    nnz_v = a[nnz_r, nnz_c]
    group = min(lanes // m, 256)

    b = KernelBuilder("spmm")
    wo = b.input("w", (cols, m), DType.F, init=w)
    vo = b.input("values", (len(nnz_v),), DType.F, init=nnz_v)
    # "Core computes the weight row addresses corresponding to non-zero
    # input cells" — the pointer array the random load walks.
    po = b.input("row_ptrs", (len(nnz_v),), DType.F,
                 init=wo.addr(nnz_c * m))
    oo = b.output("partial", (len(nnz_v), m), DType.F)
    b.width(32)
    i = 0
    while i < len(nnz_v):
        g = min(group, len(nnz_v) - i)
        b.scalar(8)
        b.dims(m, g)
        # nnz values replicated horizontally from a strided load
        val = vo.at(i).load(BCAST, SEQ)
        # weight rows from random base pointers, sequential inner dim
        wrow = po.at(i).rload(SEQ)
        prod = val * wrow
        # store partial products; combined on the scalar core per-row
        oo.at(i, 0).store(prod, SEQ, DERIVED)
        b.scalar(2 * g)
        i += g
    kern = b.build()

    def check(mem_after, state):
        partial = kern.unpack(mem_after)["partial"]
        got = np.zeros((rows, m), dtype=np.float32)
        for j, r in enumerate(nnz_r):
            got[r] += partial[j].astype(np.float32)
        np.testing.assert_allclose(got, expected, rtol=1e-4, atol=1e-4)

    flops = 2.0 * len(nnz_v) * m
    return _pattern(kern, "XNNPACK", "2D", check,
                    NeonWork(vector_ops=2 * density * cols,
                             elements=rows * m, bits=32,
                             mem_bytes=4.0 * (len(nnz_v) * (m + 2) +
                                              rows * m)),
                    flops=flops,
                    copy_bytes=4.0 * (cols * m + 2 * len(nnz_v)))


# ---------------------------------------------------------------------------
# 4. CMSIS-DSP: FIR filter (1D, multiple shifted loads)
# ---------------------------------------------------------------------------

def fir(n: int = LANES, taps: int = 16, seed: int = 3) -> PatternRun:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n + taps).astype(np.float32)
    h = rng.standard_normal(taps).astype(np.float32)
    expected = np.stack([x[t:t + n] for t in range(taps)], 0).T @ h

    b = KernelBuilder("fir")
    xo = b.input("x", (n + taps,), DType.F, init=x)
    yo = b.output("y", (n + taps,), DType.F)
    b.width(32)
    with b.dims(n):
        acc = b.const(DType.F, 0.0)
        for t in range(taps):
            b.scalar(3)
            acc += xo.at(t).load(SEQ) * float(h[t])
        yo.store(acc, SEQ)
    k = b.build()

    def check(mem_after, state):
        np.testing.assert_allclose(k.unpack(mem_after)["y"][:n],
                                   expected, rtol=1e-4, atol=1e-4)

    return _pattern(k, "CMSIS-DSP", "1D", check,
                    NeonWork(vector_ops=2 * taps, elements=n, bits=32,
                             mem_bytes=4.0 * (taps * n / 4 + 2 * n)),
                    flops=2.0 * taps * n, copy_bytes=8.0 * n)


# ---------------------------------------------------------------------------
# 5. Kvazaar: intra-picture prediction (3D strided load, Figure 3)
# ---------------------------------------------------------------------------

def intra_pred(blocks: int = 256, seed: int = 4) -> PatternRun:
    """3D load with S=(1,0,3): each 3-pel reference row is replicated down
    a 3x3 predicted block (Figure 3), then averaged with a second ref."""
    bs = 3
    refs = np.random.default_rng(seed).integers(
        0, 255, size=(blocks, bs)).astype(np.int32)
    refs2 = np.random.default_rng(seed + 1).integers(
        0, 255, size=(blocks, bs)).astype(np.int32)
    # predicted[b, y, x] = (ref1[b, x] + ref2[b, y]) >> 1  (planar-ish)
    expected = (refs[:, None, :] + refs2[:, :, None]) >> 1

    b = KernelBuilder("intra_pred")
    r1 = b.input("ref1", (blocks, bs), DType.W, init=refs)
    r2 = b.input("ref2", (blocks, bs), DType.W, init=refs2)
    out = b.output("pred", (blocks, bs, bs), DType.W)
    b.width(32)
    with b.dims(bs, bs, blocks, ld_strides={2: bs}):
        b.scalar(6)
        # ref row replicated down the column dim: S = (1, 0, 3)
        row = r1.load(SEQ, BCAST, CR)
        # ref col replicated across the row dim: S = (0, 1, 3)
        col = r2.load(BCAST, SEQ, CR)
        pred = row + col
        pred >>= 1
        out.store(pred, SEQ, DERIVED, DERIVED)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["pred"].astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    n = blocks * bs * bs
    return _pattern(k, "Kvazaar", "3D", check,
                    NeonWork(vector_ops=3, elements=n, bits=16,
                             mem_bytes=4.0 * (2 * blocks * bs + n)),
                    flops=2.0 * n, copy_bytes=4.0 * n)


# ---------------------------------------------------------------------------
# 6. libjpeg: h2v2 upsample (random base + replication, Figure 4)
# ---------------------------------------------------------------------------

def upsample(rows: int = 32, m: int = 128, seed: int = 5) -> PatternRun:
    """Each pixel replicated 2x horizontally; vertical replication via
    duplicated row pointers (the paper's 4th random dimension)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 255, size=(rows, m)).astype(np.int32)
    # rows live at "random" (shuffled) locations, like libjpeg row pointers
    row_order = rng.permutation(rows)
    mem_rows = np.zeros(rows * m)
    slot_of = np.zeros(rows, dtype=np.int64)
    for slot, r in enumerate(row_order):
        mem_rows[slot * m:(slot + 1) * m] = img[r]
        slot_of[r] = slot * m
    expected = np.repeat(np.repeat(img, 2, axis=0), 2, axis=1)

    group = max(1, min(LANES // (2 * m), 2 * rows, 256))
    b = KernelBuilder("upsample")
    ro = b.input("rows", (rows, m), DType.B, init=mem_rows)
    # input pointer per *output* row (each input row appears twice);
    # pointer operands carry the dtype of the data they point at
    ip = b.input("in_ptrs", (2 * rows,), DType.B,
                 init=ro.addr(np.repeat(slot_of, 2)))
    op_ = b.input("out_ptrs", (2 * rows,), DType.B)
    out = b.output("out", (2 * rows, 2 * m), DType.B)
    b.width(32)
    for n0 in range(0, 2 * rows, group):
        g = min(group, 2 * rows - n0)
        b.scalar(6)
        b.dims(2, m, g)
        # load: replicate 2x (S0=0), pixels sequential (S1=1),
        # random row base from the pointer array
        px = ip.at(n0).rload(BCAST, SEQ)
        # store: sequential (S0=1), row-major (S1=2 -> derived 2),
        # random output row base
        op_.at(n0).rstore(px, SEQ, DERIVED)
    k = b.build()
    # the output-row pointer table points into the planner-assigned
    # "out" region — fill it through pack() overrides
    memory = k.pack({"out_ptrs": out.addr(np.arange(2 * rows) * (2 * m))})

    def check(mem_after, state):
        got = k.unpack(mem_after)["out"].astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    n = rows * m
    return _pattern(k, "libjpeg", "4D", check,
                    NeonWork(vector_ops=3, elements=4 * n, bits=8,
                             mem_bytes=5.0 * n),
                    flops=4.0 * n, copy_bytes=5.0 * n, memory=memory)


# ---------------------------------------------------------------------------
# 7. libpng: "up" defilter — rows at random pointers (2D random)
# ---------------------------------------------------------------------------

def png_up(rows: int = 64, width: int = 128, seed: int = 6) -> PatternRun:
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    prior = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    expected = (raw + prior) & 0xFF

    group = max(1, min(LANES // width, rows, 256))
    b = KernelBuilder("png_up")
    ro = b.input("raw", (rows, width), DType.B, init=raw)
    po = b.input("prior", (rows, width), DType.B, init=prior)
    rp = b.input("raw_ptrs", (rows,), DType.B,
                 init=ro.addr(np.arange(rows) * width))
    pp = b.input("prior_ptrs", (rows,), DType.B,
                 init=po.addr(np.arange(rows) * width))
    out = b.output("out", (rows, width), DType.B)
    b.width(32)
    for r0 in range(0, rows, group):
        g = min(group, rows - r0)
        b.scalar(5)
        b.dims(width, g)
        vr = rp.at(r0).rload(SEQ)
        vp = pp.at(r0).rload(SEQ)
        s = vr + vp                        # uint8 wrap == & 0xFF
        out.at(r0, 0).store(s, SEQ, DERIVED)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["out"].astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    n = rows * width
    return _pattern(k, "libpng", "2D", check,
                    NeonWork(vector_ops=3, elements=n, bits=8,
                             mem_bytes=3.0 * n),
                    flops=float(n), copy_bytes=3.0 * n)


# ---------------------------------------------------------------------------
# 8. libwebp: RGB -> gray (strided channel loads)
# ---------------------------------------------------------------------------

def rgb2gray(pixels: int = LANES, seed: int = 7) -> PatternRun:
    rng = np.random.default_rng(seed)
    rgb = rng.integers(0, 255, size=(pixels, 3)).astype(np.int32)
    expected = (5 * rgb[:, 0] + 9 * rgb[:, 1] + 2 * rgb[:, 2]) >> 4

    b = KernelBuilder("rgb2gray")
    px = b.input("rgb", (pixels, 3), DType.W, init=rgb)
    out = b.output("gray", (pixels,), DType.W)
    b.width(16)
    with b.dims(pixels, ld_strides={0: 3}):
        b.scalar(4)
        r = px.at(0, 0).load(CR)           # R, stride 3
        g = px.at(0, 1).load(CR)           # G
        bl = px.at(0, 2).load(CR)          # B
        r *= 5
        g *= 9
        bl *= 2
        r += g
        r += bl
        r >>= 4
        out.store(r, SEQ)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["gray"].astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    return _pattern(k, "libwebp", "1D", check,
                    NeonWork(vector_ops=10, elements=pixels, bits=16,
                             mem_bytes=4.0 * pixels),
                    flops=6.0 * pixels, copy_bytes=4.0 * pixels)


# ---------------------------------------------------------------------------
# 9. Skia: alpha blend (8-bit pixels, 2D rows)
# ---------------------------------------------------------------------------

def alpha_blend(rows: int = 64, width: int = 128, seed: int = 8
                ) -> PatternRun:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    dst = rng.integers(0, 255, size=(rows, width)).astype(np.int32)
    alpha = 6                        # 4-bit alpha: 6/16 src + 10/16 dst
    expected = (src * alpha + dst * (16 - alpha)) >> 4

    b = KernelBuilder("alpha_blend")
    so = b.input("src", (rows, width), DType.W, init=src)
    do = b.inout("dst", (rows, width), DType.W, init=dst)
    b.width(32)
    with b.dims(width, rows):
        b.scalar(4)
        s = so.load(SEQ, DERIVED)
        d = do.load(SEQ, DERIVED)
        s *= alpha
        d *= 16 - alpha
        s += d
        s >>= 4
        do.store(s, SEQ, DERIVED)
    k = b.build()

    n = rows * width

    def check(mem_after, state):
        got = k.unpack(mem_after)["dst"].astype(np.int64)
        np.testing.assert_array_equal(got, expected)

    return _pattern(k, "Skia", "2D", check,
                    NeonWork(vector_ops=8, elements=n, bits=8,
                             mem_bytes=3.0 * n),
                    flops=4.0 * n, copy_bytes=3.0 * n)


# ---------------------------------------------------------------------------
# 10. webaudio: multi-channel chunk mixing (3D)
# ---------------------------------------------------------------------------

def audio_mix(chunks: int = 16, channels: int = 4, samples: int = 128,
              seed: int = 9) -> PatternRun:
    """Processes multiple 128-sample chunks at once — the paper's flagship
    example of limited 1D DLP (Section I: webaudio exposes only 128)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((chunks, channels, samples)).astype(np.float32)
    c = rng.standard_normal((chunks, channels, samples)).astype(np.float32)
    gain = 0.7
    expected = (a + c) * np.float32(gain)

    b = KernelBuilder("audio_mix")
    ao = b.input("a", (chunks, channels, samples), DType.F, init=a)
    bo = b.input("b", (chunks, channels, samples), DType.F, init=c)
    out = b.output("out", (chunks, channels, samples), DType.F)
    b.width(32)
    with b.dims(samples, channels, chunks):
        b.scalar(5)
        va = ao.load(SEQ, DERIVED, DERIVED)
        vb = bo.load(SEQ, DERIVED, DERIVED)
        va += vb
        b.keep(vb)          # the mixer holds the second input resident
        va *= gain
        out.store(va, SEQ, DERIVED, DERIVED)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["out"]
        np.testing.assert_allclose(got, expected, rtol=1e-5)

    n = chunks * channels * samples
    return _pattern(k, "webaudio", "3D", check,
                    NeonWork(vector_ops=2, elements=n, bits=32,
                             mem_bytes=12.0 * n),
                    flops=2.0 * n, copy_bytes=12.0 * n)


# ---------------------------------------------------------------------------
# 11. zlib: adler32-style reduction (dimension-level masked tree, Section IV)
# ---------------------------------------------------------------------------

def reduction(n: int = LANES, seed: int = 10, floor: int = 256
              ) -> PatternRun:
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 255, size=n).astype(np.int64)
    expected_sum = int(x.sum())

    b = KernelBuilder("reduction")
    xo = b.input("x", (n,), DType.DW, init=x)
    tmp = b.scratch("tmp", (n // 2,), DType.DW)
    out = b.output("partial", (floor,), DType.DW)
    b.width(32)
    b.dims(n)
    b.scalar(3)
    acc = xo.load(SEQ)
    m = n
    while m > floor:
        half = m // 2
        b.scalar(4)
        # Split M lanes into 2 halves along a fresh highest dim and
        # mask off the first one (Section IV reduction snippet): the
        # unmasked half lands at the start of the scratch region.
        b.dims(half, 2)
        with b.masked_off(0):
            xo.at(n - half).store(acc, SEQ, DERIVED)
        b.dims(half)
        acc += tmp.load(SEQ)
        m = half
    b.dims(floor)
    out.store(acc, SEQ)
    b.scalar(floor)          # final scalar-core reduction
    k = b.build()

    def check(mem_after, state):
        got = int(k.unpack(mem_after)["partial"].sum())
        assert got == expected_sum, (got, expected_sum)

    return _pattern(k, "zlib", "1D", check,
                    NeonWork(vector_ops=2, elements=n, bits=32,
                             mem_bytes=4.0 * n),
                    flops=float(n), copy_bytes=4.0 * n)


# ---------------------------------------------------------------------------
# 12. boringssl: XOR stream cipher with key replication (2D)
# ---------------------------------------------------------------------------

def xor_cipher(blocks: int = 256, key_len: int = 32, seed: int = 11
               ) -> PatternRun:
    rng = np.random.default_rng(seed)
    pt = rng.integers(0, 255, size=(blocks, key_len)).astype(np.int64)
    key = rng.integers(0, 255, size=key_len).astype(np.int64)
    expected = pt ^ key[None, :]

    b = KernelBuilder("xor_cipher")
    po = b.input("plaintext", (blocks, key_len), DType.B, init=pt)
    ko = b.input("key", (key_len,), DType.B, init=key)
    co = b.output("ciphertext", (blocks, key_len), DType.B)
    b.width(8)
    with b.dims(key_len, blocks):
        b.scalar(4)
        vp = po.load(SEQ, DERIVED)
        vk = ko.load(SEQ, BCAST)          # key replicated (S1=0)
        co.store(vp ^ vk, SEQ, DERIVED)
    k = b.build()

    n = blocks * key_len

    def check(mem_after, state):
        got = k.unpack(mem_after)["ciphertext"].astype(np.int64)
        np.testing.assert_array_equal(got & 0xFF, expected)

    return _pattern(k, "boringssl", "2D", check,
                    NeonWork(vector_ops=1, elements=n, bits=8,
                             mem_bytes=2.0 * n),
                    flops=float(n), copy_bytes=2.0 * n)


# ---------------------------------------------------------------------------
# 13. Arm optimized routines: memcpy (1D bytes)
# ---------------------------------------------------------------------------

def memcpy(n: int = LANES, seed: int = 12) -> PatternRun:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 255, size=n).astype(np.int64)

    b = KernelBuilder("memcpy")
    so = b.input("src", (n,), DType.B, init=src)
    do = b.output("dst", (n,), DType.B)
    b.width(8)
    with b.dims(n):
        b.scalar(2)
        do.store(so.load(SEQ), SEQ)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["dst"].astype(np.int64)
        np.testing.assert_array_equal(got & 0xFF, src)

    return _pattern(k, "ArmRoutines", "1D", check,
                    NeonWork(vector_ops=0.5, elements=n, bits=8,
                             mem_bytes=2.0 * n),
                    flops=0.0, copy_bytes=2.0 * n)


# ---------------------------------------------------------------------------
# 14. Matrix transpose (Section IV; XNNPACK 512x49 MobileNet-V1 case)
# ---------------------------------------------------------------------------

def transpose(m: int = 512, n: int = 49, seed: int = 13) -> PatternRun:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n)).astype(np.float32)
    expected = a.T.copy()

    cols_per_iter = max(1, min(LANES // m, 256))
    b = KernelBuilder("transpose")
    ao = b.input("a", (m, n), DType.F, init=a)
    out = b.output("out", (n, m), DType.F)
    b.width(32)
    with b.dims(m, cols_per_iter, ld_strides={0: n}, st_strides={1: m}):
        for i in range(0, n, cols_per_iter):
            c = min(cols_per_iter, n - i)
            if c != cols_per_iter:
                b.dim_length(1, c)
            b.scalar(4)
            # load c columns: element (y,x) <- input[x, i+y]
            v = ao.at(0, i).load(CR, SEQ)
            # store c rows of output: element (y,x) -> output[i+y, x]
            out.at(i, 0).store(v, SEQ, CR)
    k = b.build()

    def check(mem_after, state):
        got = k.unpack(mem_after)["out"]
        np.testing.assert_allclose(got, expected, rtol=1e-6)

    return _pattern(k, "XNNPACK", "2D", check,
                    NeonWork(vector_ops=1.5, elements=m * n, bits=32,
                             mem_bytes=8.0 * m * n),
                    flops=0.0, copy_bytes=8.0 * m * n)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PATTERNS: Dict[str, Callable[..., PatternRun]] = {
    "daxpy": daxpy,
    "gemm": gemm,
    "spmm": spmm,
    "fir": fir,
    "intra_pred": intra_pred,
    "upsample": upsample,
    "png_up": png_up,
    "rgb2gray": rgb2gray,
    "alpha_blend": alpha_blend,
    "audio_mix": audio_mix,
    "reduction": reduction,
    "xor_cipher": xor_cipher,
    "memcpy": memcpy,
    "transpose": transpose,
}

# Kernels used for the detailed RVV comparison (Figure 10/11 selects kernels
# "with various dimensions and data-parallel patterns").
RVV_COMPARISON_SET = ["daxpy", "reduction", "fir", "xor_cipher", "png_up",
                      "alpha_blend", "gemm", "transpose", "audio_mix",
                      "intra_pred", "upsample"]


# ---------------------------------------------------------------------------
# Execution entry points (compiled engine by default; docs/ENGINE.md)
# ---------------------------------------------------------------------------

def run_pattern(run: PatternRun, cfg: MVEConfig | None = None,
                compiled: bool = True, mode: str | None = None):
    """Execute one pattern; returns ``(mem_after, state)``.

    ``compiled=True`` goes through :func:`repro.core.engine.compile_program`
    (cached); ``compiled=False`` uses the step-interpreter oracle.  Both
    return interchangeable state objects carrying the cost-model trace.
    ``mode`` selects the compiled executor (``"vm"``/``"fused"``; ``None``
    = engine default, the signature-shared VM).
    """
    cfg = cfg or MVEConfig()
    if compiled:
        from .engine import compile_program
        return compile_program(run.program, cfg, mode=mode).run(run.memory)
    from .interp import MVEInterpreter
    return MVEInterpreter(cfg, compiled=False).run_stepwise(
        run.program, run.memory)


def sweep(names: Optional[Sequence[str]] = None,
          cfg: MVEConfig | None = None, compiled: bool = True,
          validate: bool = True, mode: str | None = None,
          ) -> Dict[str, Tuple[PatternRun, object]]:
    """Run every named pattern (default: all) and return name -> (run,
    state).  This is the fast path for full-library sweeps: under the VM
    every pattern — and every data-dependent variant of one — replays
    through a single signature-keyed XLA executable."""
    out: Dict[str, Tuple[PatternRun, object]] = {}
    for name in (names if names is not None else sorted(PATTERNS)):
        run = PATTERNS[name]()
        mem_after, state = run_pattern(run, cfg, compiled=compiled,
                                       mode=mode)
        if validate:
            run.check(np.asarray(mem_after), state)
        out[name] = (run, state)
    return out


def run_pattern_batch(name: str, seeds: Sequence[int],
                      cfg: MVEConfig | None = None,
                      mode: str | None = None, **kw):
    """Evaluate one pattern across many input images in a single vmapped
    call.

    Builds the pattern for each seed; when every seed produces the same
    program (true for the purely strided kernels — the program depends
    only on sizes), the memory images are stacked and executed by one
    ``jax.vmap``-batched call.  Data-dependent programs (e.g. ``spmm``,
    whose instruction stream follows the sparsity pattern) fall back to
    per-image runs — under the VM (default mode) every such variant still
    replays through one shared XLA executable instead of recompiling.

    Returns ``(runs, mem_after)`` where ``mem_after`` has a leading seed
    axis aligned with ``runs`` (a list of per-seed arrays when the
    fallback produces ragged memory sizes).
    """
    cfg = cfg or MVEConfig()
    from .engine import compile_program
    runs = [PATTERNS[name](seed=s, **kw) for s in seeds]
    same_prog = all(tuple(r.program) == tuple(runs[0].program)
                    for r in runs[1:])
    same_size = all(r.memory.shape == runs[0].memory.shape
                    for r in runs[1:])
    if same_prog and same_size:
        cp = compile_program(runs[0].program, cfg, mode=mode)
        mems = np.stack([r.memory for r in runs])
        mem_after, _, _ = cp.run_batch(mems)
        return runs, mem_after
    outs = [np.asarray(
        compile_program(r.program, cfg, mode=mode).run(r.memory)[0])
        for r in runs]
    if all(o.shape == outs[0].shape for o in outs[1:]):
        return runs, np.stack(outs)
    return runs, outs
