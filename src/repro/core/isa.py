"""MVE instruction set architecture definitions.

Faithful encoding of the ISA in Section III of

    "Multi-Dimensional Vector ISA Extension for Mobile In-Cache Computing"
    (Khadem, Fujiki, Chen, Gu, Talati, Mahlke, Das — 2025)

The ISA treats in-cache physical registers (8K bit-serial SIMD lanes) as
up-to-4-dimensional *logical* registers ``PR[w][z][y][x]`` and provides

  * multi-dimensional strided loads/stores (Algorithm 1 of the paper),
  * random-base + strided-offset loads/stores (Equation 1),
  * dimension-level masking over the highest dimension,
  * the 29 operations of Table II for 6 data types.

Stride encoding uses the paper's 2-bit *stride mode* per dimension:

  mode 0 -> stride 0   (replication)
  mode 1 -> stride 1   (sequential)
  mode 2 -> derived    S_i = S_{i-1} * Dim_{i-1}.Length   (S_{-1} = 1)
  mode 3 -> value taken from the per-dimension stride control register

Full reference with worked examples: docs/ISA.md.  Executable semantics:
:mod:`repro.core.interp` (step oracle) and :mod:`repro.core.engine`
(compiled path, docs/ENGINE.md).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Optional, Tuple

MAX_DIMS = 4
# Paper Section III-E: the highest dimension is capped at 256 so the mask CR
# stays one bit per element of the outermost loop.
MAX_TOP_DIM = 256


class DType(enum.Enum):
    """MVE data types (paper Section III-F)."""

    # name, bits, float?
    B = ("b", 8, False)       # 8-bit integer
    W = ("w", 16, False)      # 16-bit integer
    DW = ("dw", 32, False)    # 32-bit integer
    QW = ("qw", 64, False)    # 64-bit integer
    HF = ("hf", 16, True)     # half float
    F = ("f", 32, True)       # single float

    def __init__(self, suffix: str, bits: int, is_float: bool):
        self.suffix = suffix
        self.bits = bits
        self.is_float = is_float

    @property
    def nbytes(self) -> int:
        return self.bits // 8


class StrideMode(enum.IntEnum):
    ZERO = 0      # replicate
    ONE = 1       # sequential
    DERIVED = 2   # S_i = S_{i-1} * L_{i-1}
    CR = 3        # use stride control register


class Op(enum.Enum):
    """Operation kinds of Table II."""

    # Config
    SET_DIMC = "vsetdimc"
    SET_DIML = "vsetdiml"
    SET_MASK = "vsetmask"
    UNSET_MASK = "vunsetmask"
    SET_WIDTH = "vsetwidth"
    SET_LDSTR = "vsetldstr"   # load stride CR
    SET_STSTR = "vsetststr"   # store stride CR
    # Move
    CVT = "vcvt"
    CPY = "vcpy"
    # Memory
    SLD = "vsld"
    RLD = "vrld"
    SST = "vsst"
    RST = "vrst"
    # Arithmetic
    SET_DUP = "vsetdup"
    SHI = "vshi"      # shift immediate (constant shift)
    ROTI = "vroti"
    SHR = "vshr"      # shift by register (variable shift)
    ADD = "vadd"
    SUB = "vsub"
    MUL = "vmul"
    MIN = "vmin"
    MAX = "vmax"
    XOR = "vxor"
    AND = "vand"
    OR = "vor"
    GT = "vgt"
    GTE = "vgte"
    LT = "vlt"
    LTE = "vlte"
    EQ = "veq"
    NEQ = "vneq"
    # VM-level pseudo op to account for interleaved scalar work in the
    # trace-driven cost model (the real binary interleaves scalar insts).
    SCALAR = "scalar"


CONFIG_OPS = {Op.SET_DIMC, Op.SET_DIML, Op.SET_MASK, Op.UNSET_MASK,
              Op.SET_WIDTH, Op.SET_LDSTR, Op.SET_STSTR}
MEMORY_OPS = {Op.SLD, Op.RLD, Op.SST, Op.RST}
COMPARE_OPS = {Op.GT, Op.GTE, Op.LT, Op.LTE, Op.EQ, Op.NEQ}
ARITH_OPS = {Op.SET_DUP, Op.SHI, Op.ROTI, Op.SHR, Op.ADD, Op.SUB, Op.MUL,
             Op.MIN, Op.MAX, Op.XOR, Op.AND, Op.OR} | COMPARE_OPS
MOVE_OPS = {Op.CVT, Op.CPY}
VECTOR_OPS = MEMORY_OPS | ARITH_OPS | MOVE_OPS


def reg_defs(instr: "Instr") -> Optional[int]:
    """The register this instruction writes, or ``None``.

    Compares write the Tag latch, not a register; stores and config ops
    write no register.  Shared by the register allocator
    (:mod:`repro.frontend.regalloc`) and the optimizer's dependence
    graph (:mod:`repro.opt`).
    """
    op = instr.op
    if op in (Op.SLD, Op.RLD) or (
            op in ARITH_OPS and op not in COMPARE_OPS) or op in MOVE_OPS:
        return instr.vd
    return None


def reg_uses(instr: "Instr") -> Tuple[int, ...]:
    """The registers this instruction reads, in operand order."""
    op = instr.op
    if op in (Op.SST, Op.RST):
        return (instr.vs1,) if instr.vs1 is not None else ()
    if op in VECTOR_OPS:
        uses = []
        if instr.vs1 is not None:
            uses.append(instr.vs1)
        if instr.vs2 is not None:
            uses.append(instr.vs2)
        return tuple(uses)
    return ()


@dataclasses.dataclass(frozen=True)
class Instr:
    """One MVE instruction.

    ``vd``/``vs1``/``vs2`` name virtual vector registers (ints).  Memory
    instructions carry the base address and the per-dimension stride modes;
    config instructions carry immediates.  ``scalar_count`` is used only by
    ``Op.SCALAR`` pseudo-instructions.
    """

    op: Op
    dtype: Optional[DType] = None
    vd: Optional[int] = None
    vs1: Optional[int] = None
    vs2: Optional[int] = None
    imm: Optional[int] = None
    base: Optional[int] = None                 # element address in VM memory
    modes: Optional[Tuple[int, ...]] = None    # per-dim stride modes
    dim: Optional[int] = None                  # for vsetdiml / vset*str
    length: Optional[int] = None               # for vsetdiml
    stride: Optional[int] = None               # for vset*str
    mask_index: Optional[int] = None           # for v(un)setmask
    predicated: bool = False                   # execute under Tag latch
    scalar_count: int = 0

    def is_vector(self) -> bool:
        return self.op in VECTOR_OPS

    def is_memory(self) -> bool:
        return self.op in MEMORY_OPS

    def is_config(self) -> bool:
        return self.op in CONFIG_OPS


# ---------------------------------------------------------------------------
# Convenience constructors (mirror the intrinsics library of Section III-F).
# ---------------------------------------------------------------------------

def vsetdimc(count: int) -> Instr:
    if not (1 <= count <= MAX_DIMS):
        raise ValueError(f"dim count must be in [1,{MAX_DIMS}], got {count}")
    return Instr(Op.SET_DIMC, imm=count)


def vsetdiml(dim: int, length: int) -> Instr:
    if length < 1:
        raise ValueError("dim length must be >= 1")
    return Instr(Op.SET_DIML, dim=dim, length=length)


def vsetldstr(dim: int, stride: int) -> Instr:
    return Instr(Op.SET_LDSTR, dim=dim, stride=stride)


def vsetststr(dim: int, stride: int) -> Instr:
    return Instr(Op.SET_STSTR, dim=dim, stride=stride)


def vsetmask(index: int) -> Instr:
    return Instr(Op.SET_MASK, mask_index=index)


def vunsetmask(index: int) -> Instr:
    return Instr(Op.UNSET_MASK, mask_index=index)


def vsetwidth(bits: int) -> Instr:
    return Instr(Op.SET_WIDTH, imm=bits)


def vsld(dtype: DType, vd: int, base: int, *modes: int) -> Instr:
    return Instr(Op.SLD, dtype=dtype, vd=vd, base=base, modes=tuple(modes))


def vsst(dtype: DType, vs: int, base: int, *modes: int) -> Instr:
    return Instr(Op.SST, dtype=dtype, vs1=vs, base=base, modes=tuple(modes))


def vrld(dtype: DType, vd: int, ptr_base: int, *modes: int) -> Instr:
    """Random load: ``ptr_base`` addresses an array of row base addresses."""
    return Instr(Op.RLD, dtype=dtype, vd=vd, base=ptr_base, modes=tuple(modes))


def vrst(dtype: DType, vs: int, ptr_base: int, *modes: int) -> Instr:
    return Instr(Op.RST, dtype=dtype, vs1=vs, base=ptr_base, modes=tuple(modes))


def vsetdup(dtype: DType, vd: int, value) -> Instr:
    return Instr(Op.SET_DUP, dtype=dtype, vd=vd, imm=value)


def vbinary(op: Op, dtype: DType, vd: int, vs1: int, vs2: int,
            predicated: bool = False) -> Instr:
    return Instr(op, dtype=dtype, vd=vd, vs1=vs1, vs2=vs2,
                 predicated=predicated)


def vadd(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.ADD, dtype, vd, vs1, vs2, **kw)


def vsub(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.SUB, dtype, vd, vs1, vs2, **kw)


def vmul(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.MUL, dtype, vd, vs1, vs2, **kw)


def vmin(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.MIN, dtype, vd, vs1, vs2, **kw)


def vmax(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.MAX, dtype, vd, vs1, vs2, **kw)


def vxor(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.XOR, dtype, vd, vs1, vs2, **kw)


def vand(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.AND, dtype, vd, vs1, vs2, **kw)


def vor(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.OR, dtype, vd, vs1, vs2, **kw)


def vshi(dtype, vd, vs, amount: int) -> Instr:
    return Instr(Op.SHI, dtype=dtype, vd=vd, vs1=vs, imm=amount)


def vshr_reg(dtype, vd, vs1, vs2) -> Instr:
    return Instr(Op.SHR, dtype=dtype, vd=vd, vs1=vs1, vs2=vs2)


def vcmp(op: Op, dtype, vs1, vs2) -> Instr:
    """Comparisons write the per-lane Tag latch (predicate)."""
    return Instr(op, dtype=dtype, vs1=vs1, vs2=vs2)


def vcpy(dtype, vd, vs) -> Instr:
    return Instr(Op.CPY, dtype=dtype, vd=vd, vs1=vs)


def vcvt(dst_dtype, vd, vs) -> Instr:
    return Instr(Op.CVT, dtype=dst_dtype, vd=vd, vs1=vs)


def scalar(count: int) -> Instr:
    """``count`` interleaved scalar core instructions (cost model only)."""
    return Instr(Op.SCALAR, scalar_count=count)


# ---------------------------------------------------------------------------
# Programs: validation + disassembly.
#
# Historically ``Program`` was a bare ``Sequence[Instr]`` type alias; it is
# now a tuple subclass so programs carry their own build-time checks
# (:meth:`Program.validate`) and a readable pretty-printer
# (:meth:`Program.dump`).  Plain lists/tuples of :class:`Instr` remain
# accepted everywhere — the executors only iterate.
# ---------------------------------------------------------------------------

class ProgramError(ValueError):
    """A program failed build-time validation.

    Carries the offending instruction index and its disassembly so the
    error reads like a compiler diagnostic instead of an opaque failure
    deep inside the compile walk.
    """

    def __init__(self, message: str, index: Optional[int] = None,
                 instr: Optional[Instr] = None):
        loc = ""
        if index is not None:
            loc = f"\n  at [{index:3d}] {disassemble(instr)}" \
                if instr is not None else f"\n  at instruction {index}"
        super().__init__(message + loc)
        self.index = index
        self.instr = instr


def disassemble(instr: Instr) -> str:
    """One readable line for one instruction (assembly-ish)."""
    op = instr.op
    mn = op.value + (f".{instr.dtype.suffix}" if instr.dtype else "")
    if op is Op.SET_DIMC or op is Op.SET_WIDTH:
        return f"{mn:14s} {instr.imm}"
    if op is Op.SET_DIML:
        return f"{mn:14s} d{instr.dim}, len={instr.length}"
    if op in (Op.SET_LDSTR, Op.SET_STSTR):
        return f"{mn:14s} d{instr.dim}, stride={instr.stride}"
    if op in (Op.SET_MASK, Op.UNSET_MASK):
        return f"{mn:14s} bit={instr.mask_index}"
    if op is Op.SCALAR:
        return f"{mn:14s} x{instr.scalar_count}"
    pred = ", pred" if instr.predicated else ""
    if op in (Op.SLD, Op.RLD):
        kind = "ptrs" if op is Op.RLD else "base"
        return (f"{mn:14s} v{instr.vd}, [{kind}={instr.base}], "
                f"S={tuple(instr.modes or ())}{pred}")
    if op in (Op.SST, Op.RST):
        kind = "ptrs" if op is Op.RST else "base"
        return (f"{mn:14s} v{instr.vs1}, [{kind}={instr.base}], "
                f"S={tuple(instr.modes or ())}{pred}")
    if op is Op.SET_DUP:
        return f"{mn:14s} v{instr.vd}, {instr.imm}{pred}"
    if op in (Op.SHI, Op.ROTI):
        return f"{mn:14s} v{instr.vd}, v{instr.vs1}, {instr.imm}{pred}"
    if op in COMPARE_OPS:
        return f"{mn:14s} v{instr.vs1}, v{instr.vs2}"
    if op in (Op.CPY, Op.CVT):
        return f"{mn:14s} v{instr.vd}, v{instr.vs1}{pred}"
    srcs = [f"v{instr.vs1}"]
    if instr.vs2 is not None:
        srcs.append(f"v{instr.vs2}")
    return f"{mn:14s} v{instr.vd}, {', '.join(srcs)}{pred}"


def dump(program: Iterable[Instr]) -> str:
    """Disassemble a whole program, one numbered line per instruction."""
    return "\n".join(f"[{i:3d}] {disassemble(instr)}"
                     for i, instr in enumerate(program))


def _require(cond: bool, msg: str, i: int, instr: Instr) -> None:
    if not cond:
        raise ProgramError(msg, i, instr)


def validate(program: Iterable[Instr], memory_size: Optional[int] = None,
             strict: bool = False, wordlines: int = 256) -> None:
    """Build-time program checks; raises :class:`ProgramError`.

    Walks the config-register evolution exactly like the compile walk
    (:mod:`repro.core.engine`) and checks each instruction against the
    architectural state it will execute under:

    * structural — operands present, stride modes in ``0..3``, dim/mask
      indices in range, shifts/rotates on integer registers only;
    * register bounds — register ids must fit the *variable* register
      file: ``wordlines // kernel_width`` live PRs (Section III-B);
    * ``strict`` adds frontend-grade checks: element dtype no wider than
      the configured register width, dimension-mask bits that can never
      map onto the current highest dimension, and — when ``memory_size``
      is given — static address ranges within the memory image.

    The step interpreter, fused engine and VM run the *lenient* subset
    (``strict=False``) so hand-written programs that deliberately rely on
    clipping/drop semantics keep executing; the kernel frontend
    (:mod:`repro.frontend`) validates strictly at build time.
    """
    # Late import: machine.py imports this module at load time.
    from .machine import ControlState, apply_config

    ctrl = ControlState()
    for i, instr in enumerate(program):
        op = instr.op
        if op in CONFIG_OPS:
            if op is Op.SET_DIMC:
                _require(instr.imm is not None and
                         1 <= instr.imm <= MAX_DIMS,
                         f"dimension count must be in [1,{MAX_DIMS}]",
                         i, instr)
            elif op is Op.SET_DIML:
                _require(instr.dim is not None and
                         0 <= instr.dim < MAX_DIMS,
                         f"dimension index must be in [0,{MAX_DIMS})",
                         i, instr)
                _require(instr.length is not None and instr.length >= 1,
                         "dimension length must be >= 1", i, instr)
            elif op in (Op.SET_LDSTR, Op.SET_STSTR):
                _require(instr.dim is not None and
                         0 <= instr.dim < MAX_DIMS,
                         f"stride CR index must be in [0,{MAX_DIMS})",
                         i, instr)
                _require(instr.stride is not None,
                         "stride CR write needs a stride value", i, instr)
            elif op in (Op.SET_MASK, Op.UNSET_MASK):
                _require(instr.mask_index is not None and
                         0 <= instr.mask_index < MAX_TOP_DIM,
                         f"mask bit must be in [0,{MAX_TOP_DIM}) — the "
                         "mask CR covers only the highest dimension",
                         i, instr)
                if strict:
                    top = ctrl.dim_lens[ctrl.dim_count - 1]
                    _require(instr.mask_index < top,
                             f"mask bit {instr.mask_index} can never map "
                             f"onto the highest dimension (top length "
                             f"{top}) — dimension-level masks apply to "
                             "the top dimension only", i, instr)
            elif op is Op.SET_WIDTH:
                _require(instr.imm is not None and
                         1 <= instr.imm <= wordlines,
                         f"register width must be in [1,{wordlines}] bits",
                         i, instr)
            apply_config(ctrl, instr)
            continue
        if op is Op.SCALAR:
            _require(instr.scalar_count >= 0,
                     "scalar count must be >= 0", i, instr)
            continue

        # ---- vector instructions -------------------------------------
        _require(instr.dtype is not None,
                 "vector instruction needs a data type", i, instr)
        # Lenient: any register id the machine could ever name (the fused
        # engine hosts programs beyond the current width's physical file —
        # that is what the VM -> fused fallback exists for).  Strict: the
        # variable register count of Section III-B.
        max_regs = wordlines if not strict else \
            max(1, wordlines // max(ctrl.kernel_width, 1))
        for field, r in (("vd", instr.vd), ("vs1", instr.vs1),
                         ("vs2", instr.vs2)):
            if r is None:
                continue
            _require(0 <= r < max_regs,
                     f"register {field}=v{r} out of range: width "
                     f"{ctrl.kernel_width} leaves {max_regs} physical "
                     f"registers ({wordlines} wordlines / width)", i, instr)
        if strict:
            _require(instr.dtype.bits <= ctrl.kernel_width,
                     f"dtype {instr.dtype.name} ({instr.dtype.bits} bits) "
                     f"is wider than the configured register width "
                     f"{ctrl.kernel_width}", i, instr)

        if op in MEMORY_OPS:
            store = op in (Op.SST, Op.RST)
            _require(instr.base is not None and instr.base >= 0,
                     "memory access needs a non-negative base address",
                     i, instr)
            _require(instr.vs1 is not None if store
                     else instr.vd is not None,
                     "store needs a source register" if store
                     else "load needs a destination register", i, instr)
            modes = tuple(instr.modes or ())
            _require(all(0 <= m <= 3 for m in modes),
                     f"stride modes must be 2-bit (0..3), got {modes}",
                     i, instr)
            _require(len(modes) <= MAX_DIMS,
                     f"at most {MAX_DIMS} stride modes", i, instr)
            if strict and memory_size is not None:
                _check_address_range(ctrl, instr, memory_size, i)
            continue

        if op in COMPARE_OPS:
            _require(instr.vs1 is not None and instr.vs2 is not None,
                     "compare needs two source registers", i, instr)
            continue

        _require(instr.vd is not None,
                 "instruction needs a destination register", i, instr)
        if op is Op.SET_DUP:
            _require(instr.imm is not None,
                     "vsetdup needs an immediate value", i, instr)
        elif op in (Op.SHI, Op.ROTI):
            _require(instr.vs1 is not None and instr.imm is not None,
                     "shift/rotate needs a source register and an "
                     "immediate amount", i, instr)
            _require(not instr.dtype.is_float,
                     "shift/rotate on a float register", i, instr)
        elif op is Op.SHR:
            _require(instr.vs1 is not None and instr.vs2 is not None,
                     "variable shift needs two source registers", i, instr)
            _require(not instr.dtype.is_float,
                     "variable shift on a float register", i, instr)
        elif op in (Op.CPY, Op.CVT):
            _require(instr.vs1 is not None,
                     "move needs a source register", i, instr)
        else:
            _require(instr.vs1 is not None and instr.vs2 is not None,
                     f"{op.value} needs two source registers", i, instr)


def _check_address_range(ctrl, instr: Instr, memory_size: int,
                         i: int) -> None:
    """Strict mode: the static address envelope must stay in memory.

    For strided accesses the maximum address over active lanes is
    ``base + sum (len_d - 1) * stride_d``; random-base accesses must at
    least read their whole pointer array from memory.
    """
    store = instr.op in (Op.SST, Op.RST)
    random = instr.op in (Op.RLD, Op.RST)
    dims = ctrl.active_dims()
    strides = ctrl.resolve_strides(tuple(instr.modes or ()), store)
    if random:
        end = instr.base + dims[-1]
        _require(end <= memory_size,
                 f"pointer array [{instr.base}, {end}) exceeds the "
                 f"memory image ({memory_size} elements)", i, instr)
        return
    lo = instr.base + sum(min(0, (ln - 1) * s)
                          for ln, s in zip(dims, strides))
    hi = instr.base + sum(max(0, (ln - 1) * s)
                          for ln, s in zip(dims, strides))
    _require(lo >= 0 and hi < memory_size,
             f"static access spans [{lo}, {hi}] outside the memory "
             f"image ({memory_size} elements)", i, instr)


class Program(tuple):
    """An MVE program: an immutable sequence of :class:`Instr`.

    Adds :meth:`validate` (build-time checks with readable one-line
    errors) and :meth:`dump` (disassembler) over plain tuple semantics.
    Anything iterable of instructions still works wherever a program is
    accepted; this class is what the kernel frontend emits.
    """

    __slots__ = ()

    def __new__(cls, instrs: Iterable[Instr] = ()):
        return super().__new__(cls, tuple(instrs))

    def validate(self, memory_size: Optional[int] = None,
                 strict: bool = False) -> "Program":
        """Run :func:`validate`; returns ``self`` for chaining."""
        validate(self, memory_size=memory_size, strict=strict)
        return self

    def dump(self) -> str:
        """Readable disassembly (used by error messages and the docs)."""
        return dump(self)
