"""MVE instruction set architecture definitions.

Faithful encoding of the ISA in Section III of

    "Multi-Dimensional Vector ISA Extension for Mobile In-Cache Computing"
    (Khadem, Fujiki, Chen, Gu, Talati, Mahlke, Das — 2025)

The ISA treats in-cache physical registers (8K bit-serial SIMD lanes) as
up-to-4-dimensional *logical* registers ``PR[w][z][y][x]`` and provides

  * multi-dimensional strided loads/stores (Algorithm 1 of the paper),
  * random-base + strided-offset loads/stores (Equation 1),
  * dimension-level masking over the highest dimension,
  * the 29 operations of Table II for 6 data types.

Stride encoding uses the paper's 2-bit *stride mode* per dimension:

  mode 0 -> stride 0   (replication)
  mode 1 -> stride 1   (sequential)
  mode 2 -> derived    S_i = S_{i-1} * Dim_{i-1}.Length   (S_{-1} = 1)
  mode 3 -> value taken from the per-dimension stride control register

Full reference with worked examples: docs/ISA.md.  Executable semantics:
:mod:`repro.core.interp` (step oracle) and :mod:`repro.core.engine`
(compiled path, docs/ENGINE.md).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple

MAX_DIMS = 4
# Paper Section III-E: the highest dimension is capped at 256 so the mask CR
# stays one bit per element of the outermost loop.
MAX_TOP_DIM = 256


class DType(enum.Enum):
    """MVE data types (paper Section III-F)."""

    # name, bits, float?
    B = ("b", 8, False)       # 8-bit integer
    W = ("w", 16, False)      # 16-bit integer
    DW = ("dw", 32, False)    # 32-bit integer
    QW = ("qw", 64, False)    # 64-bit integer
    HF = ("hf", 16, True)     # half float
    F = ("f", 32, True)       # single float

    def __init__(self, suffix: str, bits: int, is_float: bool):
        self.suffix = suffix
        self.bits = bits
        self.is_float = is_float

    @property
    def nbytes(self) -> int:
        return self.bits // 8


class StrideMode(enum.IntEnum):
    ZERO = 0      # replicate
    ONE = 1       # sequential
    DERIVED = 2   # S_i = S_{i-1} * L_{i-1}
    CR = 3        # use stride control register


class Op(enum.Enum):
    """Operation kinds of Table II."""

    # Config
    SET_DIMC = "vsetdimc"
    SET_DIML = "vsetdiml"
    SET_MASK = "vsetmask"
    UNSET_MASK = "vunsetmask"
    SET_WIDTH = "vsetwidth"
    SET_LDSTR = "vsetldstr"   # load stride CR
    SET_STSTR = "vsetststr"   # store stride CR
    # Move
    CVT = "vcvt"
    CPY = "vcpy"
    # Memory
    SLD = "vsld"
    RLD = "vrld"
    SST = "vsst"
    RST = "vrst"
    # Arithmetic
    SET_DUP = "vsetdup"
    SHI = "vshi"      # shift immediate (constant shift)
    ROTI = "vroti"
    SHR = "vshr"      # shift by register (variable shift)
    ADD = "vadd"
    SUB = "vsub"
    MUL = "vmul"
    MIN = "vmin"
    MAX = "vmax"
    XOR = "vxor"
    AND = "vand"
    OR = "vor"
    GT = "vgt"
    GTE = "vgte"
    LT = "vlt"
    LTE = "vlte"
    EQ = "veq"
    NEQ = "vneq"
    # VM-level pseudo op to account for interleaved scalar work in the
    # trace-driven cost model (the real binary interleaves scalar insts).
    SCALAR = "scalar"


CONFIG_OPS = {Op.SET_DIMC, Op.SET_DIML, Op.SET_MASK, Op.UNSET_MASK,
              Op.SET_WIDTH, Op.SET_LDSTR, Op.SET_STSTR}
MEMORY_OPS = {Op.SLD, Op.RLD, Op.SST, Op.RST}
COMPARE_OPS = {Op.GT, Op.GTE, Op.LT, Op.LTE, Op.EQ, Op.NEQ}
ARITH_OPS = {Op.SET_DUP, Op.SHI, Op.ROTI, Op.SHR, Op.ADD, Op.SUB, Op.MUL,
             Op.MIN, Op.MAX, Op.XOR, Op.AND, Op.OR} | COMPARE_OPS
MOVE_OPS = {Op.CVT, Op.CPY}
VECTOR_OPS = MEMORY_OPS | ARITH_OPS | MOVE_OPS


@dataclasses.dataclass(frozen=True)
class Instr:
    """One MVE instruction.

    ``vd``/``vs1``/``vs2`` name virtual vector registers (ints).  Memory
    instructions carry the base address and the per-dimension stride modes;
    config instructions carry immediates.  ``scalar_count`` is used only by
    ``Op.SCALAR`` pseudo-instructions.
    """

    op: Op
    dtype: Optional[DType] = None
    vd: Optional[int] = None
    vs1: Optional[int] = None
    vs2: Optional[int] = None
    imm: Optional[int] = None
    base: Optional[int] = None                 # element address in VM memory
    modes: Optional[Tuple[int, ...]] = None    # per-dim stride modes
    dim: Optional[int] = None                  # for vsetdiml / vset*str
    length: Optional[int] = None               # for vsetdiml
    stride: Optional[int] = None               # for vset*str
    mask_index: Optional[int] = None           # for v(un)setmask
    predicated: bool = False                   # execute under Tag latch
    scalar_count: int = 0

    def is_vector(self) -> bool:
        return self.op in VECTOR_OPS

    def is_memory(self) -> bool:
        return self.op in MEMORY_OPS

    def is_config(self) -> bool:
        return self.op in CONFIG_OPS


# ---------------------------------------------------------------------------
# Convenience constructors (mirror the intrinsics library of Section III-F).
# ---------------------------------------------------------------------------

def vsetdimc(count: int) -> Instr:
    if not (1 <= count <= MAX_DIMS):
        raise ValueError(f"dim count must be in [1,{MAX_DIMS}], got {count}")
    return Instr(Op.SET_DIMC, imm=count)


def vsetdiml(dim: int, length: int) -> Instr:
    if length < 1:
        raise ValueError("dim length must be >= 1")
    return Instr(Op.SET_DIML, dim=dim, length=length)


def vsetldstr(dim: int, stride: int) -> Instr:
    return Instr(Op.SET_LDSTR, dim=dim, stride=stride)


def vsetststr(dim: int, stride: int) -> Instr:
    return Instr(Op.SET_STSTR, dim=dim, stride=stride)


def vsetmask(index: int) -> Instr:
    return Instr(Op.SET_MASK, mask_index=index)


def vunsetmask(index: int) -> Instr:
    return Instr(Op.UNSET_MASK, mask_index=index)


def vsetwidth(bits: int) -> Instr:
    return Instr(Op.SET_WIDTH, imm=bits)


def vsld(dtype: DType, vd: int, base: int, *modes: int) -> Instr:
    return Instr(Op.SLD, dtype=dtype, vd=vd, base=base, modes=tuple(modes))


def vsst(dtype: DType, vs: int, base: int, *modes: int) -> Instr:
    return Instr(Op.SST, dtype=dtype, vs1=vs, base=base, modes=tuple(modes))


def vrld(dtype: DType, vd: int, ptr_base: int, *modes: int) -> Instr:
    """Random load: ``ptr_base`` addresses an array of row base addresses."""
    return Instr(Op.RLD, dtype=dtype, vd=vd, base=ptr_base, modes=tuple(modes))


def vrst(dtype: DType, vs: int, ptr_base: int, *modes: int) -> Instr:
    return Instr(Op.RST, dtype=dtype, vs1=vs, base=ptr_base, modes=tuple(modes))


def vsetdup(dtype: DType, vd: int, value) -> Instr:
    return Instr(Op.SET_DUP, dtype=dtype, vd=vd, imm=value)


def vbinary(op: Op, dtype: DType, vd: int, vs1: int, vs2: int,
            predicated: bool = False) -> Instr:
    return Instr(op, dtype=dtype, vd=vd, vs1=vs1, vs2=vs2,
                 predicated=predicated)


def vadd(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.ADD, dtype, vd, vs1, vs2, **kw)


def vsub(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.SUB, dtype, vd, vs1, vs2, **kw)


def vmul(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.MUL, dtype, vd, vs1, vs2, **kw)


def vmin(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.MIN, dtype, vd, vs1, vs2, **kw)


def vmax(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.MAX, dtype, vd, vs1, vs2, **kw)


def vxor(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.XOR, dtype, vd, vs1, vs2, **kw)


def vand(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.AND, dtype, vd, vs1, vs2, **kw)


def vor(dtype, vd, vs1, vs2, **kw):
    return vbinary(Op.OR, dtype, vd, vs1, vs2, **kw)


def vshi(dtype, vd, vs, amount: int) -> Instr:
    return Instr(Op.SHI, dtype=dtype, vd=vd, vs1=vs, imm=amount)


def vshr_reg(dtype, vd, vs1, vs2) -> Instr:
    return Instr(Op.SHR, dtype=dtype, vd=vd, vs1=vs1, vs2=vs2)


def vcmp(op: Op, dtype, vs1, vs2) -> Instr:
    """Comparisons write the per-lane Tag latch (predicate)."""
    return Instr(op, dtype=dtype, vs1=vs1, vs2=vs2)


def vcpy(dtype, vd, vs) -> Instr:
    return Instr(Op.CPY, dtype=dtype, vd=vd, vs1=vs)


def vcvt(dst_dtype, vd, vs) -> Instr:
    return Instr(Op.CVT, dtype=dst_dtype, vd=vd, vs1=vs)


def scalar(count: int) -> Instr:
    """``count`` interleaved scalar core instructions (cost model only)."""
    return Instr(Op.SCALAR, scalar_count=count)


Program = Sequence[Instr]
