"""Functional executor for MVE programs.

This is the *semantic oracle* for the ISA: registers are JAX arrays of shape
``(lanes,)``; memory is a flat JAX array addressed in elements.  Multi-dim
strided loads implement Algorithm 1, random loads implement Equation 1, and
dimension-level masking follows Section III-E (masked lanes retain their old
destination value; masked stores are dropped).  The ISA semantics are
documented with worked examples in docs/ISA.md.

The interpreter also produces an execution *trace* consumed by the cost
models in :mod:`repro.core.cost` — this mirrors the paper's methodology of
a trace-driven cycle-accurate simulator fed by a functional intrinsic
library (Section VI).

Execution is routed through the compiled engine by default
(:mod:`repro.core.engine`, design note in docs/ENGINE.md): a whole-program
compile pass resolves all addressing statically and runs the data path as
one fused ``jax.jit`` function.  The per-instruction step loop is kept as
:meth:`MVEInterpreter.run_stepwise` — it is the independent cross-check
oracle the engine is equivalence-tested against (``tests/test_engine.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import isa
from .isa import DType, Instr, Op
from .cost import TraceEvent  # noqa: F401  (re-exported; historical home)
from .machine import (JNP_DTYPE, ControlState, MVEConfig, apply_config,
                      cbs_touched, flatten_indices, lane_dim_mask,
                      stream_shape, touched_lines)


@dataclasses.dataclass
class MachineState:
    memory: jnp.ndarray
    regs: Dict[int, jnp.ndarray]
    tag: jnp.ndarray           # per-lane predicate latch (T)
    ctrl: ControlState
    trace: List[TraceEvent]


class MVEInterpreter:
    """Executes an MVE program on a software model of the in-cache engine.

    ``compiled=True`` (default) routes :meth:`run` through
    :func:`repro.core.engine.compile_program`; ``compiled=False`` (or
    :meth:`run_stepwise`) uses the original per-instruction loop.
    ``mode`` picks the compiled executor — ``"vm"`` (program-as-data
    datapath, one XLA executable per signature) or ``"fused"`` (one jitted
    function per program); ``None`` uses the engine default.
    """

    def __init__(self, config: MVEConfig | None = None,
                 compiled: bool = True, mode: str | None = None):
        self.cfg = config or MVEConfig()
        self.compiled = compiled
        self.mode = mode

    # -- public API --------------------------------------------------------
    def run(self, program: isa.Program, memory: jnp.ndarray,
            ) -> Tuple[jnp.ndarray, MachineState]:
        if self.compiled:
            from .engine import compile_program
            return compile_program(program, self.cfg,
                                   mode=self.mode).run(memory)
        return self.run_stepwise(program, memory)

    def run_stepwise(self, program: isa.Program, memory: jnp.ndarray,
                     ) -> Tuple[jnp.ndarray, MachineState]:
        """The original one-instruction-at-a-time oracle loop."""
        state = MachineState(
            memory=jnp.asarray(memory),
            regs={},
            tag=jnp.ones(self.cfg.lanes, dtype=bool),
            ctrl=ControlState(),
            trace=[],
        )
        for instr in program:
            self._step(instr, state)
        return state.memory, state

    # -- helpers -----------------------------------------------------------
    def _addresses(self, state: MachineState, modes: Tuple[int, ...],
                   base: int, store: bool, random_base: bool
                   ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Per-lane element addresses + active mask for a memory access.

        For random accesses (Eq. 1) the highest dimension indexes a pointer
        array at ``base``; lower dimensions use the resolved strides shifted
        down by one (the paper's S_[3:1] become the inner strides).
        """
        ctrl = state.ctrl
        dims = ctrl.active_dims()
        strides = ctrl.resolve_strides(modes, store)
        coords = flatten_indices(dims, self.cfg.lanes)
        mask = lane_dim_mask(dims, ctrl.dim_mask, self.cfg.lanes)

        if random_base:
            # Fetch base pointers from memory: one per highest-dim element.
            top_len = dims[-1]
            ptrs = np.asarray(
                state.memory[base: base + top_len]).astype(np.int64)
            top_idx = np.clip(coords[:, len(dims) - 1], 0, top_len - 1)
            addr = ptrs[top_idx]
            for d in range(len(dims) - 1):
                addr = addr + np.where(coords[:, d] >= 0,
                                       coords[:, d], 0) * strides[d]
        else:
            addr = np.full(self.cfg.lanes, base, dtype=np.int64)
            for d in range(len(dims)):
                addr = addr + np.where(coords[:, d] >= 0,
                                       coords[:, d], 0) * strides[d]

        run, segments, unique = stream_shape(dims, strides, self.cfg.lanes)
        return addr, mask, run, segments, unique

    def _step(self, instr: Instr, state: MachineState) -> None:
        op = instr.op
        cfg = self.cfg
        ctrl = state.ctrl

        # ---- config ------------------------------------------------------
        if op in isa.CONFIG_OPS:
            apply_config(ctrl, instr)
            return self._trace_config(instr, state)
        if op is Op.SCALAR:
            state.trace.append(TraceEvent(
                op=op, dtype=None, elements=0,
                cb_mask=np.zeros(cfg.num_cbs, dtype=bool),
                scalar_count=instr.scalar_count))
            return None

        dims = ctrl.active_dims()
        mask = lane_dim_mask(dims, ctrl.dim_mask, cfg.lanes)
        jmask = jnp.asarray(mask)
        cbm = cbs_touched(dims, ctrl.dim_mask, cfg)
        elements = int(mask.sum())
        dt = JNP_DTYPE.get(instr.dtype, jnp.float32)

        def old(vd):
            return state.regs.get(
                vd, jnp.zeros(cfg.lanes, dtype=dt)).astype(dt)

        # ---- memory ------------------------------------------------------
        if op in (Op.SLD, Op.RLD):
            addr, amask, run, segs, uniq = self._addresses(
                state, instr.modes or (), instr.base,
                store=False, random_base=(op is Op.RLD))
            lines = touched_lines(addr, amask, instr.dtype.nbytes)
            gathered = state.memory[jnp.asarray(
                np.clip(addr, 0, state.memory.shape[0] - 1))].astype(dt)
            state.regs[instr.vd] = jnp.where(jnp.asarray(amask), gathered,
                                             old(instr.vd))
            state.trace.append(TraceEvent(op, instr.dtype, elements, cbm,
                                          segments=segs,
                                          contiguous_run=run,
                                          unique_elements=uniq,
                                          lines=lines))
            return None
        if op in (Op.SST, Op.RST):
            addr, amask, run, segs, uniq = self._addresses(
                state, instr.modes or (), instr.base,
                store=True, random_base=(op is Op.RST))
            lines = touched_lines(addr, amask, instr.dtype.nbytes)
            src = old(instr.vs1)
            # Drop masked lanes; later lanes win on address collisions
            # (well-defined scatter order, matches a sequential loop).
            # Masked lanes route to an out-of-range index and are dropped
            # by the scatter itself — redirecting them to a real address
            # (e.g. 0) would make them *collide* with an active lane
            # storing there and resurrect its pre-store value.
            idx = jnp.asarray(np.where(amask, addr,
                                       state.memory.shape[0]))
            state.memory = state.memory.at[idx].set(
                src.astype(state.memory.dtype), mode="drop")
            state.trace.append(TraceEvent(op, instr.dtype, elements, cbm,
                                          segments=segs,
                                          contiguous_run=run,
                                          unique_elements=uniq,
                                          lines=lines))
            return None

        # ---- moves & arithmetic -------------------------------------------
        def finish(result):
            result = result.astype(dt)
            prev = old(instr.vd)
            keep = jmask
            if instr.predicated:
                keep = keep & state.tag
            state.regs[instr.vd] = jnp.where(keep, result, prev)
            state.trace.append(TraceEvent(op, instr.dtype, elements, cbm))

        if op is Op.SET_DUP:
            return finish(jnp.full(cfg.lanes, instr.imm, dtype=dt))
        if op is Op.CPY:
            return finish(old(instr.vs1))
        if op is Op.CVT:
            src = state.regs.get(instr.vs1,
                                 jnp.zeros(cfg.lanes, dtype=jnp.float32))
            return finish(src.astype(dt))

        a = state.regs.get(instr.vs1, jnp.zeros(cfg.lanes, dtype=dt)).astype(dt)
        if instr.vs2 is not None:
            b = state.regs.get(instr.vs2,
                               jnp.zeros(cfg.lanes, dtype=dt)).astype(dt)
        else:
            b = None

        if op is Op.ADD:
            return finish(a + b)
        if op is Op.SUB:
            return finish(a - b)
        if op is Op.MUL:
            return finish(a * b)
        if op is Op.MIN:
            return finish(jnp.minimum(a, b))
        if op is Op.MAX:
            return finish(jnp.maximum(a, b))
        if op is Op.XOR:
            return finish(a ^ b)
        if op is Op.AND:
            return finish(a & b)
        if op is Op.OR:
            return finish(a | b)
        if op is Op.SHI:
            if instr.dtype.is_float:
                raise ValueError("shift on float register")
            amt = instr.imm
            return finish(a << amt if amt >= 0 else a >> (-amt))
        if op is Op.ROTI:
            bits = instr.dtype.bits
            amt = instr.imm % bits
            ua = a.astype(jnp.uint32 if bits <= 32 else jnp.uint64)
            return finish(((ua << amt) | (ua >> (bits - amt))).astype(dt))
        if op is Op.SHR:
            return finish(a << b.astype(jnp.int32))
        if op in isa.COMPARE_OPS:
            cmp = {Op.GT: a > b, Op.GTE: a >= b, Op.LT: a < b,
                   Op.LTE: a <= b, Op.EQ: a == b, Op.NEQ: a != b}[op]
            state.tag = jnp.where(jmask, cmp, state.tag)
            state.trace.append(TraceEvent(op, instr.dtype, elements, cbm))
            return None

        raise NotImplementedError(f"op {op}")

    def _trace_config(self, instr: Instr, state: MachineState) -> None:
        state.trace.append(TraceEvent(
            op=instr.op, dtype=None, elements=0,
            cb_mask=np.zeros(self.cfg.num_cbs, dtype=bool)))


def read_register(state: MachineState, reg: int, n: Optional[int] = None):
    """Test helper: dense values of the first ``n`` lanes of ``reg``."""
    v = state.regs[reg]
    return np.asarray(v if n is None else v[:n])
