"""Deterministic, shardable synthetic-text data pipeline.

Design mirrors a production loader:

  * a *source* yields variable-length documents deterministically from
    (seed, document index) — any host can materialize any index, which is
    what makes elastic restarts and data-parallel sharding trivial;
  * documents are packed into fixed (batch, seq) rows with the MVE
    dimension-level-mask idiom (:func:`repro.core.packing.pack_documents`):
    per-document segment ids give attention isolation and the loss mask is
    a *document-level* mask, not per-token predicates;
  * host sharding: host h of H reads documents h, h+H, h+2H, ... so the
    global batch order is independent of host count (elastic rescaling
    keeps determinism).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np

from ..core.packing import pack_documents


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    min_doc_len: int = 16


class SyntheticTextSource:
    """Deterministic documents: doc i is fully determined by (seed, i).

    Token stream is a stationary Markov-ish hash chain, so a model can
    actually learn structure from it (used by the training examples to
    show decreasing loss).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def doc_length(self, index: int) -> int:
        rng = np.random.default_rng((self.cfg.seed, index, 1))
        ln = int(rng.poisson(self.cfg.mean_doc_len))
        return max(self.cfg.min_doc_len, ln)

    def document(self, index: int) -> np.ndarray:
        cfg = self.cfg
        n = self.doc_length(index)
        rng = np.random.default_rng((cfg.seed, index, 2))
        # order-1 structure: next token = f(prev) with noise
        toks = np.empty(n, dtype=np.int32)
        toks[0] = rng.integers(2, cfg.vocab_size)
        noise = rng.random(n)
        jumps = rng.integers(2, cfg.vocab_size, size=n)
        for t in range(1, n):
            if noise[t] < 0.7:
                toks[t] = (toks[t - 1] * 31 + 17) % (cfg.vocab_size - 2) + 2
            else:
                toks[t] = jumps[t]
        return toks


def shard_for_host(indices: np.ndarray, host: int,
                   num_hosts: int) -> np.ndarray:
    return indices[indices % num_hosts == host]


def make_train_batches(cfg: DataConfig, host: int = 0, num_hosts: int = 1,
                       start_doc: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Yields packed batches {tokens, targets, loss_mask, positions,
    segment_ids} of shape (global_batch/num_hosts, seq_len)."""
    src = SyntheticTextSource(cfg)
    rows_needed = cfg.global_batch // num_hosts
    doc = start_doc + host
    stride = num_hosts
    buf: List[np.ndarray] = []
    while True:
        rows: List = []
        # over-fetch documents until packing yields enough rows
        while True:
            buf.append(src.document(doc))
            doc += stride
            tokens, segs, pos = pack_documents(buf, cfg.seq_len + 1)
            if len(tokens) > rows_needed:   # keep leftover docs for next batch
                tokens, segs, pos = tokens[:rows_needed], \
                    segs[:rows_needed], pos[:rows_needed]
                buf = []
                break
        targets = tokens[:, 1:]
        yield {
            "tokens": tokens[:, :-1].astype(np.int32),
            "targets": targets.astype(np.int32),
            "loss_mask": (segs[:, 1:] > 0).astype(np.float32),
            "positions": pos[:, :-1].astype(np.int32),
            "segment_ids": segs[:, :-1].astype(np.int32),
            "next_doc": np.asarray(doc, np.int64),
        }
