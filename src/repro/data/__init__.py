"""Data pipeline substrate."""
from .pipeline import (DataConfig, SyntheticTextSource,  # noqa: F401
                       make_train_batches, shard_for_host)
