"""Deterministic fault injection for the serving runtime.

In-cache compute makes failure a first-class hazard, not an edge case:
an SRAM bit-cell that computes is an SRAM bit-cell that can flip, a
cache op contended by the host has variable latency, and a serving
worker is one thread among many that the OS may kill.  This module
models that hazard space as **data**: a :class:`FaultPlan` is an
immutable, seedable, JSON-serializable schedule of :class:`FaultSpec`
entries, and a :class:`FaultInjector` executes the plan at well-defined
executor boundaries.  Same plan + same request stream = same faults, so
every chaos run is replayable and every recovery path is a
deterministic test (``tests/test_resilience.py``).

Sites (where a fault can fire)
------------------------------

==============  ========================================================
``compile``      promotion/compilation of an executable
``dispatch``     launching a (possibly batched) execution
``finalize``     materializing device results back to the host
``worker``       the background serving thread itself, between batches
``engine.*``     deep hooks inside :mod:`repro.core.engine` (via
``vm.*``         :func:`repro.core.vm.set_fault_hook`) — same matching
                 rules, used for executor-level chaos
==============  ========================================================

Kinds (what happens)
--------------------

==============  ========================================================
``error``        raise :class:`~repro.resilience.errors.InjectedFault`
``straggler``    sleep ``latency_s`` (variable-latency cache op)
``bitflip``      XOR one bit of one word of the result memory image —
                 the SRAM cell-fault model; *silent* unless audited
``kill``         raise :class:`InjectedWorkerDeath` (``worker`` site)
==============  ========================================================

A spec can be bound to one request (``rid``), one executor tier
(``tier``), fire a bounded number of ``times`` (``-1`` = sticky: a
permanently poisoned request), and skip its first ``after`` matching
occasions (to hit mid-stream).  The injector records every firing in
:attr:`FaultInjector.fired` — the replay log chaos tests compare.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .errors import InjectedFault, InjectedWorkerDeath

KINDS = ("error", "straggler", "bitflip", "kill")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault (see module docstring for the vocabulary)."""

    site: str                      # where: compile|dispatch|finalize|worker|engine.*|vm.*
    kind: str                      # what: error|straggler|bitflip|kill
    rid: Optional[int] = None      # bind to one request (None = any)
    tier: Optional[str] = None     # bind to one executor tier (None = any)
    times: int = 1                 # firings before the spec retires (-1 = sticky)
    after: int = 0                 # matching occasions skipped before the first firing
    latency_s: float = 0.0         # straggler sleep
    word: int = 0                  # bitflip: word index into the memory image
    bit: int = 0                   # bitflip: bit within the word

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


class FaultPlan:
    """An immutable, replayable schedule of faults.

    Build one explicitly from specs, randomly via :meth:`random`
    (deterministic in ``seed``), or from a recorded JSON blob via
    :meth:`from_json` — ``to_json``/``from_json`` round-trip exactly, so
    a chaos run's plan can be committed next to its test.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: Optional[int] = None):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __repr__(self) -> str:
        return f"FaultPlan(n={len(self.specs)}, seed={self.seed})"

    @classmethod
    def random(cls, seed: int, n_requests: int, rate: float,
               kinds: Sequence[str] = ("error", "straggler", "bitflip"),
               sticky_rids: Sequence[int] = (),
               straggler_s: float = 0.002,
               worker_kills: int = 0) -> "FaultPlan":
        """Deterministic per-request fault assignment.

        Each rid in ``[0, n_requests)`` independently draws a fault with
        probability ``rate``; transient kinds fire once (``times=1``) so
        a bounded retry recovers them.  ``sticky_rids`` are permanently
        poisoned (``times=-1`` dispatch errors) — the batch-bisection +
        quarantine path.  ``worker_kills`` schedules that many one-shot
        worker-thread deaths spread across the stream (supervisor path).
        """
        rng = np.random.default_rng(seed)
        specs: List[FaultSpec] = []
        for rid in range(n_requests):
            if rng.random() >= rate:
                continue
            kind = kinds[int(rng.integers(len(kinds)))]
            if kind == "error":
                specs.append(FaultSpec(site="dispatch", kind="error", rid=rid))
            elif kind == "straggler":
                specs.append(FaultSpec(site="dispatch", kind="straggler",
                                       rid=rid, latency_s=straggler_s))
            elif kind == "bitflip":
                specs.append(FaultSpec(
                    site="finalize", kind="bitflip", rid=rid,
                    word=int(rng.integers(0, 2 ** 16)),
                    bit=int(rng.integers(0, 32))))
            else:   # pragma: no cover - "kill" never drawn per-rid
                specs.append(FaultSpec(site="worker", kind="kill", rid=rid))
        for k in range(worker_kills):
            # spread kills over the stream: fire after k'th third of the
            # expected worker wakeups
            specs.append(FaultSpec(site="worker", kind="kill",
                                   after=1 + 2 * k))
        for rid in sticky_rids:
            specs.append(FaultSpec(site="dispatch", kind="error",
                                   rid=int(rid), times=-1))
        return cls(specs, seed=seed)

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "specs": [dataclasses.asdict(s) for s in self.specs],
        }, indent=2)

    @classmethod
    def from_json(cls, blob: str) -> "FaultPlan":
        d = json.loads(blob)
        return cls([FaultSpec(**s) for s in d["specs"]], seed=d.get("seed"))


class FaultInjector:
    """Executes a :class:`FaultPlan` at executor boundaries.

    Thread-safe; one injector serves the scheduler's caller threads, the
    background worker, and (optionally, via :meth:`engine_hook` passed to
    :func:`repro.core.vm.set_fault_hook`) the engine/VM internals.

    The scheduler's *recovery* paths — retries, bisection probes, audit
    reference runs — execute under :meth:`suspended`, so a fault plan
    describes faults of the primary serving path and recovery is
    shielded (the real-world analogue: recovery re-executes on a
    known-good resource, not the faulty one).  Sticky specs
    (``times=-1``) are the exception a test opts into via rid binding:
    suspension still wins, so permanently poisoned requests are modeled
    by *not* suspending the single-request retry path for dispatch
    faults (see ``MVEScheduler._run_single``).
    """

    def __init__(self, plan: FaultPlan,
                 sleep: Callable[[float], None] = time.sleep):
        self.plan = plan
        self.sleep = sleep
        self._lock = threading.Lock()
        self._remaining: List[int] = [s.times for s in plan.specs]
        self._skip: List[int] = [s.after for s in plan.specs]
        #: replay log: one dict per firing, in firing order
        self.fired: List[Dict] = []
        self._suspend = threading.local()

    # -- suspension (recovery/audit paths run shielded) --------------------
    def suspended(self):
        return _Suspension(self)

    def _is_suspended(self) -> bool:
        return getattr(self._suspend, "depth", 0) > 0

    # -- counters ----------------------------------------------------------
    @property
    def injected(self) -> int:
        with self._lock:
            return len(self.fired)

    def counts(self) -> Dict[str, int]:
        """Firings per kind (health-snapshot payload)."""
        with self._lock:
            out: Dict[str, int] = {}
            for f in self.fired:
                out[f["kind"]] = out.get(f["kind"], 0) + 1
            return out

    # -- site entry points -------------------------------------------------
    def compile(self, rids: Sequence[int] = (), tier: Optional[str] = None):
        self._hit("compile", rids, tier)

    def dispatch(self, rids: Sequence[int] = (), tier: Optional[str] = None,
                 shielded: bool = False):
        """``shielded=True`` matches only rid-bound sticky specs — the
        recovery path's semantics (see class docstring)."""
        self._hit("dispatch", rids, tier, shielded=shielded)

    def finalize(self, rids: Sequence[int], tier: Optional[str],
                 memory: np.ndarray,
                 rows: Optional[Dict[int, int]] = None) -> np.ndarray:
        """Fire finalize faults; returns the (possibly bit-flipped)
        memory.  ``memory`` is one image (1-D) or a stacked batch with
        ``rows`` mapping rid -> leading-axis row."""
        flips = self._hit("finalize", rids, tier, collect_bitflips=True)
        if not flips:
            return memory
        mem = np.array(memory, copy=True)
        for spec, rid in flips:
            row = mem if mem.ndim == 1 else mem[rows[rid]] \
                if rows and rid in rows else mem[0]
            _flip_bit(row, spec.word, spec.bit)
        return mem

    def worker_tick(self):
        """Called by the serving worker between batches."""
        self._hit("worker", (), None)

    def engine_hook(self, site: str, **ctx):
        """Adapter for :func:`repro.core.vm.set_fault_hook` — deep
        executor-boundary chaos (sites ``engine.compile``,
        ``engine.dispatch``, ``engine.finalize``, ``vm.dispatch``,
        ``vm.finalize``)."""
        self._hit(site, ctx.get("rids", ()), ctx.get("tier"))

    # -- matching core -----------------------------------------------------
    def _hit(self, site: str, rids: Sequence[int], tier: Optional[str],
             collect_bitflips: bool = False, shielded: bool = False):
        if self._is_suspended():
            return []
        rids = list(rids)
        sleeps: List[float] = []
        error: Optional[BaseException] = None
        flips: List[Tuple[FaultSpec, int]] = []
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if error is not None:
                    break
                if spec.site != site or self._remaining[i] == 0:
                    continue
                if spec.tier is not None and tier is not None \
                        and spec.tier != tier:
                    continue
                if shielded and not (spec.rid is not None
                                     and spec.times == -1):
                    continue
                rid = None
                if spec.rid is not None:
                    if spec.rid not in rids:
                        continue
                    rid = spec.rid
                if self._skip[i] > 0:
                    self._skip[i] -= 1
                    continue
                # fire
                if self._remaining[i] > 0:
                    self._remaining[i] -= 1
                self.fired.append({"site": site, "kind": spec.kind,
                                   "rid": rid, "tier": tier,
                                   "t": time.perf_counter()})
                if spec.kind == "straggler":
                    sleeps.append(spec.latency_s)
                elif spec.kind == "bitflip":
                    if collect_bitflips:
                        flips.append((spec, rid if rid is not None
                                      else (rids[0] if rids else 0)))
                elif spec.kind == "kill":
                    error = InjectedWorkerDeath(
                        f"injected worker death at {site}")
                else:
                    error = InjectedFault(
                        f"injected {site} fault"
                        + (f" for rid {rid}" if rid is not None else "")
                        + (f" on tier {tier}" if tier else ""))
        for s in sleeps:
            if s > 0:
                self.sleep(s)
        if error is not None:
            raise error
        return flips


class _Suspension:
    def __init__(self, inj: FaultInjector):
        self._inj = inj

    def __enter__(self):
        tl = self._inj._suspend
        tl.depth = getattr(tl, "depth", 0) + 1
        return self

    def __exit__(self, *exc):
        self._inj._suspend.depth -= 1
        return False


def _flip_bit(row: np.ndarray, word: int, bit: int) -> None:
    """XOR one bit of one word in-place (SRAM cell-fault model)."""
    itemsize = row.dtype.itemsize
    if itemsize == 8:
        u = row.view(np.uint64)
    elif itemsize == 4:
        u = row.view(np.uint32)
    elif itemsize == 2:
        u = row.view(np.uint16)
    else:
        u = row.view(np.uint8)
    w = word % u.size
    u[w] ^= np.asarray(1 << (bit % (8 * itemsize)), dtype=u.dtype)
