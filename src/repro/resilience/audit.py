"""Sampled integrity audit of served results.

A bit-flipped SRAM cell does not raise — it *silently* serves a wrong
answer.  The only defense is re-computation on an independent path and a
bit-exact compare, which is exactly the repo's conformance contract
(``tests/test_conformance.py``: interp == fused == VM, bit for bit).
:class:`ResultAuditor` packages that contract as a runtime component the
scheduler samples per served request:

* ``method="cross"`` — re-execute on the *other* executor (a VM-served
  result is checked against the fused engine and vice versa).  Cheap
  (one extra warm dispatch) and catches any single-executor corruption,
  because the two executors share no datapath code past the program
  walk.
* ``method="oracle"`` — re-execute on the stepwise interpreter, the
  semantic ground truth.  Orders of magnitude slower; for forensic runs
  and low sample rates.

On mismatch the auditor returns the reference payload — the scheduler
serves *that*, counts ``audit_corrected``, and records a breaker failure
against the corrupted executor tier so repeat corruption demotes it.

Sampling is deterministic per ``(seed, rid)``: the same chaos replay
audits the same requests, independent of retry interleaving.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import numpy as np

AuditReference = Tuple[np.ndarray, Dict[int, np.ndarray], np.ndarray]


class ResultAuditor:
    def __init__(self, rate: float = 1.0, seed: int = 0,
                 method: str = "cross", injector=None):
        if method not in ("cross", "oracle"):
            raise ValueError(f"unknown audit method {method!r}")
        self.rate = float(rate)
        self.seed = seed
        self.method = method
        self.injector = injector        # suspended during reference runs
        self._lock = threading.Lock()
        self.checked = 0
        self.mismatches = 0
        self._oracles: Dict[object, object] = {}

    def should_audit(self, rid: int) -> bool:
        if self.rate <= 0.0:
            return False
        if self.rate >= 1.0:
            return True
        return np.random.default_rng((self.seed, rid)).random() < self.rate

    # -- the check ---------------------------------------------------------
    def check(self, program, memory_in, cfg, served_memory, served_tag,
              served_mode: str) -> Optional[AuditReference]:
        """Bit-compare a served result against an independent
        re-execution; returns ``None`` when it verifies, else the
        reference ``(memory, regs, tag)`` to serve instead."""
        ref = self._reference(program, memory_in, cfg, served_mode)
        mem, regs, tag = ref
        with self._lock:
            self.checked += 1
        if np.array_equal(mem, np.asarray(served_memory)) and \
                np.array_equal(tag, np.asarray(served_tag)):
            return None
        with self._lock:
            self.mismatches += 1
        return ref

    def _reference(self, program, memory_in, cfg,
                   served_mode: str) -> AuditReference:
        if self.injector is not None:
            with self.injector.suspended():
                return self._reference_unshielded(
                    program, memory_in, cfg, served_mode)
        return self._reference_unshielded(program, memory_in, cfg,
                                          served_mode)

    def _reference_unshielded(self, program, memory_in, cfg,
                              served_mode: str) -> AuditReference:
        program = list(program)
        if self.method == "cross" and served_mode in ("vm", "fused"):
            from ..core.engine import compile_program
            other = "fused" if served_mode == "vm" else "vm"
            cp = compile_program(program, cfg, mode=other)
            if cp.mode != served_mode:      # no silent same-path "audit"
                mem, state = cp.run(memory_in)
                return (np.asarray(mem),
                        {r: np.asarray(v) for r, v in state.regs.items()},
                        np.asarray(state.tag))
        # oracle method, an oracle-served result, or a cross request whose
        # other mode fell back to the served one: stepwise ground truth.
        mem_i, st_i = self._oracle(cfg).run_stepwise(program, memory_in)
        return (np.asarray(mem_i),
                {r: np.asarray(v) for r, v in st_i.regs.items()},
                np.asarray(st_i.tag))

    def _oracle(self, cfg):
        o = self._oracles.get(cfg)
        if o is None:
            from ..core.interp import MVEInterpreter
            o = self._oracles[cfg] = MVEInterpreter(cfg, compiled=False)
        return o

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"checked": self.checked, "mismatches": self.mismatches}
