"""Typed failure vocabulary for the serving runtime.

Every :meth:`~repro.runtime.scheduler.MVEScheduler.submit` resolves with
a result **or one of these errors** — never a bare ``RuntimeError`` from
three frames inside an executor, and never a waiter left hanging on an
orphaned ticket.  Clients branch on the type:

==========================  ==============================================
error                        meaning / recommended client action
==========================  ==============================================
``SchedulerClosedError``     scheduler shut down before (or while) the
                             request was in flight — resubmit elsewhere
``CancelledError``           the client cancelled the ticket
``DeadlineExceededError``    retries/backoff could not finish before the
                             request deadline — the request *may* be
                             retried with a fresher deadline
``QueueFullError``           shed by the bounded admission queue
                             (backpressure) — back off and resubmit
``QuarantinedError``         the request (or its program) keeps poisoning
                             dispatches on every tier; it is isolated so
                             the rest of the batch serves.  Carries the
                             final underlying error as ``__cause__``
``WorkerDiedError``          the serving worker died while the request
                             was in hand and could not be recovered
==========================  ==============================================

The executor-level types (:class:`repro.core.engine.ExecutorError` and
its ``CompileError`` / ``DispatchError`` / ``FinalizeError`` subclasses)
classify *where* inside the execution stack a failure surfaced; the
scheduler consumes those internally — what escapes to a client is always
one of the types above, or the executor error itself once every tier and
retry is exhausted.
"""
from __future__ import annotations


class SchedulerError(RuntimeError):
    """Base of every typed serving-runtime failure."""


class SchedulerClosedError(SchedulerError):
    """The scheduler was closed; the request was resolved, not served."""


class CancelledError(SchedulerError):
    """The client cancelled the ticket before it was served."""


class DeadlineExceededError(SchedulerError):
    """The per-request deadline passed before a successful dispatch."""


class QueueFullError(SchedulerError):
    """Bounded admission queue is full and the policy is ``"shed"``."""


class QuarantinedError(SchedulerError):
    """The request failed on every tier and was quarantined.

    ``attempts`` counts executions tried across tiers/retries; the last
    underlying failure is chained as ``__cause__``.
    """

    def __init__(self, msg: str, attempts: int = 0):
        super().__init__(msg)
        self.attempts = attempts


class WorkerDiedError(SchedulerError):
    """The background worker died with this request in hand."""


class InjectedFault(RuntimeError):
    """An error deliberately raised by the fault injector (chaos runs).

    Deliberately *not* a :class:`SchedulerError`: injected faults model
    infrastructure failures (a flaky executor, a dying thread), so the
    scheduler must classify and recover from them exactly as it would
    from the real thing.
    """


class InjectedWorkerDeath(InjectedFault):
    """Injected death of the serving worker thread (supervisor test)."""
