"""repro.resilience — fault injection, breakers, retries, result audit.

The failure-semantics layer under the serving runtime
(:mod:`repro.runtime.scheduler` composes these; docs/SERVING.md
"Failure semantics" is the design note):

* :mod:`~repro.resilience.faults` — deterministic, replayable fault
  injection (:class:`FaultPlan` / :class:`FaultInjector`): compile and
  dispatch errors, artificial straggler latency, SRAM-model memory
  bit-flips, worker-thread death.
* :mod:`~repro.resilience.breaker` — :class:`CircuitBreaker` (per
  signature x target x tier) and :class:`RetryPolicy` (bounded,
  exponential backoff).
* :mod:`~repro.resilience.audit` — :class:`ResultAuditor`, the sampled
  bit-exact re-execution check that catches silent corruption.
* :mod:`~repro.resilience.errors` — the typed error vocabulary every
  ticket resolves with when it cannot resolve with a result.
"""
from .audit import ResultAuditor  # noqa: F401
from .breaker import CircuitBreaker, RetryPolicy  # noqa: F401
from .errors import (CancelledError, DeadlineExceededError,  # noqa: F401
                     InjectedFault, InjectedWorkerDeath, QueueFullError,
                     QuarantinedError, SchedulerClosedError, SchedulerError,
                     WorkerDiedError)
from .faults import FaultInjector, FaultPlan, FaultSpec  # noqa: F401
