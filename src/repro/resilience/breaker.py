"""Circuit breaking and bounded retry for the serving runtime.

Small, deterministic, clock-injectable policy objects — the scheduler
composes them, tests drive them with fake clocks.

* :class:`CircuitBreaker` — classic three-state breaker keyed by an
  arbitrary hashable (the scheduler keys per ``(signature bucket,
  target, tier)``): ``closed`` serves normally, ``threshold``
  consecutive failures **open** it (callers skip the tier — graceful
  degradation), and after ``cooldown_s`` it goes **half-open**, letting
  one probe through; a probe success closes it, a probe failure re-opens
  the cooldown window.
* :class:`RetryPolicy` — bounded retry with exponential backoff;
  ``delays()`` yields the sleep before each retry, so the total added
  latency is a closed-form bound the deadline checker can reason about.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Hashable, Iterator, List, Tuple

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


class CircuitBreaker:
    """Per-key failure breaker (see module docstring).

    ``allow(key)`` is the gate: ``True`` while closed — and exactly once
    per cooldown window while open (the half-open probe).  Record the
    outcome of every allowed attempt via ``record_success`` /
    ``record_failure``.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self._lock = threading.Lock()
        # key -> [consecutive_failures, opened_at | None, probing]
        self._state: Dict[Hashable, List] = {}
        self.opens = 0           # lifetime open transitions (stats)

    def allow(self, key: Hashable) -> bool:
        with self._lock:
            st = self._state.get(key)
            if st is None or st[1] is None:
                return True
            if st[2]:                       # a probe is already out
                return False
            if self.clock() - st[1] >= self.cooldown_s:
                st[2] = True                # half-open: let one probe through
                return True
            return False

    def record_success(self, key: Hashable) -> None:
        with self._lock:
            self._state.pop(key, None)      # fully closed + forgotten

    def record_failure(self, key: Hashable) -> bool:
        """Returns ``True`` when this failure opened (or re-opened) the
        breaker."""
        with self._lock:
            st = self._state.setdefault(key, [0, None, False])
            st[0] += 1
            if st[2] or (st[1] is None and st[0] >= self.threshold):
                st[1], st[2] = self.clock(), False
                self.opens += 1
                return True
            return False

    def state(self, key: Hashable) -> str:
        with self._lock:
            st = self._state.get(key)
            if st is None or st[1] is None:
                return CLOSED
            if st[2] or self.clock() - st[1] >= self.cooldown_s:
                return HALF_OPEN
            return OPEN

    def snapshot(self) -> Dict[str, str]:
        """Non-closed breakers as ``{str(key): state}`` (health payload)."""
        with self._lock:
            keys = list(self._state)
        out = {}
        for k in keys:
            s = self.state(k)
            if s != CLOSED:
                out[str(k)] = s
        return out


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff.

    ``max_retries`` is the number of *re*-executions after the first
    attempt; ``delays()`` yields the pre-retry sleeps:
    ``backoff_s * factor**i`` for ``i in range(max_retries)``.
    """

    max_retries: int = 2
    backoff_s: float = 0.001
    factor: float = 2.0

    def delays(self) -> Iterator[float]:
        for i in range(self.max_retries):
            yield self.backoff_s * (self.factor ** i)

    @property
    def worst_case_sleep_s(self) -> float:
        return sum(self.delays())
