"""Runtime substrate: serving scheduler, health, stragglers, elasticity.

The typed errors a scheduler ticket can resolve with live in
:mod:`repro.resilience` and are re-exported here for serving callers.
"""
from ..resilience.errors import (CancelledError,  # noqa: F401
                                 DeadlineExceededError, QuarantinedError,
                                 QueueFullError, SchedulerClosedError,
                                 SchedulerError, WorkerDiedError)
from .health import (ElasticPlan, HeartbeatMonitor,  # noqa: F401
                     StragglerDetector, plan_elastic_remesh)
from .scheduler import (MVEScheduler, SchedulerStats,  # noqa: F401
                        ServeResult, Ticket)
