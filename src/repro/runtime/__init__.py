"""Distributed-runtime substrate: health, stragglers, elasticity."""
from .health import (ElasticPlan, HeartbeatMonitor,  # noqa: F401
                     StragglerDetector, plan_elastic_remesh)
