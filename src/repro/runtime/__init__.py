"""Runtime substrate: serving scheduler, health, stragglers, elasticity."""
from .health import (ElasticPlan, HeartbeatMonitor,  # noqa: F401
                     StragglerDetector, plan_elastic_remesh)
from .scheduler import (MVEScheduler, SchedulerStats,  # noqa: F401
                        ServeResult, Ticket)
