"""Cluster-health runtime: heartbeats, straggler detection, elastic plans.

On a real multi-host deployment these observers run on the coordinator
(host 0) next to the JAX distributed service; here they are fully
deterministic, clock-injectable components with unit tests, wired into
``launch/train.py``:

  * ``HeartbeatMonitor`` — hosts report each step; silence beyond a
    timeout marks the host dead and triggers a restart-from-checkpoint
    decision (fail-stop model, the standard for TPU pods).
  * ``StragglerDetector`` — robust (median/MAD) per-host step-time outlier
    detection; persistent stragglers are proposed for eviction rather than
    letting them gate every synchronous step.
  * ``plan_elastic_remesh`` — given survivors, picks the largest
    supported (pods, data, model) mesh <= available chips and the
    checkpoint resharding plan (keep TP extent, shrink DP — gradients
    stay correct under data-parallel rescaling).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        now = clock()
        self._last: Dict[str, float] = {h: now for h in hosts}
        self._dead: set = set()

    def beat(self, host: str) -> None:
        if host in self._dead:
            self._dead.discard(host)       # host came back (restart)
        self._last[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        for h, t in self._last.items():
            if now - t > self.timeout_s:
                self._dead.add(h)
        return sorted(self._dead)

    def healthy(self) -> bool:
        return not self.dead_hosts()


class StragglerDetector:
    """Flags hosts whose step time is a robust outlier for several
    consecutive windows (mitigation: eviction or re-balancing)."""

    def __init__(self, window: int = 8, mad_threshold: float = 4.0,
                 persistence: int = 3):
        self.window = window
        self.mad_threshold = mad_threshold
        self.persistence = persistence
        self._times: Dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))
        self._flags: Dict[str, int] = defaultdict(int)

    def record(self, host: str, step_time_s: float) -> None:
        self._times[host].append(step_time_s)

    def stragglers(self) -> List[str]:
        meds = {h: float(np.median(t)) for h, t in self._times.items()
                if len(t) >= self.window // 2}
        if len(meds) < 3:
            return []
        vals = np.array(list(meds.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        out = []
        for h, v in meds.items():
            if (v - med) / mad > self.mad_threshold:
                self._flags[h] += 1
            else:
                self._flags[h] = 0
            if self._flags[h] >= self.persistence:
                out.append(h)
        return sorted(out)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    pods: int
    data: int
    model: int
    dropped_chips: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


def plan_elastic_remesh(available_chips: int, model_parallel: int = 16,
                        chips_per_pod: int = 256) -> ElasticPlan:
    """Largest (pod, data, model) mesh that fits the survivors.

    TP extent is preserved (parameter shardings stay valid); the DP extent
    shrinks to the largest power-of-two of surviving chips; whole pods are
    preferred so the pod axis keeps its DCN meaning.
    """
    if available_chips < model_parallel:
        raise ValueError("not enough chips for one model-parallel group")
    pods = max(1, available_chips // chips_per_pod)
    while pods > 1:
        if pods * chips_per_pod <= available_chips:
            break
        pods -= 1
    per_pod = available_chips // pods
    data = 1
    while data * 2 * model_parallel <= per_pod:
        data *= 2
    used = pods * data * model_parallel
    return ElasticPlan(pods=pods, data=data, model=model_parallel,
                       dropped_chips=available_chips - used)
