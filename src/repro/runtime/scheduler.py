"""Signature-batched multi-tenant scheduler for MVE program serving.

The execution stack so far serves one caller at a time: every
``CompiledProgram.run`` pays its own host round trip (pad + upload +
dispatch + sync + copy-back), so a realistic serving stream — many
logical clients submitting daxpy/gemv/spmm/conv programs concurrently,
the Swan workload mix of Table III — leaves both the 8192-lane SIMD
array's batch dimension and JAX's async dispatch queue idle.  This
module adds the missing layer: an :class:`MVEScheduler` that accepts
``submit(program, memory)`` requests from many clients, coalesces them,
and executes each group as one batched dispatch.

Scheduling policy (docs/SERVING.md has the design note):

* Pending requests are bucketed by :meth:`CompiledProgram.batch_group_key`
  — for VM-routed requests that is the **VM signature bucket** (plus the
  program and memory geometry), so every group's dispatch replays through
  one signature-keyed XLA executable; groups of one signature are
  dispatched back to back to keep that executable hot.
* Within a bucket, requests for the *same* program are padded to a
  power-of-two batch (bounded by ``max_batch``), their memory images
  stacked, and executed as **one** ``run_batch`` (vmapped) dispatch.
* Two executor tiers, exactly like a tiered JIT: every program can run
  through the **VM tier** immediately (the signature-shared executable —
  zero per-program XLA compiles, which is what keeps a stream of
  data-dependent programs, e.g. one spmm program per sparsity pattern,
  servable at all), and a program whose submission count reaches
  ``promote_after`` is **promoted to the fused tier**, whose per-program
  batched executable is ~an order of magnitude faster per image on the
  measured CPU substrate (``BENCH_engine.json`` ``serving`` section).
  ``promote_after=None`` disables promotion (pure-VM scheduler).
* All group dispatches of a drain cycle are enqueued asynchronously
  (``run_batch_async`` / ``run_async``); the scheduler syncs once per
  cycle, not once per request.

Targets: ``submit(..., target="rvv-1d")`` accepts any registered
:mod:`repro.targets` target — requests bucket per target (compilations
are tagged so one target's entries never alias another's) and the
resolved machine config rides on the ticket; unknown or
geometry-mismatched targets raise a readable
:class:`~repro.core.isa.ProgramError` (docs/TARGETS.md).

Determinism: with ``background=False`` (default) nothing executes until
:meth:`MVEScheduler.drain`, which processes every pending request on the
calling thread — submission order decides batch composition, so tests
replay identical schedules.  With ``background=True`` a worker thread
forms batches with a ``max_batch``/``max_wait_ms`` window policy, and
:meth:`submit` returns tickets that resolve concurrently.

Results are bit-identical to per-request ``CompiledProgram.run`` (and
therefore to the stepwise oracle): batching only stacks independent
memory images along a vmapped axis.  ``tests/test_conformance.py``
fuzzes that equivalence across all four executors.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import isa
from ..core.cost import TraceEvent
from ..core.engine import CompiledProgram, cache_info, compile_program
from ..core.machine import MVEConfig, next_pow2

# Bookkeeping bounds: a long-lived server facing an endless stream of
# fresh (data-dependent) programs must not grow per-program state without
# limit — mirrors the engine's bounded program LRU.
_SEEN_CAP = 4096          # submission counters (promotion heat)
_PROMOTED_CAP = 64        # fused-tier executables pinned by the scheduler
_BUCKET_STAT_CAP = 4096   # distinct group keys tracked for stats


class ServeResult:
    """Per-request outcome, duck-type compatible with
    :class:`repro.core.engine.ExecutionResult` for the common fields.

    ``trace`` is materialized lazily for batched results (a fresh copy of
    the compile-time static trace): serving loops that never read it pay
    nothing for it.
    """

    __slots__ = ("memory", "regs", "tag", "batch_size", "tier",
                 "_trace", "_trace_fn", "kernel", "_operands")

    def __init__(self, memory: np.ndarray, regs: Dict[int, np.ndarray],
                 tag: np.ndarray, batch_size: int, tier: str,
                 trace: Optional[List[TraceEvent]] = None,
                 trace_fn: Optional[Callable[[], List[TraceEvent]]] = None,
                 kernel=None):
        self.memory = memory
        self.regs = regs
        self.tag = tag
        self.batch_size = batch_size   # how many requests shared the dispatch
        self.tier = tier               # "vm" | "fused" | "single"
        self._trace = trace
        self._trace_fn = trace_fn
        self.kernel = kernel           # frontend Kernel, when submitted as one
        self._operands = None

    @property
    def trace(self) -> List[TraceEvent]:
        if self._trace is None:
            self._trace = self._trace_fn() if self._trace_fn else []
        return self._trace

    @property
    def operands(self) -> Optional[Dict[str, np.ndarray]]:
        """Results read back by operand name (kernel submissions only);
        materialised lazily like ``trace``."""
        if self._operands is None and self.kernel is not None:
            self._operands = self.kernel.unpack(self.memory)
        return self._operands

    def __repr__(self) -> str:
        return (f"ServeResult(tier={self.tier!r}, "
                f"batch_size={self.batch_size}, "
                f"memory.shape={tuple(np.shape(self.memory))})")


class Ticket:
    """Future-like handle returned by :meth:`MVEScheduler.submit`."""

    def __init__(self, rid: int, program, memory, cp: CompiledProgram,
                 submitted_at: Optional[float] = None, kernel=None,
                 cfg: Optional[MVEConfig] = None,
                 target: Optional[str] = None):
        self.rid = rid
        self.program = program
        self.memory = memory
        self.cp = cp
        self.kernel = kernel
        self.cfg = cfg                 # machine config this request runs under
        self.target = target           # registered target name (None=default)
        self.submitted_at = submitted_at if submitted_at is not None \
            else time.perf_counter()
        self.done_at: Optional[float] = None
        self._event = threading.Event()
        self._result: Optional[ServeResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        """Block until the request is served (or ``timeout`` seconds)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served in time")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency(self) -> float:
        """Submit-to-completion wall time in seconds."""
        if self.done_at is None:
            raise RuntimeError("request not finished")
        return self.done_at - self.submitted_at

    def _resolve(self, result=None, error=None) -> None:
        self._result, self._error = result, error
        self.done_at = time.perf_counter()
        self._event.set()


@dataclasses.dataclass
class SchedulerStats:
    """Counters since construction (see also :meth:`cache_info`)."""

    requests: int = 0
    dispatches: int = 0          # executable launches (any tier)
    batched_requests: int = 0    # requests served by a >1 dispatch
    vm_batches: int = 0
    fused_batches: int = 0
    singles: int = 0
    promotions: int = 0          # programs promoted to the fused tier
    drains: int = 0
    max_batch_seen: int = 0
    signature_buckets: int = 0   # distinct group keys seen

    @property
    def batch_efficiency(self) -> float:
        """Mean requests per dispatch — the lane-saturation proxy."""
        return self.requests / self.dispatches if self.dispatches else 0.0


class MVEScheduler:
    """Multi-tenant MVE program scheduler with signature batching.

    Parameters
    ----------
    cfg: machine config shared by every request (one lane geometry).
    mode: executor for the base tier (engine default: ``"vm"``).
    max_batch: largest fused-tier dispatch; groups beyond it are split.
    vm_max_batch: largest VM-tier dispatch.  The vmapped while-loop
        datapath stops gaining past small batches on the CPU substrate
        (measured sweet spot ~4), while the fused tier keeps scaling.
    promote_after: submissions of one program after which it is compiled
        into the fused tier (``None`` disables promotion).
    background: serve from a worker thread (``max_wait_ms`` batching
        window) instead of explicit :meth:`drain` calls.
    """

    def __init__(self, cfg: Optional[MVEConfig] = None,
                 mode: Optional[str] = None, max_batch: int = 16,
                 vm_max_batch: int = 4,
                 promote_after: Optional[int] = 2,
                 background: bool = False, max_wait_ms: float = 2.0):
        self.cfg = cfg or MVEConfig()
        self.mode = mode
        # Batch caps are floored to powers of two: dispatch stacks are
        # padded to the next power of two, so a non-pow2 cap would let a
        # padded dispatch exceed it.
        self.max_batch = _floor_pow2(max(1, int(max_batch)))
        self.vm_max_batch = _floor_pow2(max(1, int(vm_max_batch)))
        self.promote_after = promote_after
        self.max_wait_ms = max_wait_ms
        self.stats = SchedulerStats()
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._serve_lock = threading.Lock()      # drain() vs worker _serve
        self._pending: List[Ticket] = []
        # program key -> submissions (bounded LRU: promotion heat only)
        self._seen: "OrderedDict[Tuple, int]" = OrderedDict()
        self._promoted: "OrderedDict[Tuple, CompiledProgram]" = OrderedDict()
        self._group_keys_seen = set()
        self._wake = threading.Condition(self._lock)
        self._closed = False
        self._worker: Optional[threading.Thread] = None
        if background:
            self._worker = threading.Thread(
                target=self._serve_loop, daemon=True, name="mve-scheduler")
            self._worker.start()

    # -- client API --------------------------------------------------------
    def _resolve_target(self, target) -> Tuple[MVEConfig, Optional[str]]:
        """(machine config, cache tag) for one submission's target.

        Unknown names raise :class:`~repro.core.isa.ProgramError` naming
        every registered target; a target whose machine geometry cannot
        share this scheduler's lane/CB layout is rejected the same way —
        both used to surface as ``KeyError``-shaped internal failures.
        """
        if target is None:
            return self.cfg, None
        from .. import targets as _targets
        tgt = _targets.get_target(target)      # ProgramError when unknown
        cfg = tgt.machine_config(self.cfg)
        if (cfg.lanes, cfg.num_cbs) != (self.cfg.lanes, self.cfg.num_cbs):
            raise isa.ProgramError(
                f"target {tgt.name!r} needs machine geometry "
                f"(lanes={cfg.lanes}, cbs={cfg.num_cbs}) but this "
                f"scheduler batches for (lanes={self.cfg.lanes}, "
                f"cbs={self.cfg.num_cbs}); submit it to a scheduler "
                f"built with that geometry.  Registered targets: "
                f"{', '.join(_targets.list_targets())}")
        return cfg, tgt.name

    def submit(self, program: isa.Program, memory=None,
               target=None) -> Ticket:
        """Enqueue one program execution; returns a :class:`Ticket`.

        ``program`` is a raw instruction sequence plus a flat memory
        image, or a frontend :class:`~repro.frontend.Kernel` plus a dict
        of named operand arrays (or nothing — declared inits apply);
        kernel submissions read results back by name through
        ``ticket.result().operands``.

        ``target`` selects a registered :mod:`repro.targets` target (a
        name or instance).  Execution is bit-identical on every target —
        the scheduler's value per target is *bucketing*: requests are
        grouped per target (so per-target compilations never alias,
        ``cache_info().per_target``) and the resolved machine config
        rides on the ticket for downstream pricing.  Unknown or
        geometry-mismatched targets raise a
        :class:`~repro.core.isa.ProgramError` naming the registered
        targets.

        Thread-safe; callable from any number of client threads.  In
        deterministic mode nothing runs until :meth:`drain`."""
        submitted_at = time.perf_counter()   # before the (cold) compile
        cfg, tag = self._resolve_target(target)
        kernel = None
        if hasattr(program, "plan") and hasattr(program, "program"):
            kernel = program
            if memory is None or isinstance(memory, dict):
                memory = kernel.pack(memory)  # named arrays / inits
            # else: an already-packed flat memory image — pass through
            program = kernel.program
        elif memory is None:
            raise TypeError("raw program submissions need a memory image")
        cp = compile_program(kernel or program, cfg, mode=self.mode,
                             cache_tag=tag)
        t = Ticket(next(self._rid), tuple(program), memory, cp,
                   submitted_at=submitted_at, kernel=kernel,
                   cfg=cfg, target=tag)
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self.stats.requests += 1
            pk = (t.program, cfg, tag)
            self._seen[pk] = self._seen.get(pk, 0) + 1
            self._seen.move_to_end(pk)
            while len(self._seen) > _SEEN_CAP:
                self._seen.popitem(last=False)
            self._pending.append(t)
            self._wake.notify()
        return t

    def submit_many(self, requests: Sequence[Tuple[isa.Program, object]]
                    ) -> List[Ticket]:
        return [self.submit(p, m) for p, m in requests]

    def drain(self) -> None:
        """Serve every pending request on the calling thread and return
        when all are resolved — the deterministic mode tests replay."""
        while True:
            with self._lock:
                batch, self._pending = self._pending, []
            if not batch:
                return
            self._serve(batch)

    def close(self) -> None:
        """Stop the background worker (drains what is pending first)."""
        with self._lock:
            self._closed = True
            self._wake.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=30)
            self._worker = None
        self.drain()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def cache_info(self):
        """The engine/VM compile-cache counters this scheduler feeds —
        promotion compiles land in the same program LRU, VM dispatches in
        the same signature-keyed executable cache
        (:func:`repro.core.engine.cache_info`)."""
        return cache_info()

    # -- background worker -------------------------------------------------
    def _serve_loop(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._wake.wait()
                if self._closed and not self._pending:
                    return
                deadline = time.perf_counter() + self.max_wait_ms / 1e3
                # batching window: wait for more work until the window
                # closes or a full batch is ready
                while (len(self._pending) < self.max_batch
                       and not self._closed):
                    left = deadline - time.perf_counter()
                    if left <= 0 or not self._wake.wait(timeout=left):
                        break
                batch, self._pending = self._pending, []
            if batch:
                try:
                    self._serve(batch)
                except BaseException as e:   # pragma: no cover - backstop
                    for t in batch:
                        if not t.done():
                            t._resolve(error=e)

    # -- the scheduling core -----------------------------------------------
    def _serve(self, batch: List[Ticket]) -> None:
        """Group -> dispatch (async) -> finalize, one sync per cycle.

        Serialized with ``_serve_lock``: an explicit :meth:`drain` racing
        the background worker must not interleave stats/promotion
        bookkeeping (each still serves only tickets it popped itself)."""
        with self._serve_lock:
            self._serve_locked(batch)

    def _serve_locked(self, batch: List[Ticket]) -> None:
        self.stats.drains += 1
        buckets: "OrderedDict[tuple, OrderedDict[tuple, List[Ticket]]]" = \
            OrderedDict()
        for t in batch:
            # Per-target signature bucketing: the leading tag keeps one
            # target's groups from stacking with another's even when the
            # VM signature coincides (their cost models differ; pricing
            # rides on the ticket's target).
            key = (t.target,) + tuple(t.cp.batch_group_key(t.memory))
            gkey = (t.program, key)
            buckets.setdefault(key, OrderedDict()).setdefault(
                gkey, []).append(t)
            if len(self._group_keys_seen) < _BUCKET_STAT_CAP:
                self._group_keys_seen.add(key)
        self.stats.signature_buckets = len(self._group_keys_seen)

        dispatches = []   # (tickets, tier, finalize_thunk)
        for key, groups in buckets.items():
            # Same signature bucket back to back: every VM group replays
            # through the same signature-keyed executable while it is hot.
            # Only VM-routed requests (key[1], after the target tag) get
            # the VM-tier batch cap; fused-routed ones
            # (non-float32-canonical images, VM fallbacks) batch at the
            # full fused cap.
            routed_vm = key[1] == "vm"
            for (prog, _), tickets in groups.items():
                try:
                    fused = self._promotable(tickets[0])
                except BaseException as e:
                    for t in tickets:
                        t._resolve(error=e)
                    continue
                cap = self.vm_max_batch if routed_vm and fused is None \
                    else self.max_batch
                for chunk in _chunks(tickets, cap):
                    try:
                        dispatches.append(
                            self._dispatch(prog, chunk, fused, routed_vm))
                    except BaseException as e:
                        for t in chunk:
                            t._resolve(error=e)

        for tickets, tier, finalize in dispatches:
            try:
                results = finalize()
                for t, r in zip(tickets, results):
                    t._resolve(result=r)
            except BaseException as e:
                for t in tickets:
                    t._resolve(error=e)

    def _dispatch(self, prog: tuple, tickets: List[Ticket], fused,
                  routed_vm: bool = True):
        """Launch one group asynchronously; returns a finalize thunk."""
        cp = tickets[0].cp
        n = len(tickets)
        if n == 1:
            # Singleton: skip the vmap wrapper (and get the exact
            # random-access trace for free via finalize_run).
            runner = fused if fused is not None else cp
            pending = runner.run_async(tickets[0].memory)
            self.stats.dispatches += 1
            self.stats.singles += 1
            self.stats.max_batch_seen = max(self.stats.max_batch_seen, 1)

            def fin_single():
                mem, state = runner.finalize_run(pending)
                return [ServeResult(memory=np.asarray(mem),
                                    regs=state.regs, tag=state.tag,
                                    batch_size=1, tier="single",
                                    trace=state.trace,
                                    kernel=tickets[0].kernel)]
            return tickets, "single", fin_single

        runner = fused if fused is not None else cp
        tier = "vm" if fused is None and routed_vm else "fused"
        # Pad the stack to a power of two so each program compiles at most
        # log2(max_batch) batched executables; padded rows replay the
        # first request's image and are dropped after the dispatch.
        bucket = next_pow2(n)
        mems = [np.asarray(t.memory) for t in tickets]
        stacked = np.stack(mems + [mems[0]] * (bucket - n))
        pending = runner.run_batch_async(stacked)
        self.stats.dispatches += 1
        self.stats.batched_requests += n
        self.stats.max_batch_seen = max(self.stats.max_batch_seen, n)
        if tier == "fused":
            self.stats.fused_batches += 1
        else:
            self.stats.vm_batches += 1

        def fin_batch():
            mem, regs, tag = runner.finalize_batch(pending)
            # One device->host transfer per array (not per request): the
            # per-request views below slice host memory.
            mem = np.asarray(mem)
            tag = np.asarray(tag)
            regs = {r: np.asarray(v) for r, v in regs.items()}

            def trace_fn():
                # Deferred static_trace access too: unread traces cost
                # nothing on the dispatch hot path.
                return [dataclasses.replace(ev) for ev in cp.static_trace]

            out = []
            for b in range(n):
                out.append(ServeResult(
                    memory=mem[b],
                    regs={r: v[b] for r, v in regs.items()},
                    tag=tag[b], batch_size=n, tier=tier,
                    trace_fn=trace_fn, kernel=tickets[b].kernel))
            return out
        return tickets, tier, fin_batch

    def _promotable(self, ticket: Ticket) -> Optional[CompiledProgram]:
        """The fused-tier executable for a hot program, compiling it on
        first promotion; ``None`` while the program stays in the VM tier
        (or when promotion is off / the program already runs fused).
        Promotion heat and the fused compilation are both per
        ``(program, config, target)`` — one target's promotion never
        serves (or evicts) another's."""
        cp = ticket.cp
        if self.promote_after is None or cp.mode == "fused":
            return None
        pk = (ticket.program, ticket.cfg, ticket.target)
        hot = self._promoted.get(pk)
        if hot is not None:
            self._promoted.move_to_end(pk)
            return hot
        if self._seen.get(pk, 0) < self.promote_after:
            return None
        hot = compile_program(list(pk[0]), ticket.cfg, mode="fused",
                              cache_tag=ticket.target)
        self._promoted[pk] = hot
        while len(self._promoted) > _PROMOTED_CAP:
            self._promoted.popitem(last=False)
        self.stats.promotions += 1
        return hot


def _chunks(seq: List, n: int):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def _floor_pow2(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)
