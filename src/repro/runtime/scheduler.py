"""Signature-batched multi-tenant scheduler for MVE program serving.

The execution stack so far serves one caller at a time: every
``CompiledProgram.run`` pays its own host round trip (pad + upload +
dispatch + sync + copy-back), so a realistic serving stream — many
logical clients submitting daxpy/gemv/spmm/conv programs concurrently,
the Swan workload mix of Table III — leaves both the 8192-lane SIMD
array's batch dimension and JAX's async dispatch queue idle.  This
module adds the missing layer: an :class:`MVEScheduler` that accepts
``submit(program, memory)`` requests from many clients, coalesces them,
and executes each group as one batched dispatch.

Scheduling policy (docs/SERVING.md has the design note):

* Pending requests are bucketed by :meth:`CompiledProgram.batch_group_key`
  — for VM-routed requests that is the **VM signature bucket** (plus the
  program and memory geometry), so every group's dispatch replays through
  one signature-keyed XLA executable; groups of one signature are
  dispatched back to back to keep that executable hot.
* Within a bucket, requests for the *same* program are padded to a
  power-of-two batch (bounded by ``max_batch``), their memory images
  stacked, and executed as **one** ``run_batch`` (vmapped) dispatch.
* Two executor tiers, exactly like a tiered JIT: every program can run
  through the **VM tier** immediately (the signature-shared executable —
  zero per-program XLA compiles, which is what keeps a stream of
  data-dependent programs, e.g. one spmm program per sparsity pattern,
  servable at all), and a program whose submission count reaches
  ``promote_after`` is **promoted to the fused tier**, whose per-program
  batched executable is ~an order of magnitude faster per image on the
  measured CPU substrate (``BENCH_engine.json`` ``serving`` section).
  ``promote_after=None`` disables promotion (pure-VM scheduler).
* All group dispatches of a drain cycle are enqueued asynchronously
  (``run_batch_async`` / ``run_async``); the scheduler syncs once per
  cycle, not once per request.

Targets: ``submit(..., target="rvv-1d")`` accepts any registered
:mod:`repro.targets` target — requests bucket per target (compilations
are tagged so one target's entries never alias another's) and the
resolved machine config rides on the ticket; unknown or
geometry-mismatched targets raise a readable
:class:`~repro.core.isa.ProgramError` (docs/TARGETS.md).

Determinism: with ``background=False`` (default) nothing executes until
:meth:`MVEScheduler.drain`, which processes every pending request on the
calling thread — submission order decides batch composition, so tests
replay identical schedules.  With ``background=True`` a worker thread
forms batches with a ``max_batch``/``max_wait_ms`` window policy, and
:meth:`submit` returns tickets that resolve concurrently.

Failure semantics (docs/SERVING.md "Failure semantics"; the components
live in :mod:`repro.resilience`):

* **Every submit() resolves** — with a result or a typed
  :class:`~repro.resilience.errors.SchedulerError`.  No orphaned
  tickets: a timed-out waiter can :meth:`Ticket.cancel`, :meth:`close`
  resolves everything still pending with ``SchedulerClosedError``, and
  the serve cycle carries a backstop that resolves any ticket an
  internal error would otherwise drop.
* **Bounded retry + per-request deadlines** — transient dispatch
  failures replay with exponential backoff
  (:class:`~repro.resilience.breaker.RetryPolicy`); a request past its
  deadline resolves with ``DeadlineExceededError`` instead of retrying
  forever.
* **Batch bisection** — one poisoned request must not fail its vmapped
  batch: a failed group dispatch is split in half and re-dispatched
  until the poison is isolated; clean halves still serve *batched*, the
  poisoned request is retried alone, then **quarantined** (resolved with
  ``QuarantinedError``; re-submissions are rejected until a cooldown
  expires).
* **Circuit breakers + tier degradation** — failures are recorded per
  ``(signature bucket, target, tier)``; an open breaker demotes traffic
  down the ladder *fused → VM → stepwise oracle* (the oracle is pure
  Python: slow, but it cannot share a failure mode with the jitted
  executors), with half-open probes re-admitting a recovered tier.
* **Bounded admission queue** — ``max_queue`` + ``admission="block"``
  (backpressure the submitter) or ``"shed"`` (resolve immediately with
  ``QueueFullError``).
* **Supervised worker** — the background thread heartbeats a
  :class:`~repro.runtime.health.HeartbeatMonitor`; if it dies
  mid-stream, in-hand tickets are re-queued and a supervisor restarts
  the thread, so a worker death is invisible to clients (chaos-tested
  with injected thread deaths).
* **Sampled integrity audit** — optional bit-exact re-execution of
  served results on an independent executor
  (:class:`~repro.resilience.audit.ResultAuditor`) catches silent
  corruption (the SRAM bit-flip model); corrupted results are replaced
  by the verified reference and the corrupting tier accumulates breaker
  failures.

Fault injection: pass ``injector=FaultInjector(plan)`` to run a
deterministic chaos schedule against the real scheduler paths —
``benchmarks/resilience_bench.py`` measures throughput under 0/1/10 %
injected fault rates and ``tests/test_resilience.py`` replays a seeded
10 % chaos stream and asserts full recovery.

Results are bit-identical to per-request ``CompiledProgram.run`` (and
therefore to the stepwise oracle): batching only stacks independent
memory images along a vmapped axis.  ``tests/test_conformance.py``
fuzzes that equivalence across all four executors.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import isa
from ..core.cost import TraceEvent
from ..core.engine import CompiledProgram, cache_info, compile_program
from ..core.machine import MVEConfig, next_pow2
from ..resilience.audit import ResultAuditor
from ..resilience.breaker import CircuitBreaker, RetryPolicy
from ..resilience.errors import (CancelledError, DeadlineExceededError,
                                 InjectedWorkerDeath, QuarantinedError,
                                 QueueFullError, SchedulerClosedError,
                                 SchedulerError, WorkerDiedError)
from .health import HeartbeatMonitor, StragglerDetector

# Bookkeeping bounds: a long-lived server facing an endless stream of
# fresh (data-dependent) programs must not grow per-program state without
# limit — mirrors the engine's bounded program LRU.
_SEEN_CAP = 4096          # submission counters (promotion heat)
_PROMOTED_CAP = 64        # fused-tier executables pinned by the scheduler
_BUCKET_STAT_CAP = 4096   # distinct group keys tracked for stats
_QUARANTINE_CAP = 1024    # poisoned program keys remembered

#: name of the (single) serving worker in the heartbeat monitor
_WORKER_HOST = "serve-worker"


class ServeResult:
    """Per-request outcome, duck-type compatible with
    :class:`repro.core.engine.ExecutionResult` for the common fields.

    ``tier`` records which executor produced it: ``"vm"`` / ``"fused"``
    (batched dispatches), ``"single"`` (un-batched engine dispatch) or
    ``"oracle"`` (stepwise-interpreter fallback of the degradation
    ladder).

    ``trace`` is materialized lazily for batched results (a fresh copy of
    the compile-time static trace): serving loops that never read it pay
    nothing for it.
    """

    __slots__ = ("memory", "regs", "tag", "batch_size", "tier",
                 "_trace", "_trace_fn", "kernel", "_operands")

    def __init__(self, memory: np.ndarray, regs: Dict[int, np.ndarray],
                 tag: np.ndarray, batch_size: int, tier: str,
                 trace: Optional[List[TraceEvent]] = None,
                 trace_fn: Optional[Callable[[], List[TraceEvent]]] = None,
                 kernel=None):
        self.memory = memory
        self.regs = regs
        self.tag = tag
        self.batch_size = batch_size   # how many requests shared the dispatch
        self.tier = tier               # "vm" | "fused" | "single" | "oracle"
        self._trace = trace
        self._trace_fn = trace_fn
        self.kernel = kernel           # frontend Kernel, when submitted as one
        self._operands = None

    @property
    def trace(self) -> List[TraceEvent]:
        if self._trace is None:
            self._trace = self._trace_fn() if self._trace_fn else []
        return self._trace

    @property
    def operands(self) -> Optional[Dict[str, np.ndarray]]:
        """Results read back by operand name (kernel submissions only);
        materialised lazily like ``trace``."""
        if self._operands is None and self.kernel is not None:
            self._operands = self.kernel.unpack(self.memory)
        return self._operands

    def __repr__(self) -> str:
        return (f"ServeResult(tier={self.tier!r}, "
                f"batch_size={self.batch_size}, "
                f"memory.shape={tuple(np.shape(self.memory))})")


class Ticket:
    """Future-like handle returned by :meth:`MVEScheduler.submit`.

    Resolution is race-safe and exactly-once: the first of {scheduler
    result, scheduler error, :meth:`cancel`, :meth:`MVEScheduler.close`}
    wins and the rest are no-ops, so a timed-out :meth:`result` waiter
    can always cancel without racing an in-flight resolution.
    """

    def __init__(self, rid: int, program, memory, cp: CompiledProgram,
                 submitted_at: Optional[float] = None, kernel=None,
                 cfg: Optional[MVEConfig] = None,
                 target: Optional[str] = None,
                 deadline: Optional[float] = None):
        self.rid = rid
        self.program = program
        self.memory = memory
        self.cp = cp
        self.kernel = kernel
        self.cfg = cfg                 # machine config this request runs under
        self.target = target           # registered target name (None=default)
        self.deadline = deadline       # absolute perf_counter() deadline
        self.submitted_at = submitted_at if submitted_at is not None \
            else time.perf_counter()
        self.done_at: Optional[float] = None
        self._event = threading.Event()
        self._resolve_lock = threading.Lock()
        self._result: Optional[ServeResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        """Block until the request is served (or ``timeout`` seconds).

        A ``TimeoutError`` does **not** orphan the ticket: it stays
        pending and will still be resolved by the scheduler — call
        :meth:`cancel` to resolve it now and drop the request."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} not served in time "
                f"(ticket still pending; cancel() to abandon it)")
        if self._error is not None:
            raise self._error
        return self._result

    def error(self) -> Optional[BaseException]:
        """The resolution error, if the ticket failed (non-blocking)."""
        return self._error if self._event.is_set() else None

    def cancel(self) -> bool:
        """Resolve the ticket with
        :class:`~repro.resilience.errors.CancelledError` if it is still
        pending.  Returns ``True`` when the cancellation won the race,
        ``False`` when the ticket was already resolved (its result/error
        stands).  The scheduler skips cancelled tickets at dispatch."""
        return self._resolve(error=CancelledError(
            f"request {self.rid} cancelled by client"))

    @property
    def latency(self) -> float:
        """Submit-to-completion wall time in seconds."""
        if self.done_at is None:
            raise RuntimeError("request not finished")
        return self.done_at - self.submitted_at

    def _resolve(self, result=None, error=None) -> bool:
        """First resolution wins; returns whether this call resolved."""
        with self._resolve_lock:
            if self._event.is_set():
                return False
            self._result, self._error = result, error
            self.done_at = time.perf_counter()
            self._event.set()
            return True


@dataclasses.dataclass
class SchedulerStats:
    """Counters since construction (see also :meth:`cache_info` and
    :meth:`MVEScheduler.health`)."""

    requests: int = 0
    dispatches: int = 0          # executable launches (any tier)
    batched_requests: int = 0    # requests served by a >1 dispatch
    vm_batches: int = 0
    fused_batches: int = 0
    singles: int = 0
    promotions: int = 0          # programs promoted to the fused tier
    drains: int = 0
    max_batch_seen: int = 0
    signature_buckets: int = 0   # distinct group keys seen
    # -- resilience (PR 7) -------------------------------------------------
    retries: int = 0             # single-request re-executions after failure
    bisections: int = 0          # failed batches split to isolate poison
    recovered: int = 0           # requests served after >= 1 failure
    oracle_serves: int = 0       # requests served by the stepwise oracle tier
    demotions: int = 0           # tier steps down the fused->vm->oracle ladder
    quarantines: int = 0         # requests resolved with QuarantinedError
    quarantine_rejects: int = 0  # submissions rejected while quarantined
    breaker_opens: int = 0       # circuit-breaker open transitions
    breaker_skips: int = 0       # dispatches skipped because a breaker was open
    promotion_failures: int = 0  # fused-tier compiles that failed
    deadline_misses: int = 0     # requests resolved with DeadlineExceededError
    sheds: int = 0               # requests shed by the bounded admission queue
    audit_checked: int = 0       # served results integrity-audited
    audit_corrected: int = 0     # audited results replaced by the reference
    worker_restarts: int = 0     # background worker deaths survived
    worker_errors: int = 0       # serve-cycle failures caught by the backstop

    @property
    def batch_efficiency(self) -> float:
        """Mean requests per dispatch — the lane-saturation proxy."""
        return self.requests / self.dispatches if self.dispatches else 0.0


@dataclasses.dataclass
class _DispatchCtx:
    """Everything the recovery path needs to replay a group dispatch."""

    prog: tuple
    key: tuple                   # target-tagged signature bucket
    fused: Optional[CompiledProgram]
    routed_vm: bool


class MVEScheduler:
    """Multi-tenant MVE program scheduler with signature batching and
    self-healing failure semantics (module docstring; docs/SERVING.md).

    Parameters
    ----------
    cfg: machine config shared by every request (one lane geometry).
    mode: executor for the base tier (engine default: ``"vm"``).
    max_batch: largest fused-tier dispatch; groups beyond it are split.
    vm_max_batch: largest VM-tier dispatch.  The vmapped while-loop
        datapath stops gaining past small batches on the CPU substrate
        (measured sweet spot ~4), while the fused tier keeps scaling.
    promote_after: submissions of one program after which it is compiled
        into the fused tier (``None`` disables promotion).
    background: serve from a worker thread (``max_wait_ms`` batching
        window) instead of explicit :meth:`drain` calls.
    max_queue: bound on the pending-request queue (``None`` = unbounded).
    admission: ``"block"`` (submit waits for space — needs a concurrent
        drainer, i.e. ``background=True`` or another thread calling
        :meth:`drain`) or ``"shed"`` (resolve immediately with
        ``QueueFullError``).
    default_deadline_s: deadline applied to submissions that do not pass
        their own (``None`` = no deadline).
    retry: :class:`~repro.resilience.breaker.RetryPolicy` for failed
        single-request re-executions.
    breaker: :class:`~repro.resilience.breaker.CircuitBreaker` keyed per
        ``(signature bucket, tier)``; open tiers are skipped (degradation
        ladder fused → vm → oracle).
    quarantine_cooldown_s: how long a poisoned program key is rejected
        before one probe submission is allowed again.
    audit_rate / audit_method / audit_seed: sampled integrity audit of
        served results (:class:`~repro.resilience.audit.ResultAuditor`);
        rate 0 disables.
    injector: :class:`~repro.resilience.faults.FaultInjector` executing a
        deterministic chaos plan against this scheduler's serve paths.
    """

    def __init__(self, cfg: Optional[MVEConfig] = None,
                 mode: Optional[str] = None, max_batch: int = 16,
                 vm_max_batch: int = 4,
                 promote_after: Optional[int] = 2,
                 background: bool = False, max_wait_ms: float = 2.0,
                 max_queue: Optional[int] = None,
                 admission: str = "block",
                 default_deadline_s: Optional[float] = None,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 quarantine_cooldown_s: float = 30.0,
                 audit_rate: float = 0.0,
                 audit_method: str = "cross",
                 audit_seed: int = 0,
                 injector=None):
        self.cfg = cfg or MVEConfig()
        self.mode = mode
        # Batch caps are floored to powers of two: dispatch stacks are
        # padded to the next power of two, so a non-pow2 cap would let a
        # padded dispatch exceed it.
        self.max_batch = _floor_pow2(max(1, int(max_batch)))
        self.vm_max_batch = _floor_pow2(max(1, int(vm_max_batch)))
        self.promote_after = promote_after
        self.max_wait_ms = max_wait_ms
        if admission not in ("block", "shed"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.max_queue = max_queue
        self.admission = admission
        self.default_deadline_s = default_deadline_s
        self.retry = retry or RetryPolicy()
        self.quarantine_cooldown_s = quarantine_cooldown_s
        self.stats = SchedulerStats()
        # Threshold > (1 + default max_retries): one permanently poisoned
        # request exhausting its per-tier retries must not open a breaker
        # that healthy siblings of the same signature bucket share.
        self._breaker = breaker or CircuitBreaker(threshold=5,
                                                  cooldown_s=5.0)
        self._injector = injector
        self._auditor = ResultAuditor(
            rate=audit_rate, seed=audit_seed, method=audit_method,
            injector=injector) if audit_rate > 0.0 else None
        self._heartbeat = HeartbeatMonitor(hosts=[], timeout_s=10.0)
        self._stragglers = StragglerDetector(window=8)
        self._sleep = time.sleep           # patchable in tests
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._serve_lock = threading.Lock()      # drain() vs worker _serve
        self._pending: List[Ticket] = []
        # program key -> submissions (bounded LRU: promotion heat only)
        self._seen: "OrderedDict[Tuple, int]" = OrderedDict()
        self._promoted: "OrderedDict[Tuple, CompiledProgram]" = OrderedDict()
        self._quarantined: "OrderedDict[Tuple, float]" = OrderedDict()
        self._oracles: Dict[MVEConfig, object] = {}
        self._group_keys_seen = set()
        self._wake = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)  # queue has room
        self._closed = False
        self._worker: Optional[threading.Thread] = None
        if background:
            self._worker = threading.Thread(
                target=self._worker_main, daemon=True, name="mve-scheduler")
            self._worker.start()

    # -- client API --------------------------------------------------------
    def _resolve_target(self, target) -> Tuple[MVEConfig, Optional[str]]:
        """(machine config, cache tag) for one submission's target.

        Unknown names raise :class:`~repro.core.isa.ProgramError` naming
        every registered target; a target whose machine geometry cannot
        share this scheduler's lane/CB layout is rejected the same way —
        both used to surface as ``KeyError``-shaped internal failures.
        """
        if target is None:
            return self.cfg, None
        from .. import targets as _targets
        tgt = _targets.get_target(target)      # ProgramError when unknown
        cfg = tgt.machine_config(self.cfg)
        if (cfg.lanes, cfg.num_cbs) != (self.cfg.lanes, self.cfg.num_cbs):
            raise isa.ProgramError(
                f"target {tgt.name!r} needs machine geometry "
                f"(lanes={cfg.lanes}, cbs={cfg.num_cbs}) but this "
                f"scheduler batches for (lanes={self.cfg.lanes}, "
                f"cbs={self.cfg.num_cbs}); submit it to a scheduler "
                f"built with that geometry.  Registered targets: "
                f"{', '.join(_targets.list_targets())}")
        return cfg, tgt.name

    def submit(self, program: isa.Program, memory=None,
               target=None, deadline_s: Optional[float] = None) -> Ticket:
        """Enqueue one program execution; returns a :class:`Ticket`.

        ``program`` is a raw instruction sequence plus a flat memory
        image, or a frontend :class:`~repro.frontend.Kernel` plus a dict
        of named operand arrays (or nothing — declared inits apply);
        kernel submissions read results back by name through
        ``ticket.result().operands``.

        ``target`` selects a registered :mod:`repro.targets` target (a
        name or instance).  Execution is bit-identical on every target —
        the scheduler's value per target is *bucketing*: requests are
        grouped per target (so per-target compilations never alias,
        ``cache_info().per_target``) and the resolved machine config
        rides on the ticket for downstream pricing.  Unknown or
        geometry-mismatched targets raise a
        :class:`~repro.core.isa.ProgramError` naming the registered
        targets.

        ``deadline_s`` bounds this request's submit-to-resolution time
        (default: the scheduler's ``default_deadline_s``); past it the
        ticket resolves with ``DeadlineExceededError`` instead of
        retrying further.

        The returned ticket **always resolves** — with a
        :class:`ServeResult` or a typed
        :class:`~repro.resilience.errors.SchedulerError`.

        Thread-safe; callable from any number of client threads.  In
        deterministic mode nothing runs until :meth:`drain`."""
        submitted_at = time.perf_counter()   # before the (cold) compile
        cfg, tag = self._resolve_target(target)
        kernel = None
        if hasattr(program, "plan") and hasattr(program, "program"):
            kernel = program
            if memory is None or isinstance(memory, dict):
                memory = kernel.pack(memory)  # named arrays / inits
            # else: an already-packed flat memory image — pass through
            program = kernel.program
        elif memory is None:
            raise TypeError("raw program submissions need a memory image")
        cp = compile_program(kernel or program, cfg, mode=self.mode,
                             cache_tag=tag)
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        t = Ticket(next(self._rid), tuple(program), memory, cp,
                   submitted_at=submitted_at, kernel=kernel,
                   cfg=cfg, target=tag,
                   deadline=None if deadline_s is None
                   else submitted_at + deadline_s)
        with self._lock:
            if self._closed:
                raise SchedulerClosedError("scheduler is closed")
            if self.max_queue is not None:
                while len(self._pending) >= self.max_queue \
                        and not self._closed:
                    if self.admission == "shed":
                        self.stats.sheds += 1
                        t._resolve(error=QueueFullError(
                            f"admission queue full "
                            f"({self.max_queue} pending); request "
                            f"{t.rid} shed — back off and resubmit"))
                        return t
                    self._space.wait(timeout=0.05)
                if self._closed:
                    raise SchedulerClosedError("scheduler closed while "
                                               "waiting for queue space")
            self.stats.requests += 1
            pk = (t.program, cfg, tag)
            self._seen[pk] = self._seen.get(pk, 0) + 1
            self._seen.move_to_end(pk)
            while len(self._seen) > _SEEN_CAP:
                self._seen.popitem(last=False)
            self._pending.append(t)
            self._wake.notify()
        return t

    def submit_many(self, requests: Sequence[Tuple[isa.Program, object]]
                    ) -> List[Ticket]:
        return [self.submit(p, m) for p, m in requests]

    def drain(self) -> None:
        """Serve every pending request on the calling thread and return
        when all are resolved — the deterministic mode tests replay."""
        while True:
            with self._lock:
                batch, self._pending = self._pending, []
                self._space.notify_all()
            if not batch:
                return
            self._serve(batch)

    def close(self, drain: bool = True) -> None:
        """Shut down: stop the background worker, optionally serve what
        is still pending (``drain=True``, the default), then resolve
        every ticket that remains unresolved with a typed
        :class:`~repro.resilience.errors.SchedulerClosedError` — no
        waiter is ever left hanging on a closed scheduler."""
        with self._lock:
            self._closed = True
            self._wake.notify_all()
            self._space.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=30)
            self._worker = None
        if drain:
            self.drain()
        with self._lock:
            leftovers, self._pending = self._pending, []
        for t in leftovers:
            t._resolve(error=SchedulerClosedError(
                f"scheduler closed before request {t.rid} was served"))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def cache_info(self):
        """The engine/VM compile-cache counters this scheduler feeds —
        promotion compiles land in the same program LRU, VM dispatches in
        the same signature-keyed executable cache
        (:func:`repro.core.engine.cache_info`)."""
        return cache_info()

    def health(self) -> Dict:
        """One structured snapshot of the runtime's failure state:
        worker liveness/heartbeats, per-tier straggler flags, open
        circuit breakers, quarantine population, and the
        retry/shed/deadline/audit counters — the payload a mesh-level
        coordinator would scrape (ROADMAP device-mesh item)."""
        with self._lock:
            pending = len(self._pending)
            quarantined = len(self._quarantined)
            worker = self._worker
        st = self.stats
        snap = {
            "pending": pending,
            "closed": self._closed,
            "worker": {
                "alive": worker.is_alive() if worker is not None else None,
                "restarts": st.worker_restarts,
                "errors": st.worker_errors,
                "dead_hosts": self._heartbeat.dead_hosts(),
            },
            "stragglers": self._stragglers.stragglers(),
            "breakers": {"open": self._breaker.snapshot(),
                         "opens": st.breaker_opens,
                         "skips": st.breaker_skips},
            "quarantine": {"active": quarantined,
                           "total": st.quarantines,
                           "rejects": st.quarantine_rejects},
            "counters": {
                "requests": st.requests,
                "retries": st.retries,
                "bisections": st.bisections,
                "recovered": st.recovered,
                "oracle_serves": st.oracle_serves,
                "demotions": st.demotions,
                "deadline_misses": st.deadline_misses,
                "sheds": st.sheds,
                "promotion_failures": st.promotion_failures,
            },
            "audit": (self._auditor.counters()
                      if self._auditor is not None else None),
            "injected": (self._injector.counts()
                         if self._injector is not None else None),
        }
        return snap

    # -- background worker -------------------------------------------------
    def _worker_main(self) -> None:
        """Supervisor shell around :meth:`_serve_loop`: a worker death
        (injected or real) re-queues whatever the dead incarnation held
        and restarts the loop — zero orphaned tickets, invisible to
        clients."""
        while True:
            try:
                self._serve_loop()
                return                          # clean close
            except (KeyboardInterrupt, SystemExit):
                raise
            except BaseException:
                with self._lock:
                    self.stats.worker_restarts += 1
                    if self._closed and not self._pending:
                        return
                continue

    def _serve_loop(self) -> None:
        while True:
            batch: List[Ticket] = []
            try:
                with self._lock:
                    while not self._pending and not self._closed:
                        self._wake.wait()
                    if self._closed and not self._pending:
                        return
                    deadline = time.perf_counter() + self.max_wait_ms / 1e3
                    # batching window: wait for more work until the window
                    # closes or a full batch is ready
                    while (len(self._pending) < self.max_batch
                           and not self._closed):
                        left = deadline - time.perf_counter()
                        if left <= 0 or not self._wake.wait(timeout=left):
                            break
                    batch, self._pending = self._pending, []
                    self._space.notify_all()
                self._heartbeat.beat(_WORKER_HOST)
                if self._injector is not None:
                    self._injector.worker_tick()   # may kill this worker
                if batch:
                    self._serve(batch)
            except InjectedWorkerDeath:
                # Simulated thread death: put the work back for the next
                # incarnation (the supervisor restarts us) and die.
                self._requeue(batch)
                raise
            except (KeyboardInterrupt, SystemExit) as e:
                # Re-raise after resolving: in-flight tickets must never
                # be dropped on the interpreter-shutdown path.
                self.stats.worker_errors += 1
                for t in batch:
                    t._resolve(error=WorkerDiedError(
                        f"serving worker interrupted "
                        f"({type(e).__name__})"))
                raise
            except BaseException as e:   # pragma: no cover - backstop
                # _serve() has its own per-ticket error handling; anything
                # that still escapes is an internal error — account for it
                # and resolve, never drop.
                self.stats.worker_errors += 1
                for t in batch:
                    t._resolve(error=e)

    def _requeue(self, batch: List[Ticket]) -> None:
        alive = [t for t in batch if not t.done()]
        if not alive:
            return
        with self._lock:
            self._pending[:0] = alive       # head: preserve arrival order
            self._wake.notify_all()

    # -- the scheduling core -----------------------------------------------
    def _serve(self, batch: List[Ticket]) -> None:
        """Group -> dispatch (async) -> finalize -> recover, one sync per
        healthy cycle.

        Serialized with ``_serve_lock``: an explicit :meth:`drain` racing
        the background worker must not interleave stats/promotion
        bookkeeping (each still serves only tickets it popped itself).
        The ``finally`` backstop upholds the resolution guarantee even
        against internal scheduler bugs."""
        with self._serve_lock:
            try:
                self._serve_locked(batch)
            except (KeyboardInterrupt, SystemExit):
                raise
            except BaseException as e:
                self.stats.worker_errors += 1
                for t in batch:
                    t._resolve(error=e)
            finally:
                for t in batch:
                    if t._resolve(error=SchedulerError(
                            f"request {t.rid} fell through the serve "
                            f"cycle (internal scheduler error)")):
                        self.stats.worker_errors += 1

    def _serve_locked(self, batch: List[Ticket]) -> None:
        self.stats.drains += 1
        now = time.perf_counter()
        live: List[Ticket] = []
        for t in batch:
            if t.done():                    # cancelled / shed / pre-resolved
                continue
            if t.deadline is not None and now > t.deadline:
                self.stats.deadline_misses += 1
                t._resolve(error=DeadlineExceededError(
                    f"request {t.rid} missed its deadline before "
                    f"dispatch"))
                continue
            live.append(t)

        buckets: "OrderedDict[tuple, OrderedDict[tuple, List[Ticket]]]" = \
            OrderedDict()
        for t in live:
            # Per-target signature bucketing: the leading tag keeps one
            # target's groups from stacking with another's even when the
            # VM signature coincides (their cost models differ; pricing
            # rides on the ticket's target).
            key = (t.target,) + tuple(t.cp.batch_group_key(t.memory))
            gkey = (t.program, key)
            buckets.setdefault(key, OrderedDict()).setdefault(
                gkey, []).append(t)
            if len(self._group_keys_seen) < _BUCKET_STAT_CAP:
                self._group_keys_seen.add(key)
        self.stats.signature_buckets = len(self._group_keys_seen)

        inflight = []   # (ctx, tickets, tier, finalize_thunk)
        for key, groups in buckets.items():
            # Same signature bucket back to back: every VM group replays
            # through the same signature-keyed executable while it is hot.
            # Only VM-routed requests (key[1], after the target tag) get
            # the VM-tier batch cap; fused-routed ones
            # (non-float32-canonical images, VM fallbacks) batch at the
            # full fused cap.
            routed_vm = key[1] == "vm"
            for (prog, _), tickets in groups.items():
                tickets = [t for t in tickets if not t.done()]
                if not tickets:
                    continue
                pk = (tickets[0].program, tickets[0].cfg,
                      tickets[0].target)
                if self._quarantine_active(pk):
                    self.stats.quarantine_rejects += len(tickets)
                    for t in tickets:
                        t._resolve(error=QuarantinedError(
                            f"request {t.rid}: program is quarantined "
                            f"after repeated failures (cooldown "
                            f"{self.quarantine_cooldown_s:.0f}s)"))
                    continue
                fused = self._promotable_safe(key, tickets[0])
                ctx = _DispatchCtx(prog=prog, key=key, fused=fused,
                                   routed_vm=routed_vm)
                btier = "fused" if fused is not None else tickets[0].cp.mode
                if not self._breaker.allow((key, btier)):
                    # Tier breaker open: skip the batched dispatch and
                    # walk each request down the degradation ladder.
                    self.stats.breaker_skips += 1
                    for t in tickets:
                        self._serve_one_resilient(ctx, t, None)
                    continue
                cap = self.vm_max_batch if routed_vm and fused is None \
                    else self.max_batch
                for chunk in _chunks(tickets, cap):
                    try:
                        inflight.append(
                            (ctx,) + self._dispatch(prog, chunk, fused,
                                                    routed_vm))
                    except (KeyboardInterrupt, SystemExit):
                        raise
                    except Exception as e:
                        self._breaker.record_failure((key, btier)) and \
                            self._note_open()
                        self._recover_group(ctx, chunk, e)

        for ctx, tickets, tier, finalize in inflight:
            btier = "fused" if ctx.fused is not None \
                else tickets[0].cp.mode
            t0 = time.perf_counter()
            try:
                results = finalize()
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                self._breaker.record_failure((ctx.key, btier)) and \
                    self._note_open()
                self._recover_group(
                    ctx, [t for t in tickets if not t.done()], e)
                continue
            self._stragglers.record(btier, time.perf_counter() - t0)
            self._breaker.record_success((ctx.key, btier))
            for t, r in zip(tickets, results):
                t._resolve(result=r)

    def _promotable_safe(self, key, ticket) -> Optional[CompiledProgram]:
        """:meth:`_promotable` behind the fused-tier breaker: a failed
        promotion compile is a tier failure, not a request failure — the
        group still serves on its base tier."""
        if self.promote_after is None or ticket.cp.mode == "fused":
            return None
        if not self._breaker.allow((key, "fused")):
            self.stats.breaker_skips += 1
            return None
        try:
            return self._promotable(ticket)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            self.stats.promotion_failures += 1
            self._breaker.record_failure((key, "fused")) and \
                self._note_open()
            return None

    def _note_open(self) -> bool:
        self.stats.breaker_opens += 1
        return True

    # -- recovery ladder ---------------------------------------------------
    def _recover_group(self, ctx: _DispatchCtx, tickets: List[Ticket],
                       err: Optional[BaseException]) -> None:
        """Bisect a failed group until the poison is isolated: clean
        halves re-dispatch *batched* (shielded from one-shot injected
        faults — the retry semantics), single failures walk the
        per-request resilient path."""
        tickets = [t for t in tickets if not t.done()]
        if not tickets:
            return
        if len(tickets) == 1:
            self._serve_one_resilient(ctx, tickets[0], err)
            return
        self.stats.bisections += 1
        mid = len(tickets) // 2
        for half in (tickets[:mid], tickets[mid:]):
            try:
                _, tier, fin = self._dispatch(ctx.prog, half, ctx.fused,
                                              ctx.routed_vm, shielded=True)
                results = fin()
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                self._recover_group(ctx, half, e)
                continue
            for t, r in zip(half, results):
                if t._resolve(result=r):
                    self.stats.recovered += 1

    def _serve_one_resilient(self, ctx: _DispatchCtx, t: Ticket,
                             first_err: Optional[BaseException]) -> None:
        """Serve one request through the degradation ladder
        fused → vm → stepwise oracle, with bounded retry + backoff per
        tier, deadline checks before every attempt, and quarantine as
        the end state."""
        if t.done():
            return
        last = first_err
        attempts = 0
        ladder: List[Tuple[str, Optional[CompiledProgram]]] = []
        if ctx.fused is not None:
            ladder.append(("fused", ctx.fused))
        if t.cp.mode not in [name for name, _ in ladder]:
            ladder.append((t.cp.mode, t.cp))
        ladder.append(("oracle", None))
        for tier, runner in ladder:
            bkey = (ctx.key, tier)
            if tier != "oracle" and not self._breaker.allow(bkey):
                self.stats.breaker_skips += 1
                self.stats.demotions += 1       # skipped == stepped down
                continue
            for delay in itertools.chain([0.0], self.retry.delays()):
                if delay > 0:
                    self._sleep(delay)
                if t.done():
                    return
                if t.deadline is not None \
                        and time.perf_counter() > t.deadline:
                    self.stats.deadline_misses += 1
                    t._resolve(error=DeadlineExceededError(
                        f"request {t.rid} exceeded its deadline after "
                        f"{attempts} recovery attempt(s)"))
                    return
                attempts += 1
                if attempts > 1 or first_err is not None:
                    self.stats.retries += 1
                try:
                    r = self._run_single(ctx, t, tier, runner)
                except (KeyboardInterrupt, SystemExit):
                    raise
                except Exception as e:
                    last = e
                    if tier != "oracle":
                        if self._breaker.record_failure(bkey):
                            self._note_open()
                            break           # tier just opened: demote now
                    continue
                if tier != "oracle":
                    self._breaker.record_success(bkey)
                if t._resolve(result=r):
                    if first_err is not None or attempts > 1:
                        self.stats.recovered += 1
                return
            self.stats.demotions += 1
        # Every tier (oracle included) failed: isolate the poison.
        self._quarantine_request(ctx, t, last, attempts)

    def _run_single(self, ctx: _DispatchCtx, t: Ticket, tier: str,
                    runner: Optional[CompiledProgram]) -> ServeResult:
        """One shielded single-request execution on a given tier.

        Shielded = one-shot injected faults do not re-fire (a retry runs
        on a fresh resource), but rid-bound *sticky* faults — the model
        of a permanently poisoned request — still do."""
        inj = self._injector
        if inj is not None:
            inj.dispatch([t.rid], tier, shielded=True)
        self.stats.dispatches += 1
        self.stats.singles += 1
        self.stats.max_batch_seen = max(self.stats.max_batch_seen, 1)
        if tier == "oracle":
            self.stats.oracle_serves += 1
            mem_i, st_i = self._oracle(t.cfg).run_stepwise(
                list(t.program), t.memory)
            return ServeResult(
                memory=np.asarray(mem_i),
                regs={r: np.asarray(v) for r, v in st_i.regs.items()},
                tag=np.asarray(st_i.tag), batch_size=1, tier="oracle",
                trace=st_i.trace, kernel=t.kernel)
        if inj is not None:
            with inj.suspended():           # recovery path is shielded
                mem_j, state = runner.run(t.memory)
        else:
            mem_j, state = runner.run(t.memory)
        mem = np.asarray(mem_j)
        regs, tag = state.regs, state.tag
        if self._auditor is not None and self._auditor.should_audit(t.rid):
            self.stats.audit_checked += 1
            ref = self._auditor.check(t.program, t.memory, t.cfg, mem,
                                      tag, runner.mode)
            if ref is not None:
                self.stats.audit_corrected += 1
                self._breaker.record_failure((ctx.key, tier)) and \
                    self._note_open()
                mem, regs, tag = ref
        return ServeResult(memory=mem, regs=regs, tag=tag, batch_size=1,
                           tier="single", trace=state.trace,
                           kernel=t.kernel)

    def _oracle(self, cfg: MVEConfig):
        o = self._oracles.get(cfg)
        if o is None:
            from ..core.interp import MVEInterpreter
            o = self._oracles[cfg] = MVEInterpreter(cfg, compiled=False)
        return o

    # -- quarantine --------------------------------------------------------
    def _quarantine_active(self, pk) -> bool:
        with self._lock:
            ts = self._quarantined.get(pk)
            if ts is None:
                return False
            if time.monotonic() - ts >= self.quarantine_cooldown_s:
                del self._quarantined[pk]   # parole: allow one probe
                return False
            return True

    def _quarantine_request(self, ctx: _DispatchCtx, t: Ticket,
                            last: Optional[BaseException],
                            attempts: int) -> None:
        pk = (t.program, t.cfg, t.target)
        with self._lock:
            self._quarantined[pk] = time.monotonic()
            self._quarantined.move_to_end(pk)
            while len(self._quarantined) > _QUARANTINE_CAP:
                self._quarantined.popitem(last=False)
        self.stats.quarantines += 1
        err = QuarantinedError(
            f"request {t.rid} failed on every tier after {attempts} "
            f"attempt(s); program quarantined for "
            f"{self.quarantine_cooldown_s:.0f}s "
            f"(last error: {type(last).__name__ if last else 'n/a'}: "
            f"{last})", attempts=attempts)
        err.__cause__ = last
        t._resolve(error=err)

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, prog: tuple, tickets: List[Ticket], fused,
                  routed_vm: bool = True, shielded: bool = False):
        """Launch one group asynchronously; returns a finalize thunk."""
        cp = tickets[0].cp
        btier = "fused" if fused is not None else cp.mode
        inj = self._injector
        rids = [t.rid for t in tickets]
        if inj is not None:
            inj.dispatch(rids, btier, shielded=shielded)
        n = len(tickets)
        auditor = self._auditor
        if n == 1:
            # Singleton: skip the vmap wrapper (and get the exact
            # random-access trace for free via finalize_run).
            runner = fused if fused is not None else cp
            pending = runner.run_async(tickets[0].memory)
            self.stats.dispatches += 1
            self.stats.singles += 1
            self.stats.max_batch_seen = max(self.stats.max_batch_seen, 1)

            def fin_single():
                mem, state = runner.finalize_run(pending)
                mem = np.asarray(mem)
                regs, tag = state.regs, state.tag
                if inj is not None and not shielded:
                    mem = inj.finalize(rids, btier, mem)
                if auditor is not None \
                        and auditor.should_audit(tickets[0].rid):
                    self.stats.audit_checked += 1
                    ref = auditor.check(tickets[0].program,
                                        tickets[0].memory, tickets[0].cfg,
                                        mem, tag, runner.mode)
                    if ref is not None:
                        self.stats.audit_corrected += 1
                        self._breaker.record_failure(
                            (ticket_key(tickets[0]), btier)) and \
                            self._note_open()
                        mem, regs, tag = ref
                return [ServeResult(memory=mem,
                                    regs=regs, tag=tag,
                                    batch_size=1, tier="single",
                                    trace=state.trace,
                                    kernel=tickets[0].kernel)]
            return tickets, "single", fin_single

        runner = fused if fused is not None else cp
        tier = "vm" if fused is None and routed_vm else "fused"
        # Pad the stack to a power of two so each program compiles at most
        # log2(max_batch) batched executables; padded rows replay the
        # first request's image and are dropped after the dispatch.
        bucket = next_pow2(n)
        mems = [np.asarray(t.memory) for t in tickets]
        stacked = np.stack(mems + [mems[0]] * (bucket - n))
        pending = runner.run_batch_async(stacked)
        self.stats.dispatches += 1
        self.stats.batched_requests += n
        self.stats.max_batch_seen = max(self.stats.max_batch_seen, n)
        if tier == "fused":
            self.stats.fused_batches += 1
        else:
            self.stats.vm_batches += 1

        def fin_batch():
            mem, regs, tag = runner.finalize_batch(pending)
            # One device->host transfer per array (not per request): the
            # per-request views below slice host memory.
            mem = np.asarray(mem)
            tag = np.asarray(tag)
            regs = {r: np.asarray(v) for r, v in regs.items()}
            if inj is not None and not shielded:
                rows = {t.rid: b for b, t in enumerate(tickets)}
                mem = inj.finalize(rids, btier, mem, rows)

            def trace_fn():
                # Deferred static_trace access too: unread traces cost
                # nothing on the dispatch hot path.
                return [dataclasses.replace(ev) for ev in cp.static_trace]

            out = []
            for b in range(n):
                t = tickets[b]
                rmem = mem[b]
                rregs = {r: v[b] for r, v in regs.items()}
                rtag = tag[b]
                if auditor is not None and auditor.should_audit(t.rid):
                    self.stats.audit_checked += 1
                    ref = auditor.check(t.program, t.memory, t.cfg,
                                        rmem, rtag, runner.mode)
                    if ref is not None:
                        self.stats.audit_corrected += 1
                        self._breaker.record_failure(
                            (ticket_key(t), btier)) and self._note_open()
                        rmem, rregs, rtag = ref
                out.append(ServeResult(
                    memory=rmem, regs=rregs, tag=rtag,
                    batch_size=n, tier=tier,
                    trace_fn=trace_fn, kernel=t.kernel))
            return out
        return tickets, tier, fin_batch

    def _promotable(self, ticket: Ticket) -> Optional[CompiledProgram]:
        """The fused-tier executable for a hot program, compiling it on
        first promotion; ``None`` while the program stays in the VM tier
        (or when promotion is off / the program already runs fused).
        Promotion heat and the fused compilation are both per
        ``(program, config, target)`` — one target's promotion never
        serves (or evicts) another's."""
        cp = ticket.cp
        if self.promote_after is None or cp.mode == "fused":
            return None
        pk = (ticket.program, ticket.cfg, ticket.target)
        hot = self._promoted.get(pk)
        if hot is not None:
            self._promoted.move_to_end(pk)
            return hot
        if self._seen.get(pk, 0) < self.promote_after:
            return None
        if self._injector is not None:
            self._injector.compile([ticket.rid], tier="fused")
        hot = compile_program(list(pk[0]), ticket.cfg, mode="fused",
                              cache_tag=ticket.target)
        self._promoted[pk] = hot
        while len(self._promoted) > _PROMOTED_CAP:
            self._promoted.popitem(last=False)
        self.stats.promotions += 1
        return hot


def ticket_key(t: Ticket) -> tuple:
    """The target-tagged signature bucket a ticket groups under."""
    return (t.target,) + tuple(t.cp.batch_group_key(t.memory))


def _chunks(seq: List, n: int):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def _floor_pow2(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)
