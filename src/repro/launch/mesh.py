"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
DCN dimension — only data parallelism (gradient all-reduce) crosses it.

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    grid = np.asarray(devices[:need]).reshape(shape)
    return Mesh(grid, axes)


def make_mesh(shape: Dict[str, int]) -> Mesh:
    """Arbitrary small mesh for tests, e.g. {'data': 2, 'model': 4}."""
    need = int(np.prod(list(shape.values())))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    grid = np.asarray(devices[:need]).reshape(tuple(shape.values()))
    return Mesh(grid, tuple(shape.keys()))
