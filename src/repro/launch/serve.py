"""Serving driver: continuous batching with MVE dimension-level masking.

The paper's central abstraction — pack multi-dimensional, irregular
parallelism onto a fixed wide lane axis and mask whole *dimension
elements* rather than per-element predicates — is exactly the shape of
continuous-batching decode:

  * the decode batch is a fixed :class:`repro.core.packing.LaneGrid`
    (requests = the highest dimension; a mask bit per request slot),
  * arriving requests claim masked-off slots; finished requests release
    them; prefill and decode interleave freely because every slot feeds
    its own next token (prompt token while prefilling, last sample after),
  * ONE jitted decode step serves whatever mix is resident: per-slot
    sequence positions ride in a (B,)-shaped cache index, and inactive
    slots are simply computed-and-discarded — dimension-level masking,
    not per-token predication.

CPU-runnable with reduced configs (examples/serve_batched.py); the decode
dry-run cells lower this same step for the production meshes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.packing import LaneGrid
from ..models.lm import LM


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclasses.dataclass
class SlotState:
    request: Request
    length: int = 0                     # tokens resident in this slot
    prompt_pos: int = 0                 # prompt tokens consumed


class ContinuousBatchingEngine:
    """Fixed-slot continuous batching, one batched decode per step.

    Greedy decoding; prefill streams prompt tokens through the same
    batched step (so a long prompt never stalls other slots)."""

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_seq: int, clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.model = LM(cfg)
        self.params = params
        self.max_seq = max_seq
        self.grid = LaneGrid((max_seq, batch_slots))   # top dim = requests
        self.clock = clock
        self._queue: List[Request] = []
        self._done: Dict[int, Request] = {}
        b = batch_slots
        cache_defs = self.model.cache_defs(b, max_seq)
        from ..models.common import DTYPES
        self.cache = jax.tree.map(
            lambda d: jnp.zeros(d.shape, DTYPES[d.dtype]),
            cache_defs, is_leaf=lambda x: hasattr(x, "shape") and
            hasattr(x, "dtype") and not isinstance(x, jnp.ndarray))
        self._decode = jax.jit(self.model.decode_step)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submitted_at = self.clock()
        self._queue.append(req)

    def _try_admit(self) -> None:
        while self._queue:
            slot = self.grid.allocate(None)
            if slot is None:
                return
            req = self._queue.pop(0)
            self.grid._payload[slot] = SlotState(req)

    # -- main loop -------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, one batched decode, evict.

        Returns the number of active slots served."""
        self._try_admit()
        active = self.grid.active_slots()
        if len(active) == 0:
            return 0
        b = self.grid.top
        tokens = np.zeros((b, 1), np.int32)
        lengths = np.zeros((b,), np.int32)
        for slot in active:
            st: SlotState = self.grid.payload(slot)
            req = st.request
            if st.prompt_pos < len(req.prompt):
                tokens[slot, 0] = int(req.prompt[st.prompt_pos])
            else:
                tokens[slot, 0] = req.output[-1]
            lengths[slot] = st.length
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lengths))
        logits_np = np.asarray(
            logits[:, : self.cfg.vocab_size], np.float32)

        served = 0
        for slot in active:
            st = self.grid.payload(slot)
            req = st.request
            st.length += 1
            served += 1
            if st.prompt_pos < len(req.prompt):
                st.prompt_pos += 1
                if st.prompt_pos < len(req.prompt):
                    continue             # still prefilling
            nxt = int(np.argmax(logits_np[slot]))
            req.output.append(nxt)
            if req.first_token_at is None:
                req.first_token_at = self.clock()
            eos = (req.eos_id is not None and nxt == req.eos_id)
            if (len(req.output) >= req.max_new_tokens or eos
                    or st.length >= self.max_seq - 1):
                req.done_at = self.clock()
                self._done[req.rid] = req
                self.grid.release(slot)
        return served

    def run_until_drained(self, max_iters: int = 10_000
                          ) -> Dict[int, Request]:
        it = 0
        while (self._queue or len(self.grid.active_slots())) and \
                it < max_iters:
            self.step()
            it += 1
        return self._done

    @property
    def occupancy(self) -> float:
        return self.grid.occupancy()


# ---------------------------------------------------------------------------
# MVE program serving: the front door over the signature-batched scheduler.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProgramRequest:
    """One client's MVE program submission (compare :class:`Request`).

    Timing and results delegate to the underlying scheduler
    :class:`~repro.runtime.scheduler.Ticket` — one source of truth."""

    rid: int
    program: tuple
    memory: np.ndarray
    ticket: object = None                # runtime.scheduler.Ticket
    result: Optional[object] = None      # ServeResult once served
    error: Optional[BaseException] = None  # typed SchedulerError on failure

    @property
    def submitted_at(self) -> float:
        return self.ticket.submitted_at

    @property
    def done_at(self) -> Optional[float]:
        return self.ticket.done_at

    @property
    def latency(self) -> float:
        """Submit-to-completion seconds; raises until finished."""
        return self.ticket.latency


class MVEProgramServer:
    """Serving facade for MVE programs: request bookkeeping + latency
    accounting over :class:`repro.runtime.scheduler.MVEScheduler`.

    The LM path above packs concurrent *decode* requests onto the lane
    grid; this path packs concurrent *program* requests onto vmapped
    batch dispatches grouped by VM signature — the same
    dimension-level-batching idea one level up the stack.  Used by
    ``benchmarks/serving_bench.py`` to replay the Table III workload mix.

    Thread-safe like the scheduler it wraps; ``keep_done`` bounds the
    finished-request history a long-lived server retains.
    """

    def __init__(self, scheduler=None, keep_done: int = 4096,
                 **scheduler_kwargs):
        import threading
        from collections import OrderedDict

        from ..runtime.scheduler import MVEScheduler
        self.scheduler = scheduler or MVEScheduler(**scheduler_kwargs)
        self.keep_done = keep_done
        self._lock = threading.Lock()
        self._next_rid = 0
        self._inflight: "OrderedDict[int, ProgramRequest]" = OrderedDict()
        self._done: "OrderedDict[int, ProgramRequest]" = OrderedDict()

    def submit(self, program, memory=None, target=None,
               deadline_s=None) -> ProgramRequest:
        """Accepts a raw ``(program, memory)`` pair or a frontend
        :class:`~repro.frontend.Kernel` plus named operand arrays — the
        same overloads as :meth:`MVEScheduler.submit`; kernel requests
        read results back by name (``req.result.operands``).  ``target``
        selects a registered :mod:`repro.targets` target (unknown names
        raise a ``ProgramError`` listing what is registered);
        ``deadline_s`` bounds the request's submit-to-resolution time."""
        ticket = self.scheduler.submit(program, memory, target=target,
                                       deadline_s=deadline_s)
        with self._lock:
            req = ProgramRequest(rid=self._next_rid,
                                 program=ticket.program,
                                 memory=ticket.memory, ticket=ticket)
            self._next_rid += 1
            self._inflight[req.rid] = req
        return req

    def run_until_drained(self) -> Dict[int, ProgramRequest]:
        """Serve everything in flight; returns rid -> finished request.

        A request the scheduler resolved with a typed error (quarantine,
        deadline, shed, cancellation — docs/SERVING.md "Failure
        semantics") finishes with ``req.error`` set and ``req.result``
        ``None``; one failed request never aborts the drain of the
        others."""
        self.scheduler.drain()
        with self._lock:
            inflight = list(self._inflight.items())
        for rid, req in inflight:            # blocks outside the lock
            try:
                req.result = req.ticket.result()
            except Exception as e:
                req.error = e
        with self._lock:
            for rid, req in inflight:
                self._done[rid] = req
                self._inflight.pop(rid, None)
            while len(self._done) > self.keep_done:
                self._done.popitem(last=False)
            return dict(self._done)      # snapshot, not the internal dict

    def health(self) -> Dict:
        """The underlying scheduler's health snapshot (worker liveness,
        breakers, quarantine, retry/shed/audit counters) — what a
        mesh-level coordinator scrapes."""
        return self.scheduler.health()

    def latency_stats(self, last: Optional[int] = None) -> Dict[str, float]:
        """Mean/p50/p95 request latency (seconds) over finished requests
        (the ``last`` most recent ones when given — e.g. one replay)."""
        with self._lock:
            reqs = [self._done[rid] for rid in sorted(self._done)]
        if not reqs:
            return {"mean": 0.0, "p50": 0.0, "p95": 0.0}
        if last is not None:
            reqs = reqs[-last:]
        lats = np.array([r.latency for r in reqs])
        return {"mean": float(lats.mean()),
                "p50": float(np.percentile(lats, 50)),
                "p95": float(np.percentile(lats, 95))}
