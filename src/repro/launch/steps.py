"""Step-function factories + sharding trees shared by dryrun/train/serve."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell
from ..models.common import abstract_tree, axes_tree
from ..models.lm import LM
from ..models.specs import (decode_specs, prefill_batch_specs,
                            train_batch_specs)
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..parallel.axes import ShardingCtx, named_sharding, spec_for
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def shardings_for(axes, shapes, ctx: ShardingCtx):
    """Pytree of NamedSharding from parallel (axes, ShapeDtypeStruct)."""
    return jax.tree.map(
        lambda a, s: NamedSharding(
            ctx.mesh, spec_for(a, s.shape, ctx.mesh, ctx.rules)),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def replicated(ctx: ShardingCtx):
    return NamedSharding(ctx.mesh, P())


def opt_state_axes(param_axes):
    return {"m": param_axes, "v": param_axes, "step": ()}


def make_train_step(model: LM, opt_cfg: AdamWConfig):
    ga = model.cfg.grad_accum

    def train_step(params, opt_state, batch):
        if ga <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
        else:
            # gradient accumulation over microbatches (fp32 accumulators,
            # sharded like the params)
            micro = jax.tree.map(
                lambda x: x.reshape(ga, x.shape[0] // ga, *x.shape[1:]),
                batch)

            acc_dt = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[model.cfg.grad_accum_dtype]

            def body(carry, mb):
                gsum, lsum, msum = carry
                (loss, m), g = jax.value_and_grad(
                    model.loss, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gsum, g)
                msum = jax.tree.map(lambda a, b: a + b, msum, m)
                return (gsum, lsum + loss, msum), None

            gsum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            m0 = {"ce": 0.0, "aux": 0.0, "tokens": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (gsum, loss, msum), _ = jax.lax.scan(
                body, (gsum0, jnp.float32(0.0), m0), micro)
            grads = jax.tree.map(lambda g: g / ga, gsum)
            loss = loss / ga
            metrics = jax.tree.map(lambda x: x / ga, msum)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        metrics = {**metrics, **om, "loss": loss}
        return params, opt_state, metrics
    return train_step


def make_prefill_step(model: LM):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: LM):
    def decode_step(params, cache, tokens, cache_index):
        return model.decode_step(params, cache, tokens, cache_index)
    return decode_step


def jitted_cell(cfg: ModelConfig, cell: ShapeCell, ctx: ShardingCtx,
                opt_cfg: Optional[AdamWConfig] = None):
    """Build (jitted step fn, abstract args) for one (arch x shape) cell
    under a sharding context.  Used by the dry-run and the launchers."""
    model = LM(cfg)
    p_abs = model.abstract_params()
    p_axes = model.param_axes()
    p_shard = shardings_for(p_axes, p_abs, ctx)

    if cell.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        specs, baxes = train_batch_specs(cfg, cell)
        b_shard = shardings_for(baxes, specs, ctx)
        if opt_cfg.state_format == "int8":
            # block-quantized moments: q sharded like the param, the
            # per-row scale replicated on the last (quantized) dim
            from ..optim.adamw import _scale_shape

            def q_abs(s):
                return {"q": jax.ShapeDtypeStruct(s.shape, jnp.int8),
                        "s": jax.ShapeDtypeStruct(
                            _scale_shape(s.shape), jnp.float32)}

            m_abs = jax.tree.map(q_abs, p_abs)

            def q_shard(a, s):
                return {"q": NamedSharding(
                    ctx.mesh, spec_for(a, s.shape, ctx.mesh, ctx.rules)),
                    "s": NamedSharding(
                    ctx.mesh, spec_for(
                        tuple(a[:-1]) + (None,) if a else (None,),
                        _scale_shape(s.shape), ctx.mesh, ctx.rules))}

            m_shard = jax.tree.map(
                q_shard, p_axes, p_abs,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x))
        else:
            # optimizer m/v are fp32 with param shapes
            m_abs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                p_abs)
            m_shard = p_shard
        opt_abs = {"m": m_abs, "v": m_abs,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_shard = {"m": m_shard, "v": m_shard, "step": replicated(ctx)}
        metrics_shard = {k: replicated(ctx) for k in
                         ("ce", "aux", "tokens", "lr", "grad_norm", "loss")}
        step = jax.jit(
            make_train_step(model, opt_cfg),
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, metrics_shard),
            donate_argnums=(0, 1),
        )
        return step, (p_abs, opt_abs, specs)

    if cell.kind == "prefill":
        specs, baxes = prefill_batch_specs(cfg, cell)
        b_shard = shardings_for(baxes, specs, ctx)
        cache_defs = model.cache_defs(cell.global_batch, cell.seq_len)
        c_abs = abstract_tree(cache_defs)
        c_axes = axes_tree(cache_defs)
        c_shard = shardings_for(c_axes, c_abs, ctx)
        logits_shard = named_sharding(
            ("batch", "act_vocab"),
            (cell.global_batch, cfg.padded_vocab), ctx)
        step = jax.jit(
            make_prefill_step(model),
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard),
        )
        return step, (p_abs, specs)

    if cell.kind == "decode":
        c_abs, c_axes, tok, tok_axes = decode_specs(cfg, cell)
        c_shard = shardings_for(c_axes, c_abs, ctx)
        t_shard = shardings_for(tok_axes, tok, ctx)
        logits_shard = named_sharding(
            ("batch", "act_vocab"),
            (cell.global_batch, cfg.padded_vocab), ctx)
        idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
        step = jax.jit(
            make_decode_step(model),
            in_shardings=(p_shard, c_shard, t_shard["tokens"],
                          replicated(ctx)),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(1,),
        )
        return step, (p_abs, c_abs, tok["tokens"], idx_abs)

    raise ValueError(cell.kind)
