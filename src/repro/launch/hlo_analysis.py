"""Post-compile HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` gives per-device FLOPs and bytes-accessed but
no collective traffic, so collective bytes are parsed from the compiled
(partitioned, per-device) HLO text: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op we sum the *result*
shape bytes — the data each device materializes from the wire (a
consistent, schedule-independent proxy; documented in EXPERIMENTS.md).

Hardware model (TPU v5e-class, from the task spec):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_START_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes in one device's module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        kind = None
        for k in _COLLECTIVES:
            # match the op name, not fusion names: " all-reduce(" etc.
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        m = _START_RE.match(line)
        if not m:
            continue
        rhs = m.group(1)
        # result type = shape tokens before the op name
        op_pos = rhs.find(kind)
        result_part = rhs[:op_pos]
        total = 0
        for dt, dims in _SHAPE_RE.findall(result_part):
            total += _shape_bytes(dt, dims)
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops_total: float          # 6ND / 2ND convention

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
        (catches remat/redundancy waste)."""
        per_dev = self.model_flops_total / self.chips
        return per_dev / max(self.flops_per_device, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization at the modelled bound: the fraction of
        peak the step achieves if it runs exactly at the dominant term."""
        per_dev_useful = self.model_flops_total / self.chips
        return per_dev_useful / (self.bound_s * PEAK_FLOPS)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, cell) -> float:
    """6*N*D train / 2*N*D inference convention; N_active for MoE."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch
