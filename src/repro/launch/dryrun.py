import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]

Methodology note (documented in EXPERIMENTS.md §Dry-run): XLA's
``cost_analysis`` counts a ``while`` (scan) body ONCE, so flops/bytes/
collective-bytes are measured by compiling the model at 1 and 2 layer
*units* and extrapolating ``c1 + (units-1) * (c2 - c1)``; the inner
attention/cross-entropy chunk scans are set to trip-count 1 for those
analysis compiles.  Peak memory and the compile proof come from the
full-depth compile with production chunking.

Results are cached as JSON under results/dryrun/.  The XLA_FLAGS line
above MUST run before any jax import (device count locks at first init).
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax           # noqa: E402

from ..configs import ARCH_IDS, SHAPES, cell_supported, get_config  # noqa: E402
from ..parallel.axes import sharding_context  # noqa: E402
from . import hlo_analysis  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import jitted_cell  # noqa: E402

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/dryrun")

# Gradient accumulation per arch for the train_4k cell, chosen so the
# per-device peak fits a 16 GB v5e HBM (see EXPERIMENTS.md §Dry-run).
TRAIN_GRAD_ACCUM = {
    "qwen2-72b": 4,
    "granite-34b": 4,
    "arctic-480b": 4,
    "nemotron-4-15b": 2,
    "llama4-scout-17b-a16e": 8,
    "llama-3.2-vision-11b": 2,
    "zamba2-2.7b": 4,
}


def result_path(arch: str, shape: str, multi_pod: bool,
                tag: str = "") -> str:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{mesh_name}{suffix}.json")


def layer_plan(cfg) -> Tuple[Dict, Dict, int]:
    """(unit-1 overrides, unit-2 overrides, #units) for extrapolation."""
    fam = cfg.family
    if fam == "encdec":
        return ({"num_layers": 1, "encoder_layers": 1},
                {"num_layers": 2, "encoder_layers": 2}, cfg.num_layers)
    if fam == "vlm":
        e = cfg.cross_attn_every
        return ({"num_layers": e}, {"num_layers": 2 * e},
                cfg.num_layers // e)
    if fam == "hybrid":
        e = cfg.attn_every
        return ({"num_layers": e}, {"num_layers": 2 * e},
                cfg.num_layers // e)
    return ({"num_layers": 1}, {"num_layers": 2}, cfg.num_layers)


def _compile_once(cfg, cell, multi_pod: bool, rules=None,
                  opt_overrides=None):
    from ..optim import AdamWConfig
    mesh = make_production_mesh(multi_pod=multi_pod)
    from ..parallel.axes import DEFAULT_RULES
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    opt_cfg = AdamWConfig(**opt_overrides) if opt_overrides else None
    with sharding_context(mesh, merged) as ctx:
        step, abstract_args = jitted_cell(cfg, cell, ctx, opt_cfg=opt_cfg)
        lowered = step.lower(*abstract_args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return compiled, cost, coll, mesh.size


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             overrides: Optional[Dict] = None, tag: str = "",
             force: bool = False, analysis: bool = True,
             rule_overrides: Optional[Dict] = None,
             opt_overrides: Optional[Dict] = None) -> Dict:
    """Lower+compile one cell; returns (and caches) the analysis record."""
    path = result_path(arch, shape, multi_pod, tag)
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cell.kind == "train" and arch in TRAIN_GRAD_ACCUM:
        cfg = dataclasses.replace(
            cfg, grad_accum=TRAIN_GRAD_ACCUM[arch])
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    ok, why = cell_supported(cfg, cell)
    rec: Dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag,
                 "overrides": overrides or {},
                 "rule_overrides": {k: list(v) for k, v in
                                    (rule_overrides or {}).items()}}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(path, rec)
        return rec

    t0 = time.time()
    try:
        # 1) full-depth compile: the runnability proof + memory analysis
        compiled, cost_full, coll_full, chips = _compile_once(
            cfg, cell, multi_pod, rules=rule_overrides,
            opt_overrides=opt_overrides)
        mem = compiled.memory_analysis()
        t_full = time.time() - t0
        rec.update(
            status="ok", chips=chips, compile_s=round(t_full, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes_per_device":
                    mem.argument_size_in_bytes +
                    mem.output_size_in_bytes +
                    mem.temp_size_in_bytes -
                    mem.alias_size_in_bytes,
            },
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
            cost_raw={"flops": float(cost_full.get("flops", 0.0)),
                      "bytes": float(cost_full.get("bytes accessed", 0.0)),
                      "collectives": coll_full},
        )

        if analysis:
            # 2) unit-extrapolated roofline terms
            o1, o2, units = layer_plan(cfg)
            # analysis compiles measure per-step totals at grad_accum=1
            # (an accumulated step does the same work per token)
            chunks = {"attn_chunk": cell.seq_len, "ce_chunk": cell.seq_len,
                      "scan_unroll": True, "grad_accum": 1}
            c1 = dataclasses.replace(cfg, **o1, **chunks)
            c2 = dataclasses.replace(cfg, **o2, **chunks)
            _, costa, colla, _ = _compile_once(
                c1, cell, multi_pod, rules=rule_overrides,
                opt_overrides=opt_overrides)
            _, costb, collb, _ = _compile_once(
                c2, cell, multi_pod, rules=rule_overrides,
                opt_overrides=opt_overrides)

            def extrap(a, b):
                return a + (units - 1) * (b - a)

            flops = extrap(float(costa.get("flops", 0.0)),
                           float(costb.get("flops", 0.0)))
            nbytes = extrap(float(costa.get("bytes accessed", 0.0)),
                            float(costb.get("bytes accessed", 0.0)))
            coll = {k: int(extrap(colla[k], collb[k])) for k in colla}
            rl = hlo_analysis.Roofline(
                flops_per_device=flops,
                bytes_per_device=nbytes,
                collective_bytes_per_device=float(coll["total"]),
                chips=chips,
                model_flops_total=hlo_analysis.model_flops(cfg, cell),
            )
            rec["collectives"] = coll
            rec["roofline"] = rl.as_dict()
            rec["extrapolation"] = {"units": units, "o1": o1, "o2": o2}
        rec["total_s"] = round(time.time() - t0, 2)
    except Exception as e:                      # record the failure
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    _save(path, rec)
    return rec


def _save(path: str, rec: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="full compile only (multi-pod proof runs)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for arch, shape in cells:
        for mp in meshes:
            # roofline analysis is single-pod only (per spec); multi-pod
            # runs prove the 'pod' axis shards.
            analysis = (not mp) and (not args.no_analysis)
            rec = run_cell(arch, shape, multi_pod=mp, force=args.force,
                           analysis=analysis)
            status = rec["status"]
            extra = ""
            if status == "ok" and "roofline" in rec:
                r = rec["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" compute={r['compute_s']*1e3:.2f}ms"
                         f" memory={r['memory_s']*1e3:.2f}ms"
                         f" coll={r['collective_s']*1e3:.2f}ms"
                         f" useful={r['useful_flops_ratio']:.2f}"
                         f" frac={r['roofline_fraction']:.3f}"
                         f" peakGB="
                         f"{rec['memory']['peak_bytes_per_device']/2**30:.2f}")
            elif status == "ok":
                extra = (f" peakGB="
                         f"{rec['memory']['peak_bytes_per_device']/2**30:.2f}"
                         f" compile={rec['compile_s']:.0f}s")
            elif status == "error":
                extra = " " + rec["error"].splitlines()[0]
            elif status == "skipped":
                extra = " (" + rec["reason"][:60] + ")"
            print(f"[dryrun] {arch:24s} {shape:12s} "
                  f"{'2x16x16' if mp else '16x16':8s} {status}{extra}",
                  flush=True)


if __name__ == "__main__":
    main()
