"""Training driver: data pipeline -> sharded train loop -> checkpoints.

Production behaviors (all unit-tested):
  * restart-from-latest-checkpoint (exact data-position resume),
  * async checkpointing every --ckpt-every steps with retention,
  * SIGTERM/SIGINT emergency checkpoint (preemption handling),
  * heartbeat + straggler runtime hooks (single-host here; the monitors
    are the same objects a multi-host coordinator would drive),
  * optional int8-compressed cross-pod gradient sync (see
    repro.parallel.compression; demonstrated in the shard_map DP path).

CPU-scale usage (examples/train_tiny.py wraps this):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager, load_checkpoint
from ..checkpoint.store import latest_step
from ..configs import ARCH_IDS, get_config
from ..configs.base import ShapeCell
from ..data import DataConfig, make_train_batches
from ..models.lm import LM
from ..models.specs import train_batch_specs
from ..optim import AdamWConfig, adamw_init
from ..parallel.axes import sharding_context
from ..runtime import HeartbeatMonitor, StragglerDetector
from .mesh import make_mesh
from .steps import jitted_cell


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    host: str = "host0"


def train_loop(cfg, cell: ShapeCell, loop: TrainLoopConfig,
               mesh=None, opt_cfg: Optional[AdamWConfig] = None,
               seed: int = 0) -> Dict[str, float]:
    """Runs the loop; returns final metrics.  Restartable."""
    mesh = mesh or make_mesh({"data": 1, "model": 1})
    opt_cfg = opt_cfg or AdamWConfig(total_steps=loop.steps)
    model = LM(cfg)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=cell.seq_len,
                          global_batch=cell.global_batch, seed=seed)

    heart = HeartbeatMonitor([loop.host])
    straggler = StragglerDetector()
    manager = (CheckpointManager(loop.ckpt_dir)
               if loop.ckpt_dir else None)

    with sharding_context(mesh) as ctx:
        step_fn, _ = jitted_cell(cfg, cell, ctx, opt_cfg=opt_cfg)

        start_step, start_doc = 0, 0
        params = opt_state = None
        if loop.ckpt_dir and latest_step(loop.ckpt_dir) is not None:
            template = {
                "params": model.abstract_params(),
                "opt": {"m": model.abstract_params(),
                        "v": model.abstract_params(),
                        "step": jax.ShapeDtypeStruct((), jnp.int32)},
            }
            template = jax.tree.map(
                lambda s: np.zeros(s.shape, s.dtype), template)
            state, meta = load_checkpoint(loop.ckpt_dir, template)
            params, opt_state = state["params"], state["opt"]
            opt_state["m"] = jax.tree.map(
                lambda x: x.astype(jnp.float32), opt_state["m"])
            opt_state["v"] = jax.tree.map(
                lambda x: x.astype(jnp.float32), opt_state["v"])
            start_step = int(meta["step"])
            start_doc = int(meta.get("next_doc", 0))
            print(f"[train] restored step {start_step} "
                  f"(doc {start_doc}) from {loop.ckpt_dir}", flush=True)
        if params is None:
            params = model.init_params(jax.random.PRNGKey(seed))
            opt_state = adamw_init(params, opt_cfg.state_format)

        batches = make_train_batches(data_cfg, start_doc=start_doc)

        interrupted = {"flag": False}

        def on_signal(signum, frame):
            interrupted["flag"] = True

        old_handlers = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                old_handlers[sig] = signal.signal(sig, on_signal)
            except ValueError:          # non-main thread (tests)
                pass

        metrics: Dict[str, float] = {}
        next_doc = start_doc
        try:
            for step in range(start_step, loop.steps):
                t0 = time.time()
                batch = next(batches)
                next_doc = int(batch.pop("next_doc"))
                params, opt_state, m = step_fn(params, opt_state, batch)
                m["loss"].block_until_ready()
                dt = time.time() - t0
                heart.beat(loop.host)
                straggler.record(loop.host, dt)
                metrics = {k: float(v) for k, v in m.items()}
                metrics["step_time_s"] = dt
                if (step + 1) % loop.log_every == 0:
                    print(f"[train] step {step+1} "
                          f"loss={metrics['loss']:.4f} "
                          f"lr={metrics['lr']:.2e} {dt*1e3:.0f}ms",
                          flush=True)
                if manager and (step + 1) % loop.ckpt_every == 0:
                    manager.save_async(
                        step + 1, {"params": params, "opt": opt_state},
                        {"step": step + 1, "next_doc": next_doc})
                if interrupted["flag"]:
                    if manager:
                        manager.save_emergency(
                            step + 1, {"params": params, "opt": opt_state},
                            {"step": step + 1, "next_doc": next_doc})
                        print(f"[train] emergency checkpoint at "
                              f"step {step+1}", flush=True)
                    break
        finally:
            if manager:
                manager.wait()
            for sig, h in old_handlers.items():
                signal.signal(sig, h)
        metrics["final_step"] = float(
            min(loop.steps, step + 1) if loop.steps else 0)
        return metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1,
                    help="data-parallel mesh extent")
    ap.add_argument("--model", type=int, default=1,
                    help="model-parallel mesh extent")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    cell = ShapeCell("custom", args.seq, args.batch, "train")
    mesh = make_mesh({"data": args.data, "model": args.model})
    loop = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every)
    metrics = train_loop(cfg, cell, loop, mesh=mesh)
    print(f"[train] done: {metrics}")


if __name__ == "__main__":
    main()
