"""``repro.timing``: a cycle-accurate in-order pipeline model.

The analytic timelines (:func:`repro.core.cost.simulate`) are
single-number cycle counts; this package replays the same
:class:`~repro.core.cost.TraceEvent` streams through a configurable
in-order machine — fetch/decode rates, an issue-width-limited in-order
front end, a scoreboard with RAW/WAR/WAW tracking, functional-unit
pipes with chaining, and memory-port conflicts — parameterized by
YAML-style uarch configs (:data:`UARCH_CONFIGS`: one mobile core, one
per in-cache scheme BS/BP/BH/AC).

Most users never import this directly: the ``*-timed`` targets
registered by :mod:`repro.targets.timed` expose it through the uniform
artifact surface —

    art = repro.targets.compile(kernel, target="mve-bs-timed")
    tl = art.timeline()
    tl.stalls                      # per-cause: dependency / structural /
                                   # memory-port / frontend
    tl.lower_bound, tl.upper_bound # the verified analytic envelope

Every timed total is contractually inside ``[lower_bound,
upper_bound]`` computed from the same ops (:func:`envelope`) — fuzzed
in ``tests/test_conformance.py``, pinned in
``tests/test_timing_goldens.py``.  Design note: docs/TIMING.md.
"""
from .model import (CHAINABLE_FUS, CTRL_REG, MEM_REG,  # noqa: F401
                    TAG_REG, Scoreboard, TimedOp, TimedTimeline,
                    build_timed_ops, envelope, simulate_pipeline)
from .uarch import (UARCH_CONFIGS, FUSpec, UarchConfig,  # noqa: F401
                    get_uarch, list_uarchs)
