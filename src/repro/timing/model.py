"""The in-order pipeline model: scoreboard, FU pipes, ports, envelope.

The model replays a :class:`~repro.core.cost.TraceEvent` stream (turned
into :class:`TimedOp` records by :func:`build_timed_ops`) through a
configurable in-order machine (:class:`~repro.timing.uarch.UarchConfig`):

  fetch/decode  ops become issue-ready at ``i // fetch_rate +
                decode_latency``;
  issue         strictly in order, at most ``issue_width`` per cycle,
                gated by the scoreboard and structural availability;
  scoreboard    RAW (wait for the producer — or its chain point when
                chaining is on and both units chain), WAW (wait for the
                prior writer to complete), WAR (a writer waits until
                prior readers have finished reading);
  execute       the op holds one pipe of its functional unit for its
                occupancy; the ``mem`` unit's pipes are the memory
                ports and are held for the whole access.

Per-op durations reuse the analytic per-op costs of
:mod:`repro.core.cost` (``compute_cycles`` x serial passes,
``memory_access_cycles``) so the pipeline model and the analytic
timeline price identical work and differ only in *overlap* — which is
what makes the envelope contract provable:

* :func:`envelope` returns ``(lower, upper)`` computed from the same
  ops.  ``upper`` replays the stream fully serialized (every op waits
  for its predecessor to complete; no chaining, no dual issue); every
  constraint the pipeline model applies is weaker, so by induction its
  cycles never exceed ``upper``.  ``lower`` is the max of the ideal-
  issue bounds (front-end + latency floor per op, issue-slot count,
  per-unit occupancy over pipes) — each a true lower bound of any
  schedule.  ``tests/test_conformance.py`` fuzzes the bracket on random
  programs; ``tests/test_timing.py`` pins hazard semantics.

Stalls are attributed at issue, per cause, into
``TimedTimeline.stalls``: ``dependency`` (scoreboard), ``structural``
(FU pipe busy), ``memory-port`` (mem port busy), ``frontend``
(fetch/decode or issue-width limited).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import cost, isa
from ..core.cost import TimingParams, TraceEvent
from ..core.isa import (COMPARE_OPS, CONFIG_OPS, MEMORY_OPS, Op)
from ..core.machine import MVEConfig
from .uarch import UarchConfig, get_uarch

#: Virtual scoreboard resources beyond architectural vector registers:
#: the control-register file (every vector op reads the live dim/stride
#: config; every config op rewrites it), the Tag latch (compares write,
#: predicated ops read), and a memory-order token (loads read, stores
#: write) that keeps same-address accesses in program order.
CTRL_REG = -1
TAG_REG = -2
MEM_REG = -3

#: Units whose results can chain (stream element-wise to a consumer on a
#: *different* unit).  The controller and scalar core produce whole
#: values, not element streams.
CHAINABLE_FUS = frozenset({"array", "simd", "mem"})


@dataclasses.dataclass(frozen=True)
class TimedOp:
    """One operation as the pipeline model sees it.

    ``defs``/``uses`` name scoreboard resources (architectural registers
    ``>= 0`` plus the virtual ``CTRL_REG``/``TAG_REG``/``MEM_REG``);
    ``duration`` is the op's execution latency on its unit, ``lanes``
    the SIMD lanes it keeps busy (utilization accounting), ``count`` the
    dynamic instructions it stands for (scalar bundles carry many).
    """

    fu: str
    duration: float
    defs: Tuple[int, ...] = ()
    uses: Tuple[int, ...] = ()
    op: Optional[Op] = None
    lanes: float = 0.0
    count: int = 1
    label: str = ""


@dataclasses.dataclass
class TimedTimeline(cost.Timeline):
    """A :class:`~repro.core.cost.Timeline` with the pipeline model's
    extra surface: per-cause ``stalls``, per-unit busy cycles, and the
    verification envelope the totals are guaranteed to sit inside."""

    uarch: str = ""
    lower_bound: float = 0.0
    upper_bound: float = 0.0
    fu_busy: Dict[str, float] = dataclasses.field(default_factory=dict)
    issue_width: int = 1

    @property
    def stall_cycles(self) -> float:
        return sum(self.stalls.values())

    @property
    def issue_utilization(self) -> float:
        """Fraction of issue slots actually used."""
        ops = (self.vector_instructions + self.config_instructions
               + (1 if self.scalar_cycles else 0))
        slots = self.total_cycles * max(1, self.issue_width)
        return min(1.0, ops / slots) if slots else 0.0


class Scoreboard:
    """RAW/WAR/WAW dependency tracking over scoreboard resources.

    ``ready_time`` returns the earliest cycle an op's operands allow it
    to issue; ``commit`` records the op's start/complete times.  With
    chaining enabled, a RAW consumer on a chainable unit may start at
    ``min(producer_complete, producer_start + chain_latency)`` — never
    later than simply waiting, which the envelope proof relies on.
    """

    def __init__(self, chaining: bool = True, chain_latency: float = 8.0):
        self.chaining = chaining
        self.chain_latency = chain_latency
        self._ready: Dict[int, float] = {}    # write fully visible
        self._chain: Dict[int, float] = {}    # first elements usable
        self._readers: Dict[int, float] = {}  # last read completes

    def ready_time(self, op: TimedOp) -> float:
        t = 0.0
        chain_ok = self.chaining and op.fu in CHAINABLE_FUS
        for r in op.uses:                          # RAW
            if chain_ok and r >= 0:
                t = max(t, self._chain.get(r, 0.0))
            else:
                t = max(t, self._ready.get(r, 0.0))
        for r in op.defs:
            t = max(t, self._ready.get(r, 0.0))    # WAW
            t = max(t, self._readers.get(r, 0.0))  # WAR
        return t

    def commit(self, op: TimedOp, start: float, complete: float) -> None:
        for r in op.uses:
            self._readers[r] = max(self._readers.get(r, 0.0), complete)
        for r in op.defs:
            self._ready[r] = complete
            if op.fu in CHAINABLE_FUS and r >= 0:
                self._chain[r] = min(complete, start + self.chain_latency)
            else:
                self._chain[r] = complete
            self._readers[r] = 0.0         # new readers gate the *next* write


def simulate_pipeline(ops: Sequence[TimedOp], uarch,
                      lane_capacity: float = 0.0) -> TimedTimeline:
    """Replay ``ops`` through the in-order pipeline of ``uarch``.

    Deterministic by construction (no randomness, stable pipe
    selection) and monotone in ``issue_width`` / ``mem_ports`` — both
    properties are fuzzed in ``tests/test_timing.py``.
    """
    ua = get_uarch(uarch)
    sb = Scoreboard(ua.chaining, ua.chain_latency)
    pipes: Dict[str, List[float]] = {}
    stalls = {"frontend": 0.0, "dependency": 0.0,
              "structural": 0.0, "memory-port": 0.0}
    tl = TimedTimeline(uarch=ua.name, stalls=stalls,
                       issue_width=ua.issue_width)
    last_issue = 0.0
    slot_cycle, slot_used = -1, 0
    t_end = 0.0

    for i, op in enumerate(ops):
        decode_t = i // ua.fetch_rate + ua.decode_latency
        floor = max(last_issue, 0.0)
        base = max(decode_t, floor)
        stalls["frontend"] += base - floor

        dep = sb.ready_time(op)
        t_dep = max(base, dep)
        stalls["dependency"] += t_dep - base

        unit = pipes.setdefault(op.fu, [0.0] * ua.pipes_for(op.fu))
        j = min(range(len(unit)), key=unit.__getitem__)
        issue = max(t_dep, unit[j])
        stalls["memory-port" if op.fu == "mem" else "structural"] += \
            issue - t_dep

        cyc = int(issue)
        if cyc == slot_cycle and slot_used >= ua.issue_width:
            stalls["frontend"] += (slot_cycle + 1) - issue
            issue = float(slot_cycle + 1)
            cyc = slot_cycle + 1
        if cyc != slot_cycle:
            slot_cycle, slot_used = cyc, 0
        slot_used += 1

        hop = 0.0 if op.fu == "scalar" else ua.issue_latency
        start = issue + hop
        complete = start + op.duration
        unit[j] = start + ua.occupancy(op.fu, op.duration)
        sb.commit(op, start, complete)
        last_issue = issue
        t_end = max(t_end, complete)

        tl.fu_busy[op.fu] = tl.fu_busy.get(op.fu, 0.0) + op.duration
        tl.issue_cycles += hop
        if op.fu not in ("mem", "ctrl", "scalar"):
            # utilization counts compute lanes only; with chaining, mem
            # occupancy overlaps compute and would push the ratio past 1
            tl.busy_lane_cycles += op.duration * op.lanes
        if op.fu == "ctrl":
            tl.config_instructions += op.count
        elif op.fu == "scalar":
            tl.scalar_instructions += op.count
            tl.scalar_cycles += op.duration
        else:
            tl.vector_instructions += op.count
            if op.fu == "mem":
                tl.data_cycles += op.duration
            else:
                tl.compute_cycles += op.duration

    tl.total_cycles = t_end
    tl.lane_slots = t_end * lane_capacity
    busiest = max(tl.fu_busy.values(), default=0.0)
    tl.idle_cycles = max(0.0, t_end - busiest)
    tl.lower_bound, tl.upper_bound = envelope(ops, ua)
    return tl


def envelope(ops: Sequence[TimedOp], uarch) -> Tuple[float, float]:
    """``(ideal-issue lower bound, fully-serialized upper bound)`` for
    ``ops`` under ``uarch`` — the bracket every pipeline-model total is
    contractually inside (module docstring sketches the induction)."""
    ua = get_uarch(uarch)
    if not ops:
        return 0.0, 0.0
    lo = 0.0
    occ: Dict[str, float] = {}
    min_tail = math.inf
    for i, op in enumerate(ops):
        decode_t = i // ua.fetch_rate + ua.decode_latency
        hop = 0.0 if op.fu == "scalar" else ua.issue_latency
        lo = max(lo, decode_t + hop + op.duration)
        occ[op.fu] = occ.get(op.fu, 0.0) + ua.occupancy(op.fu, op.duration)
        min_tail = min(min_tail, hop + op.duration)
    lo = max(lo, math.ceil(len(ops) / ua.issue_width) - 1 + min_tail)
    for fu, total in occ.items():
        lo = max(lo, total / ua.pipes_for(fu))

    hi = 0.0
    for i, op in enumerate(ops):
        decode_t = i // ua.fetch_rate + ua.decode_latency
        issue = decode_t if i == 0 else max(decode_t, hi + 1.0)
        hop = 0.0 if op.fu == "scalar" else ua.issue_latency
        hi = issue + hop + op.duration
    return lo, hi


# ---------------------------------------------------------------------------
# TimedOp builders: trace -> pipeline-model input.
# ---------------------------------------------------------------------------

def _incache_duration(ev: TraceEvent, cfg: MVEConfig,
                      tp: TimingParams, ua: UarchConfig) -> float:
    """Identical to the per-event work :func:`repro.core.cost.simulate`
    charges, so analytic and pipeline models price the same ops."""
    if ev.op in CONFIG_OPS:
        return max(1.0, ua.config_latency)
    if ev.op is Op.SCALAR:
        return max(1.0, ev.scalar_count / tp.scalar_ipc)
    if ev.op in MEMORY_OPS:
        return max(1.0, cost.memory_access_cycles(ev, cfg, tp))
    eff = cfg.effective_lanes(ev.dtype.bits if ev.dtype else 32)
    passes = max(1, -(-ev.elements // max(eff, 1)))
    return max(1.0, cost.compute_cycles(ev.op, ev.dtype, cfg) * passes)


def _simd_duration(ev: TraceEvent, ua: UarchConfig) -> float:
    """Packed-SIMD per-event cost (the mobile-core config): one vector
    loop over 128-bit lanes per compute event; an L1 burst per access."""
    if ev.op in CONFIG_OPS:
        return max(1.0, ua.config_latency)
    if ev.op is Op.SCALAR:
        return max(1.0, ev.scalar_count / 4.0)
    bits = ev.dtype.bits if ev.dtype else 32
    if ev.op in MEMORY_OPS:
        bytes_ = ev.unique_elements * (bits // 8 or 1)
        return max(1.0, ua.simd_mem_latency
                   + bytes_ / ua.simd_bytes_per_cycle)
    lanes = max(1, ua.simd_bits // bits)
    return max(1.0, math.ceil(ev.elements / lanes))


def _fu_lanes(ev: TraceEvent, cfg: MVEConfig, ua: UarchConfig,
              cost_model: str) -> Tuple[str, float]:
    if ev.op in CONFIG_OPS:
        return "ctrl", 0.0
    if ev.op is Op.SCALAR:
        return "scalar", 0.0
    compute_fu = "array" if cost_model == "incache" else "simd"
    if ev.op in MEMORY_OPS:
        fu = "mem"
    else:
        fu = compute_fu
    if cost_model == "incache":
        bits = ev.dtype.bits if ev.dtype else 32
        lanes = float(min(ev.elements, cfg.effective_lanes(bits))
                      if fu != "mem" else ev.elements)
    else:
        bits = ev.dtype.bits if ev.dtype else 32
        lanes = float(min(ev.elements, max(1, ua.simd_bits // bits)))
    return fu, lanes


def _duration(ev: TraceEvent, cfg: MVEConfig, tp: TimingParams,
              ua: UarchConfig, cost_model: str) -> float:
    if cost_model == "simd":
        return _simd_duration(ev, ua)
    return _incache_duration(ev, cfg, tp, ua)


def _aligned_op(instr: "isa.Instr", ev: TraceEvent, cfg: MVEConfig,
                tp: TimingParams, ua: UarchConfig,
                cost_model: str) -> TimedOp:
    """Register-accurate TimedOp when the trace is 1:1 with the program
    (the MVE engine's static trace is — one event per instruction)."""
    fu, lanes = _fu_lanes(ev, cfg, ua, cost_model)
    dur = _duration(ev, cfg, tp, ua, cost_model)
    if fu == "ctrl":
        return TimedOp(fu, dur, defs=(CTRL_REG,), op=ev.op,
                       label=ev.op.value)
    if fu == "scalar":
        return TimedOp(fu, dur, op=ev.op, count=max(1, ev.scalar_count),
                       label="scalar")
    defs: List[int] = []
    uses: List[int] = [CTRL_REG]
    d = isa.reg_defs(instr)
    if d is not None:
        defs.append(d)
    uses.extend(isa.reg_uses(instr))
    if instr.op in COMPARE_OPS:
        defs.append(TAG_REG)
    if instr.predicated:
        uses.append(TAG_REG)
    if instr.op in MEMORY_OPS:
        if instr.op in (Op.SLD, Op.RLD):
            uses.append(MEM_REG)
        else:
            defs.append(MEM_REG)
    return TimedOp(fu, dur, defs=tuple(defs), uses=tuple(uses), op=ev.op,
                   lanes=lanes, label=ev.op.value)


def _synth_op(ev: TraceEvent, cfg: MVEConfig, tp: TimingParams,
              ua: UarchConfig, cost_model: str,
              last_defs: List[int], next_reg: List[int]) -> TimedOp:
    """TimedOp with a synthesized virtual-register chain, for lowered
    streams that are not 1:1 with the program (the RVV 1D decomposition
    interleaves address scalars, predicate config, partial accesses and
    pack moves).  Producers define rotating virtual registers; consumers
    read the most recent definitions — a deterministic, conservative
    dependence structure."""
    fu, lanes = _fu_lanes(ev, cfg, ua, cost_model)
    dur = _duration(ev, cfg, tp, ua, cost_model)
    if fu == "ctrl":
        return TimedOp(fu, dur, defs=(CTRL_REG,), op=ev.op,
                       label=ev.op.value)
    if fu == "scalar":
        return TimedOp(fu, dur, op=ev.op, count=max(1, ev.scalar_count),
                       label="scalar")

    def fresh() -> int:
        r = next_reg[0] % 32            # finite file: WAW/WAR pressure
        next_reg[0] += 1
        last_defs.append(r)
        if len(last_defs) > 2:
            del last_defs[0]
        return r

    defs: List[int] = []
    uses: List[int] = [CTRL_REG]
    op = ev.op
    if op in (Op.SLD, Op.RLD):
        uses.append(MEM_REG)
        defs.append(fresh())
    elif op in (Op.SST, Op.RST):
        uses.extend(last_defs[-1:])
        defs.append(MEM_REG)
    elif op in COMPARE_OPS:
        uses.extend(last_defs[-2:])
        defs.append(TAG_REG)
    elif op in (Op.CPY, Op.CVT, Op.SET_DUP, Op.SHI, Op.ROTI):
        uses.extend(last_defs[-1:])
        defs.append(fresh())
    else:                               # binary arithmetic
        uses.extend(last_defs[-2:])
        defs.append(fresh())
    return TimedOp(fu, dur, defs=tuple(defs), uses=tuple(uses), op=op,
                   lanes=lanes, label=op.value)


def build_timed_ops(program, trace: Sequence[TraceEvent], cfg: MVEConfig,
                    tp: Optional[TimingParams] = None,
                    uarch="mve-bs", cost_model: str = "incache",
                    ) -> Tuple[List[TimedOp], float]:
    """Turn a performance trace into pipeline-model input.

    Returns ``(ops, lane_capacity)``.  When ``trace`` is instruction-
    aligned with ``program`` (same length, same opcode per slot), defs
    and uses come from the real architectural registers; otherwise a
    virtual-register chain is synthesized from the event stream.
    """
    tp = tp or TimingParams()
    ua = get_uarch(uarch)
    instrs = tuple(getattr(program, "program", program) or ())
    aligned = (len(instrs) == len(trace)
               and all(ins.op is ev.op for ins, ev in zip(instrs, trace)))
    ops: List[TimedOp] = []
    if aligned:
        for ins, ev in zip(instrs, trace):
            ops.append(_aligned_op(ins, ev, cfg, tp, ua, cost_model))
    else:
        last_defs: List[int] = []
        next_reg = [0]
        for ev in trace:
            ops.append(_synth_op(ev, cfg, tp, ua, cost_model,
                                 last_defs, next_reg))
    if cost_model == "simd":
        lane_capacity = float(max(
            (op.lanes for op in ops if op.fu == "simd"), default=1.0)
            * ua.simd_pipes)
    else:
        lane_capacity = float(cfg.lanes)
    return ops, lane_capacity
