"""YAML-style micro-architecture configs for the pipeline model.

A :class:`UarchConfig` is the machine the trace replays through:
front-end rates (fetch/decode), in-order issue width, the core->engine
issue hop, chaining, memory ports, and a set of named functional units.
Configs are written as plain nested dicts (the same shape a YAML file
would parse to — see TBM's ``rvv-simple.yaml`` lineage) and frozen into
dataclasses via :meth:`UarchConfig.from_dict`, so a new machine is one
dict entry, not code (docs/TIMING.md shows a worked example).

Shipped configs:

  ===========  ===========================================================
  name         machine
  ===========  ===========================================================
  mobile-core  Cortex-A76-class mobile core: dual-issue, 2 ASIMD pipes,
               2 L/S ports, single-cycle forwarding
  mve-bs       MVE controller on the bit-serial engine: 1-wide issue over
               the 16-cycle core/L2 hop, TMU<->array chaining (8 cycles
               to the first usable bit-slice), one TMU stream port
  mve-bp       bit-parallel engine — word-granular chaining (2 cycles)
  mve-bh       bit-hybrid engine — segment-granular chaining (4 cycles)
  mve-ac       associative engine — no chaining (truth-table search
               consumes whole operand vectors per row)
  rvv-1d       the mve-bs controller driven by the lowered 1D stream
  ===========  ===========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class FUSpec:
    """One functional unit: ``pipes`` parallel instances; a *pipelined*
    unit accepts a new op every ``init_interval`` cycles while an
    unpipelined one is busy for the op's whole duration (in-cache array
    macro-ops hold the subarrays end to end)."""

    pipes: int = 1
    pipelined: bool = False
    init_interval: float = 1.0


@dataclasses.dataclass(frozen=True)
class UarchConfig:
    """One in-order machine the pipeline model simulates.

    ``issue_latency`` is the issue *hop* (core -> engine controller; the
    analytic model's ``TimingParams.issue_cycles``) every non-scalar op
    pays between issue and execution start.  ``chain_latency`` is the
    delay from a producer's start until its first results are usable by
    a chained consumer on a *different* unit; chaining never beats
    simply waiting for the producer to complete.
    """

    name: str
    description: str = ""
    fetch_rate: int = 4            # instructions fetched per cycle
    decode_latency: float = 1.0    # fetch -> issue-ready
    issue_width: int = 1           # in-order issues per cycle
    issue_latency: float = 16.0    # issue -> execution start hop
    config_latency: float = 1.0    # CR write occupancy on the controller
    chaining: bool = True
    chain_latency: float = 8.0
    mem_ports: int = 1
    fus: Tuple[Tuple[str, FUSpec], ...] = ()
    # analytic per-op cost constants for the packed-SIMD cost model
    simd_bits: int = 128
    simd_pipes: int = 2
    simd_mem_latency: float = 4.0
    simd_bytes_per_cycle: float = 16.0

    def spec(self, fu: str) -> FUSpec:
        for name, s in self.fus:
            if name == fu:
                return s
        return FUSpec()

    def pipes_for(self, fu: str) -> int:
        """Parallel instances of ``fu`` (memory ports for the ``mem``
        unit — the monotonicity knob the property suite raises)."""
        if fu == "mem":
            return max(1, self.mem_ports)
        return max(1, self.spec(fu).pipes)

    def occupancy(self, fu: str, duration: float) -> float:
        """Cycles one pipe of ``fu`` is blocked by an op of ``duration``
        (never more than the duration itself)."""
        if fu == "mem":
            return duration
        s = self.spec(fu)
        if s.pipelined:
            return min(duration, max(s.init_interval, 1.0))
        return duration

    @classmethod
    def from_dict(cls, name: str, d: Dict) -> "UarchConfig":
        """Build a config from a YAML-style nested dict; unknown keys
        raise so config typos fail loudly."""
        d = dict(d)
        fus = tuple(sorted(
            (fu, FUSpec(**spec)) for fu, spec in d.pop("fus", {}).items()))
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(
                f"uarch config {name!r}: unknown keys {sorted(unknown)}")
        return cls(name=name, fus=fus, **d)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["fus"] = {fu: dataclasses.asdict(s) for fu, s in self.fus}
        del d["name"]
        return d


# ---------------------------------------------------------------------------
# Shipped configs (YAML-style dicts; see module docstring).
# ---------------------------------------------------------------------------

_MVE_BS = {
    "description": "MVE controller, bit-serial engine (Neural Cache)",
    "fetch_rate": 4,
    "decode_latency": 1.0,
    "issue_width": 1,
    "issue_latency": 16.0,
    "config_latency": 1.0,
    "chaining": True,
    "chain_latency": 8.0,
    "mem_ports": 1,
    "fus": {
        "array": {"pipes": 1},                       # the CB subarrays
        "ctrl": {"pipes": 1, "pipelined": True},
        "scalar": {"pipes": 1, "pipelined": True},
    },
}

UARCH_CONFIGS: Dict[str, Dict] = {
    "mobile-core": {
        "description": "Cortex-A76-class mobile core (2x128b ASIMD)",
        "fetch_rate": 8,
        "decode_latency": 1.0,
        "issue_width": 2,
        "issue_latency": 1.0,
        "config_latency": 1.0,
        "chaining": True,
        "chain_latency": 1.0,       # single-cycle forwarding network
        "mem_ports": 2,
        "fus": {
            "simd": {"pipes": 2, "pipelined": True},
            "ctrl": {"pipes": 1, "pipelined": True},
            "scalar": {"pipes": 1, "pipelined": True},
        },
        "simd_bits": 128,
        "simd_pipes": 2,
        "simd_mem_latency": 4.0,
        "simd_bytes_per_cycle": 16.0,
    },
    "mve-bs": _MVE_BS,
    "mve-bp": dict(
        _MVE_BS,
        description="MVE controller, bit-parallel engine (VRAM)",
        chain_latency=2.0),
    "mve-bh": dict(
        _MVE_BS,
        description="MVE controller, bit-hybrid engine (EVE)",
        chain_latency=4.0),
    "mve-ac": dict(
        _MVE_BS,
        description="MVE controller, associative engine (CAPE)",
        chaining=False),
    "rvv-1d": dict(
        _MVE_BS,
        description="mve-bs controller replaying the lowered 1D stream"),
}

_CACHE: Dict[str, UarchConfig] = {}


def get_uarch(name_or_cfg) -> UarchConfig:
    """Resolve a shipped config by name; dicts and :class:`UarchConfig`
    instances pass through (dicts get the name ``"custom"``)."""
    if isinstance(name_or_cfg, UarchConfig):
        return name_or_cfg
    if isinstance(name_or_cfg, dict):
        return UarchConfig.from_dict("custom", name_or_cfg)
    if name_or_cfg not in UARCH_CONFIGS:
        raise ValueError(
            f"unknown uarch config {name_or_cfg!r}; shipped configs: "
            f"{', '.join(sorted(UARCH_CONFIGS))}")
    if name_or_cfg not in _CACHE:
        _CACHE[name_or_cfg] = UarchConfig.from_dict(
            name_or_cfg, UARCH_CONFIGS[name_or_cfg])
    return _CACHE[name_or_cfg]


def list_uarchs() -> Tuple[str, ...]:
    return tuple(sorted(UARCH_CONFIGS))
