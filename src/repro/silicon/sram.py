"""First-order CACTI-style SRAM subarray energy/area model.

CACTI (and the Accelergy wrapper the sweep cache mirrors) decomposes an
SRAM macro into cell matrix + peripheral circuitry and prices each
access as wire/device capacitance switched at the supply rail.  This
module is the same decomposition in closed form, small enough to audit:

* **Geometry.**  A :class:`SRAMSpec` names the technology node, the
  subarray shape (``wordlines`` rows x ``bitlines`` columns), the array
  count and the port count.  A 6T cell occupies ``cell_f2`` F^2 (F = the
  feature size); the cell aspect ratio fixes wordline/bitline wire
  lengths, which dominate the switched capacitance.

* **Energy.**  One wordline activation charges the wordline wire plus
  one access-transistor gate per column (``E = C * Vdd^2``).  A read
  additionally develops a small-signal swing (``v_swing_frac * Vdd``) on
  every bitline pair and fires one sense amp per column; a write drives
  full-rail swing on the written pairs.  An in-SRAM *compute* cycle is
  the Neural-Cache sequence — two wordline activations (both operands),
  the read swing, and the per-column peripheral logic (single-bit ALU +
  carry latch).

* **Area.**  Cell matrix plus CACTI-style peripheral overhead expressed
  in row/column equivalents (sense amps, write drivers and precharge as
  extra rows; row decoder and wordline drivers as extra columns), divided
  by an inter-array routing efficiency for the macro total.

* **Scaling.**  Linear dimensions scale with F, device/wire capacitance
  per unit length approximately with F^0.5, and Vdd weakly (DVS floors);
  so energy and area both shrink monotonically with the node — the
  monotonicity contract ``tests/test_silicon.py`` asserts.

Absolute constants below are documented 7 nm anchors, but the consumers
(:mod:`repro.silicon.params`, :mod:`repro.silicon.area`) use this model
**ratiometrically**: only the *relative* scaling between two geometries
ever reaches an :class:`~repro.core.cost.EnergyParams` or an area table,
and the default Table IV geometry is pinned to the repo's calibrated
constants (docs/SILICON.md, "Calibration contract").
"""
from __future__ import annotations

import dataclasses
import functools
import math

#: Reference node (nm) all constants below are anchored at.
REFERENCE_NODE_NM = 7.0

# -- 7 nm anchor constants ---------------------------------------------------
_VDD_7NM = 0.75               # V, nominal supply
_CELL_F2 = 157.0              # 6T high-density cell size in F^2
_CELL_ASPECT = 2.0            # cell width : height
_C_WIRE_FF_PER_UM = 0.20      # wire capacitance per um (M2-level)
_C_GATE_FF = 0.025            # access-transistor gate cap per cell on a WL
_C_DRAIN_FF = 0.020           # pass-gate drain cap per cell on a BL
_E_SENSE_PJ = 0.0020          # one sense-amp fire
_E_LOGIC_PJ = 0.0040          # per-column single-bit ALU + carry latch
_E_WIRE_PJ_PER_MM_BIT = 0.08  # H-tree data wire energy per bit per mm
_V_SWING_FRAC = 0.10          # read develop swing as a fraction of Vdd
_ROW_OVERHEAD = 18.0          # sense amps/write drivers/precharge, in rows
_COL_OVERHEAD = 14.0          # row decoder + WL drivers, in columns
_ARRAY_EFFICIENCY = 0.85      # macro area efficiency (inter-array routing)
_LEAK_NW_PER_CELL = 0.0015    # per-cell leakage power at 7 nm
_PORT_AREA_FACTOR = 0.35      # extra cell area per additional port
_PORT_CAP_FACTOR = 0.25       # extra BL/WL loading per additional port


@dataclasses.dataclass(frozen=True)
class SRAMSpec:
    """One SRAM macro: ``num_arrays`` subarrays of ``wordlines`` rows x
    ``bitlines`` columns in a ``tech_nm`` process."""

    tech_nm: float = 7.0
    num_arrays: int = 32
    bitlines: int = 256        # columns = SIMD lanes per array
    wordlines: int = 256       # rows = register-file bits per lane
    ports: int = 1

    def __post_init__(self) -> None:
        if self.tech_nm <= 0 or self.num_arrays <= 0 or self.ports <= 0 \
                or self.bitlines <= 0 or self.wordlines <= 0:
            raise ValueError(f"non-physical SRAMSpec: {self}")


@dataclasses.dataclass(frozen=True)
class SRAMEstimate:
    """Model output: per-access energies (pJ), leakage (mW), area (mm^2).

    ``read_pj_per_byte`` is the macro-level transfer cost — one access
    amortized over the bits it delivers plus the H-tree wire energy to
    the macro edge — which is what the L2->TMU ``e_l2_byte`` constant
    scales with.
    """

    wl_activate_pj: float      # one wordline activation in one subarray
    read_access_pj: float      # one full-row read (all bitlines)
    write_access_pj: float     # one full-row write
    compute_cycle_pj: float    # one in-SRAM compute cycle per subarray
    read_pj_per_byte: float    # macro transfer cost per byte
    leakage_mw: float          # whole-macro standby leakage
    subarray_area_mm2: float   # one subarray incl. its peripherals
    total_area_mm2: float      # whole macro incl. routing inefficiency


def _vdd(tech_nm: float) -> float:
    """Supply voltage: scales weakly with the node (DVS floors keep Vdd
    far from linear shrink)."""
    return _VDD_7NM * (tech_nm / REFERENCE_NODE_NM) ** 0.3


@functools.lru_cache(maxsize=4096)
def estimate(spec: SRAMSpec) -> SRAMEstimate:
    """Evaluate the analytic model for one :class:`SRAMSpec`.

    Pure and memoized — two equal specs return the *same* estimate
    object, which is what makes the ratio calibration in
    :mod:`repro.silicon.params` exact (``x / x == 1.0``).
    """
    s = spec.tech_nm / REFERENCE_NODE_NM    # linear feature scale
    vdd = _vdd(spec.tech_nm)
    port_cap = 1.0 + _PORT_CAP_FACTOR * (spec.ports - 1)
    port_area = 1.0 + _PORT_AREA_FACTOR * (spec.ports - 1)

    # cell geometry (um)
    f_um = spec.tech_nm * 1e-3
    cell_area_um2 = _CELL_F2 * f_um * f_um * port_area
    cell_w = math.sqrt(cell_area_um2 * _CELL_ASPECT)
    cell_h = cell_area_um2 / cell_w
    wl_len_um = spec.bitlines * cell_w
    bl_len_um = spec.wordlines * cell_h

    # switched capacitance (fF); device caps scale ~F, wire caps ~sqrt(F)
    c_wire = _C_WIRE_FF_PER_UM * math.sqrt(s)
    c_wl = (spec.bitlines * _C_GATE_FF * s + wl_len_um * c_wire) * port_cap
    c_bl = (spec.wordlines * _C_DRAIN_FF * s + bl_len_um * c_wire) * port_cap

    # energies (fF * V^2 = fJ; /1e3 -> pJ)
    wl_activate = c_wl * vdd * vdd * 1e-3
    bl_read_swing = spec.bitlines * c_bl * vdd * (_V_SWING_FRAC * vdd) * 1e-3
    bl_write_swing = 0.5 * spec.bitlines * c_bl * vdd * vdd * 1e-3
    sense = spec.bitlines * _E_SENSE_PJ * s * s
    logic = spec.bitlines * _E_LOGIC_PJ * s * s
    read_access = wl_activate + bl_read_swing + sense
    write_access = wl_activate + bl_write_swing
    # Neural-Cache compute cycle: both operand wordlines + sense + ALU
    compute_cycle = 2.0 * wl_activate + bl_read_swing + sense + logic

    # area (mm^2)
    subarray_area = ((spec.wordlines + _ROW_OVERHEAD) * cell_h *
                     (spec.bitlines + _COL_OVERHEAD) * cell_w) * 1e-6
    total_area = spec.num_arrays * subarray_area / _ARRAY_EFFICIENCY

    # macro transfer cost: one row read amortized over its bytes, plus
    # the H-tree hop to the macro edge (~sqrt(area) of wire per bit)
    htree_mm = math.sqrt(total_area)
    read_per_byte = (read_access / (spec.bitlines / 8.0) +
                     8.0 * htree_mm * _E_WIRE_PJ_PER_MM_BIT * s)

    cells = spec.num_arrays * spec.wordlines * spec.bitlines
    leakage_mw = cells * _LEAK_NW_PER_CELL * s * s * vdd / _VDD_7NM * 1e-6

    return SRAMEstimate(
        wl_activate_pj=wl_activate,
        read_access_pj=read_access,
        write_access_pj=write_access,
        compute_cycle_pj=compute_cycle,
        read_pj_per_byte=read_per_byte,
        leakage_mw=leakage_mw,
        subarray_area_mm2=subarray_area,
        total_area_mm2=total_area,
    )
