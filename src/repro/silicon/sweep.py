"""Disk-cached (scheme x geometry) silicon sweep.

Mirrors the CACTI workflow the accelergy wrapper uses (SNIPPETS.md 1-2):
evaluating the analytic model over a grid is cheap here but the *cache
discipline* is the point being reproduced — records are persisted to a
JSON sidecar keyed by :data:`~repro.silicon.params.SILICON_MODEL_VERSION`
so a warm run loads instead of recomputing, and a model change
invalidates the whole file rather than silently serving stale numbers.

Python's ``json`` serializes floats via ``repr`` (shortest round-trip),
so a loaded :class:`SiliconRecord` compares **equal** to the freshly
computed one — the cold==warm contract ``tests/test_silicon.py`` and the
``silicon`` bench section assert.

The cache file defaults to ``.silicon_records.json`` in the working
directory (override with ``REPRO_SILICON_CACHE``) and is gitignored.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional

from ..core.machine import MVEConfig
from . import params as _params
from .area import area_report
from .params import SILICON_MODEL_VERSION, derived_energy, spec_for
from .sram import estimate


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (scheme, geometry, node) coordinate in the sweep grid."""

    scheme: str = "bs"
    num_arrays: int = 32
    bitlines: int = 256
    wordlines: int = 256
    tech_nm: float = 7.0

    def cfg(self) -> MVEConfig:
        return MVEConfig(num_arrays=self.num_arrays, bitlines=self.bitlines,
                         wordlines=self.wordlines, scheme=self.scheme)

    @property
    def key(self) -> str:
        return (f"{self.scheme}@{self.num_arrays}x{self.bitlines}"
                f"x{self.wordlines}@{self.tech_nm}nm")


@dataclasses.dataclass(frozen=True)
class SiliconRecord:
    """One evaluated sweep point: derived energy constants, raw model
    outputs, and the area accounting."""

    point: SweepPoint
    params_source: str
    e_array_cycle: float
    e_l2_byte: float
    e_issue: float
    compute_cycle_pj: float
    read_pj_per_byte: float
    leakage_mw: float
    macro_area_mm2: float
    added_area_mm2: float
    overhead_pct: float


def default_grid() -> List[SweepPoint]:
    """4 schemes x 5 (arrays, bitlines) shapes x 2 wordline depths = 40
    points around the Table IV default."""
    shapes = [(16, 256), (32, 128), (32, 256), (32, 512), (64, 256)]
    return [SweepPoint(scheme=s, num_arrays=na, bitlines=bl, wordlines=wl)
            for s in _params.SCHEME_ARRAY_FACTOR
            for na, bl in shapes
            for wl in (128, 256)]


def evaluate_point(point: SweepPoint) -> SiliconRecord:
    """Run the analytic model + derivation for one sweep point."""
    cfg = point.cfg()
    ep, source = derived_energy(cfg, tech_nm=point.tech_nm)
    est = estimate(spec_for(cfg, point.tech_nm))
    ar = area_report(cfg, tech_nm=point.tech_nm)
    return SiliconRecord(
        point=point, params_source=source,
        e_array_cycle=ep.e_array_cycle, e_l2_byte=ep.e_l2_byte,
        e_issue=ep.e_issue,
        compute_cycle_pj=est.compute_cycle_pj,
        read_pj_per_byte=est.read_pj_per_byte,
        leakage_mw=est.leakage_mw,
        macro_area_mm2=est.total_area_mm2,
        added_area_mm2=ar.added_mm2,
        overhead_pct=ar.overhead_pct,
    )


def default_cache_path() -> str:
    return os.environ.get("REPRO_SILICON_CACHE", ".silicon_records.json")


def _to_json(records: Dict[str, SiliconRecord]) -> dict:
    flat = {}
    for key, rec in records.items():
        row = dataclasses.asdict(rec.point)
        row.update({f.name: getattr(rec, f.name)
                    for f in dataclasses.fields(rec) if f.name != "point"})
        flat[key] = row
    return {"model_version": SILICON_MODEL_VERSION, "records": flat}


def _from_json(doc: dict) -> Optional[Dict[str, SiliconRecord]]:
    if doc.get("model_version") != SILICON_MODEL_VERSION:
        return None
    point_fields = {f.name for f in dataclasses.fields(SweepPoint)}
    out: Dict[str, SiliconRecord] = {}
    for key, raw in doc.get("records", {}).items():
        point = SweepPoint(**{k: v for k, v in raw.items()
                              if k in point_fields})
        rest = {k: v for k, v in raw.items() if k not in point_fields}
        out[key] = SiliconRecord(point=point, **rest)
    return out


def load_cache(path: Optional[str] = None
               ) -> Optional[Dict[str, SiliconRecord]]:
    """Load cached records; ``None`` on missing/corrupt/stale-version."""
    path = path or default_cache_path()
    try:
        with open(path, "r", encoding="utf-8") as fh:
            doc = json.load(fh)
        return _from_json(doc)
    except (OSError, ValueError, TypeError, KeyError):
        return None


def sweep(points: Optional[Iterable[SweepPoint]] = None,
          cache_path: Optional[str] = None,
          force: bool = False) -> Dict[str, SiliconRecord]:
    """Evaluate ``points`` (default :func:`default_grid`), serving from
    and updating the JSON cache.

    ``force=True`` recomputes everything and rewrites the cache.  A
    cached file with a different :data:`SILICON_MODEL_VERSION` is
    discarded wholesale.
    """
    pts = list(points) if points is not None else default_grid()
    path = cache_path or default_cache_path()
    cached = None if force else (load_cache(path) or {})
    cached = cached or {}
    records: Dict[str, SiliconRecord] = {}
    missing = False
    for p in pts:
        hit = cached.get(p.key)
        if hit is not None and hit.point == p:
            records[p.key] = hit
        else:
            records[p.key] = evaluate_point(p)
            missing = True
    if missing or force:
        merged = {**cached, **records}
        tmp = f"{path}.tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump(_to_json(merged), fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
    return records
